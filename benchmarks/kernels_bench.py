"""Kernel-level microbenchmarks (jax engine primitives on CPU; the Pallas
bodies themselves are TPU-targeted and validated in interpret mode — wall
times here measure the XLA fallback path the CPU engine actually uses)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import csv_line


def _time(fn, *args, n=5):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / n


def bench_kernels() -> List[str]:
    import jax.numpy as jnp

    from repro.kernels import ops

    out: List[str] = []
    rng = np.random.default_rng(0)

    # rle_expand: 1M runs -> ~8M rows
    freqs = rng.integers(1, 16, 1_000_000)
    bounds = jnp.asarray(np.cumsum(freqs), jnp.int32)
    payload = jnp.asarray(rng.integers(0, 1 << 20, 1_000_000), jnp.int32)
    total = int(np.sum(freqs))
    t = _time(lambda: np.repeat(np.asarray(payload), freqs))
    out.append(csv_line("kernels/rle_expand_np/8M", t * 1e6,
                        f"rows={total};GBps={total * 4 / t / 1e9:.2f}"))

    # mul_segsum exact path: 4M entries, 100k segments
    seg = np.sort(rng.integers(0, 100_000, 4_000_000)).astype(np.int32)
    _, seg = np.unique(seg, return_inverse=True)
    x = jnp.asarray(rng.integers(0, 1000, len(seg)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 1000, len(seg)), jnp.int32)
    segj = jnp.asarray(seg, jnp.int32)
    ns = int(seg.max()) + 1
    t = _time(lambda: ops.mul_segsum(segj, x, y, ns, exact=True))
    out.append(csv_line("kernels/mul_segsum_exact/4M", t * 1e6,
                        f"entries={len(seg)}"))

    # dense_message MXU-shape matmul (counting semiring)
    phi = jnp.asarray(rng.integers(0, 100, (2048, 2048)), jnp.float32)
    m = jnp.asarray(rng.integers(0, 100, (2048, 128)), jnp.float32)
    t = _time(lambda: (phi @ m).block_until_ready())
    flops = 2 * 2048 * 2048 * 128
    out.append(csv_line("kernels/dense_message/2048", t * 1e6,
                        f"GFLOPs={flops / t / 1e9:.1f}"))
    return out
