"""Kernel-level microbenchmarks (jax engine primitives on CPU; the Pallas
bodies themselves are TPU-targeted and validated in interpret mode — wall
times here measure the XLA fallback path the CPU engine actually uses,
except the fused-vs-per-column comparison, which times both Pallas paths
under the interpreter so the ratio isolates the amortized run search).

Run as a module for the CI gate / JSON summary:

  PYTHONPATH=src python -m benchmarks.kernels_bench --smoke
  PYTHONPATH=src python -m benchmarks.kernels_bench --json BENCH_kernels.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import csv_line


def _time(fn, *args, n=5):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / n


def bench_fused_expand(n_runs: int = 20_000, reps: int = 3) -> List[str]:
    """Per-level desummarization: fused multi-payload vs per-column kernel.

    The fused kernel recovers each output tile's run index once for all K
    payload columns; the per-column path re-runs the 2*RB comparison-matrix
    search (and a kernel launch, and the bounds-window reads) K times.  Both
    run in interpret mode — the only way to execute Pallas bodies on this
    CPU container — so the ratio reflects the amortization, not Mosaic
    codegen.
    """
    import jax.numpy as jnp

    from repro.kernels import ops

    out: List[str] = []
    rng = np.random.default_rng(0)
    freqs = rng.integers(1, 9, n_runs)
    bounds = jnp.asarray(np.cumsum(freqs), jnp.int32)
    total = int(np.sum(freqs))

    for k in (4, 8):
        payloads = jnp.asarray(
            rng.integers(0, 1 << 20, (k, n_runs)), jnp.int32)

        def per_column():
            cols = [ops.rle_expand(payloads[q], bounds, total,
                                   interpret=True) for q in range(k)]
            return cols[-1]

        def fused():
            return ops.rle_expand_many(payloads, bounds, total,
                                       interpret=True)

        t_col = _time(per_column, n=reps)
        t_fus = _time(fused, n=reps)
        out.append(csv_line(
            f"kernels/expand_level_per_column/K{k}", t_col * 1e6,
            f"rows={total}"))
        out.append(csv_line(
            f"kernels/expand_level_fused/K{k}", t_fus * 1e6,
            f"rows={total};speedup={t_col / t_fus:.2f}x"))
    return out


def bench_kernels() -> List[str]:
    import jax.numpy as jnp

    from repro.kernels import ops

    out: List[str] = []
    rng = np.random.default_rng(0)

    # rle_expand: 1M runs -> ~8M rows
    freqs = rng.integers(1, 16, 1_000_000)
    bounds = jnp.asarray(np.cumsum(freqs), jnp.int32)
    payload = jnp.asarray(rng.integers(0, 1 << 20, 1_000_000), jnp.int32)
    total = int(np.sum(freqs))
    t = _time(lambda: np.repeat(np.asarray(payload), freqs))
    out.append(csv_line("kernels/rle_expand_np/8M", t * 1e6,
                        f"rows={total};GBps={total * 4 / t / 1e9:.2f}"))

    # mul_segsum exact path: 4M entries, 100k segments
    seg = np.sort(rng.integers(0, 100_000, 4_000_000)).astype(np.int32)
    _, seg = np.unique(seg, return_inverse=True)
    x = jnp.asarray(rng.integers(0, 1000, len(seg)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 1000, len(seg)), jnp.int32)
    segj = jnp.asarray(seg, jnp.int32)
    ns = int(seg.max()) + 1
    t = _time(lambda: ops.mul_segsum(segj, x, y, ns, exact=True))
    out.append(csv_line("kernels/mul_segsum_exact/4M", t * 1e6,
                        f"entries={len(seg)}"))

    # dense_message MXU-shape matmul (counting semiring)
    phi = jnp.asarray(rng.integers(0, 100, (2048, 2048)), jnp.float32)
    m = jnp.asarray(rng.integers(0, 100, (2048, 128)), jnp.float32)
    t = _time(lambda: (phi @ m).block_until_ready())
    flops = 2 * 2048 * 2048 * 128
    out.append(csv_line("kernels/dense_message/2048", t * 1e6,
                        f"GFLOPs={flops / t / 1e9:.1f}"))

    out.extend(bench_fused_expand())
    return out


def smoke() -> int:
    """Exact-equality gate: fused kernel vs the np.repeat oracle (CI)."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.expand import expand_gather
    from repro.kernels.expand_fused import expand_gather_many

    rng = np.random.default_rng(7)
    failures = 0

    def check(name, got, want):
        nonlocal failures
        if np.array_equal(np.asarray(got), np.asarray(want)):
            print(f"  ok  {name}")
        else:
            failures += 1
            print(f"FAIL  {name}")

    # mixed zero-length runs, several K
    freqs = rng.integers(0, 5, 700)
    bounds = np.cumsum(freqs).astype(np.int32)
    total = int(bounds[-1])
    for k in (1, 3, 6):
        payloads = rng.integers(0, 1 << 20, (k, 700)).astype(np.int32)
        got = ops.rle_expand_many(payloads, bounds, total, interpret=True)
        want = np.stack([np.repeat(payloads[q], freqs) for q in range(k)])
        check(f"fused K={k} vs np.repeat", got, want)

    # single-run level
    got = ops.rle_expand_many(np.asarray([[42], [7]], np.int32),
                              np.asarray([5], np.int32), 5, interpret=True)
    check("single run", got, [[42] * 5, [7] * 5])

    # K=1 degeneration matches expand_gather including the padded tail
    t_pad = ops.next_bucket(total)
    payload = rng.integers(0, 1 << 20, 700).astype(np.int32)
    g1 = expand_gather(jnp.asarray(payload), jnp.asarray(bounds),
                       t_pad=t_pad, interpret=True)
    gm = expand_gather_many(jnp.asarray(payload[None]), jnp.asarray(bounds),
                            t_pad=t_pad, interpret=True)
    check("K=1 tail contract", gm[0], g1)

    print("smoke:", "FAIL" if failures else "OK")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="exact-equality gate (fused kernel vs oracle)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the csv rows as a JSON summary")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    lines = bench_kernels()
    print("name,us_per_call,derived")
    for line in lines:
        print(line, flush=True)
    if args.json:
        write_json(lines, args.json)
    return 0


def write_json(lines: List[str], path: str) -> None:
    """Persist csv rows as {name: {us_per_call, derived...}} (perf trail)."""
    summary: Dict[str, Dict[str, object]] = {}
    for line in lines:
        name, us, derived = line.split(",", 2)
        entry: Dict[str, object] = {"us_per_call": float(us)}
        for kv in filter(None, derived.split(";")):
            k, _, v = kv.partition("=")
            try:
                entry[k] = float(v.rstrip("x"))
            except ValueError:
                entry[k] = v
        summary[name] = entry
    with open(path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    sys.exit(main())
