"""Partitioned-execution benchmarks (DESIGN.md §15).

Measures hash-partitioned Graphical Join against the monolithic pipeline
on skewed lastfm-shaped instances:

* **step scaling** — wall time of the bottleneck elimination step,
  monolithic vs the slowest shard (the critical path of a k-device
  deployment: shards are independent programs, so the slowest shard IS
  the step's distributed latency);
* **wall scaling** — end-to-end summarize wall time, monolithic vs the
  sharded run on this host.  Thread rows contend for the GIL (an
  underestimate of device scaling); process rows (DESIGN §17 — the
  repro/dist/actions.py spawn pool) are real multi-core parallelism,
  bounded by the ``cpus`` column (on a 1-CPU container the honest
  process wall_scaling is ~1x minus dispatch overhead: the workers
  serialize on the single core);
* **balance** — per-worker folded row counts of the partitioned
  occurrences (how the multiplicative hash + over-partition fold spread
  a Zipf-skewed key).

Run as a module:

  PYTHONPATH=src python -m benchmarks.dist_bench --smoke     # CI gate
  PYTHONPATH=src python -m benchmarks.dist_bench --json BENCH_dist.json
  PYTHONPATH=src python -m benchmarks.dist_bench --shard-executor=process

``--smoke`` is an exact-equality gate: the partitioned summary's row
count, desummarized row multiset, and aggregates must equal the
monolithic numpy oracle's bit for bit — on the thread path AND across
real spawned shard workers (2-worker process path).
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Tuple

# must precede any jax import in the process: XLA pins the device count at
# first init, and the smoke gate wants the forced-virtual-device layout
# when invoked standalone (CI exports the same flag for the whole step)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np

from benchmarks.common import csv_line


def _instances(scale: float):
    """Skewed lastfm-shaped workloads (alpha cranks the key Zipf)."""
    from repro.relational.synth import lastfm_like
    out = []
    cat, qs = lastfm_like(
        n_users=int(1200 * scale), n_artists=int(900 * scale),
        artists_per_user=18, friends_per_user=8, alpha=1.35, seed=7)
    out.append(("lastfm_hot_A2", cat, qs["lastfm_A2"]))
    out.append(("lastfm_hot_cyc", cat, qs["lastfm_cyc"]))
    return out


def _cpus() -> int:
    """CPUs this process may actually use (the hard cap on process-path
    wall scaling — reported next to it so the numbers stay honest)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # pragma: no cover - non-Linux
        return os.cpu_count() or 1


def _run(cat, query, partitions: int, shard_executor=None):
    """(gj, gfjs, summarize_wall_seconds) for one pipeline run."""
    from repro.core.api import GraphicalJoin
    kw = {}
    if partitions > 1:
        kw["partitions"] = partitions
        if shard_executor:
            kw["shard_executor"] = shard_executor
    gj = GraphicalJoin(cat, query, **kw)
    gj.plan()                       # planning excluded from the wall time
    if shard_executor == "process":
        # pool startup (spawn + worker imports) is a one-time service
        # cost, not per-query latency: run one untimed warmup query so the
        # persistent shared pool is hot before the measured dispatch
        GraphicalJoin(cat, query, **kw).run()
    t0 = time.perf_counter()
    gfjs = gj.run()
    wall = time.perf_counter() - t0
    return gj, gfjs, wall


def _serial_shard_step_seconds(enc, plan) -> List[dict]:
    """Per-virtual-shard step wall times measured in ISOLATION (shards
    one at a time) — each shard of a real deployment runs alone on its
    device, so the un-contended per-shard max is the honest step-level
    critical path (the executor's pooled run would charge contention to
    it).  With ``partition_fold`` > 1 the caller folds these onto the
    worker count before taking the max."""
    from repro.core.elimination import build_generator
    from repro.dist.partition import PartitionScheme, partition_encoded
    scheme = PartitionScheme(plan.partition_var,
                             plan.partitions * plan.partition_fold)
    out = []
    for enc_s in partition_encoded(enc, scheme):
        gen = build_generator(enc_s, elimination_order=list(plan.order),
                              early_projection=plan.early_projection)
        out.append(dict(gen.step_seconds))
    return out


def bench_dist(partitions: int = 4, scale: float = 1.0,
               shard_executor: str = "both") -> List[str]:
    executors = ("thread", "process") if shard_executor == "both" \
        else (shard_executor,)
    lines: List[str] = []
    for name, cat, query in _instances(scale):
        mono_gj, mono_g, mono_wall = _run(cat, query, 1)
        for executor in executors:
            part_gj, part_g, part_wall = _run(cat, query, partitions,
                                              shard_executor=executor)
            assert part_g.join_size == mono_g.join_size

            plan = part_gj.plan()
            pvar = plan.partition_var
            mono_step = mono_gj._executor.step_seconds.get(pvar, 0.0)
            per_shard = _serial_shard_step_seconds(part_gj.enc, plan)
            # fold the virtual-shard step times onto the worker count —
            # the folded max is the per-device critical path
            from repro.dist.partition import fold_loads
            shard_step = float(fold_loads(
                [s.get(pvar, 0.0) for s in per_shard],
                plan.partitions).max())
            step_scaling = mono_step / shard_step if shard_step > 0 else 0.0
            wall_scaling = mono_wall / part_wall if part_wall > 0 else 0.0
            # skew comes from the executor's shard report (the same
            # per-shard matrix explain(analyze=True) renders) instead of
            # being recomputed here — one measurement, every consumer
            report = part_gj._executor.shard_report or {}
            balance = report.get("skew", 1.0)
            time_skew = report.get("time_skew", 1.0)
            stragglers = len(report.get("stragglers", ()))
            suffix = "" if executor == "thread" else f"_{executor}"
            lines.append(csv_line(
                f"dist/{name}_p{partitions}{suffix}", part_wall * 1e6,
                f"step_scaling={step_scaling:.2f}x;"
                f"wall_scaling={wall_scaling:.2f}x;"
                f"partition_var={pvar};join_size={mono_g.join_size};"
                f"shard_skew={balance:.2f};time_skew={time_skew:.2f};"
                f"stragglers={stragglers};partitions={partitions};"
                f"fold={plan.partition_fold};"
                f"executor={executor};workers={report.get('workers', 0)};"
                f"retries={report.get('retries', 0)};cpus={_cpus()}"))
    from repro.dist.actions import shutdown_shared_executor
    shutdown_shared_executor()
    lines.extend(bench_hybrid(scale))
    return lines


# ---------------------------------------------------------------------------
# Hypertree-decomposed hybrid GJ/WCOJ vs pure GJ (DESIGN §19)
# ---------------------------------------------------------------------------

def _hybrid_instances(scale: float):
    """Cyclic workloads: the skewed lastfm cycle plus the hub-skewed
    pattern family (the AGM-gap instances the WCOJ bag step exists for)."""
    from repro.relational.synth import cyclic_pattern_like, lastfm_like
    out = []
    cat, qs = lastfm_like(
        n_users=int(1200 * scale), n_artists=int(900 * scale),
        artists_per_user=18, friends_per_user=8, alpha=1.35, seed=7)
    out.append(("lastfm_hot_cyc", cat, qs["lastfm_cyc"]))
    # clique/star sizes are modest on purpose: the PURE-GJ side of the
    # comparison is quadratic through the hub, and the row exists to
    # measure the gap, not to spend minutes proving it grows
    for pattern, m in (("triangle", 1500), ("clique4", 400),
                       ("star_cyclic", 400)):
        c, q = cyclic_pattern_like(pattern, m=int(m * scale), domain=5000,
                                   dense=200, dense_domain=40, seed=0)
        out.append((f"{pattern}_hub", c, q))
    return out


def bench_hybrid(scale: float = 1.0) -> List[str]:
    """``hybrid/<name>`` rows: forced-hybrid wall vs pure GJ on the SAME
    elimination order (the isolated bag-step effect), plus which plan the
    cost model picks when left alone (``picked=``).  Exactness is asserted
    (join sizes must match) — a perf row from a wrong answer is worthless."""
    from repro.core.api import GraphicalJoin
    lines: List[str] = []
    for name, cat, query in _hybrid_instances(scale):
        gj_h = GraphicalJoin(cat, query, hybrid=True)
        plan_h = gj_h.plan()
        t0 = time.perf_counter()
        g_h = gj_h.run()
        hyb_wall = time.perf_counter() - t0
        gj_p = GraphicalJoin(cat, query, hybrid=False,
                             elimination_order=list(plan_h.order))
        t0 = time.perf_counter()
        g_p = gj_p.run()
        pure_wall = time.perf_counter() - t0
        assert g_h.join_size == g_p.join_size, name
        picked = GraphicalJoin(cat, query).plan().source
        speedup = pure_wall / hyb_wall if hyb_wall > 0 else 0.0
        rho = max((b.rho for b in plan_h.bags), default=0.0)
        lines.append(csv_line(
            f"hybrid/{name}", hyb_wall * 1e6,
            f"pure_us={pure_wall * 1e6:.1f};"
            f"hybrid_speedup={speedup:.2f}x;"
            f"bags={len(plan_h.bags)};rho={rho:.2f};"
            f"picked={picked};join_size={g_h.join_size};"
            f"order={'|'.join(plan_h.order)}"))
    return lines


# ---------------------------------------------------------------------------
# CI smoke: partitioned == monolithic oracle, exactly
# ---------------------------------------------------------------------------

def _row_multiset(gj, gfjs, all_vars) -> np.ndarray:
    res = gj.desummarize(gfjs, decode=False)
    if gfjs.join_size == 0:
        return np.zeros((0, len(all_vars)), np.int64)
    m = np.stack([res[v] for v in all_vars], axis=1)
    return m[np.lexsort(m.T[::-1])]


def smoke(workers: int = 2) -> int:
    from repro.dist.actions import shutdown_shared_executor
    from repro.relational.synth import lastfm_like
    from repro.summary.algebra import SummaryFrame
    cat, qs = lastfm_like(n_users=250, n_artists=180, artists_per_user=6,
                          friends_per_user=4, alpha=1.3, seed=3)
    failures = 0
    cases = [(name, 4, "thread")
             for name in ("lastfm_A1", "lastfm_A2", "lastfm_cyc")]
    # the process path across real spawned shard workers — same exact-
    # equality bar, acyclic + cyclic
    cases += [(name, workers, "process")
              for name in ("lastfm_A2", "lastfm_cyc")]
    for name, parts, executor in cases:
        query = qs[name]
        mono_gj, mono_g, _ = _run(cat, query, 1)
        part_gj, part_g, _ = _run(cat, query, parts,
                                  shard_executor=executor)
        vs = sorted(query.variables)
        f0, f1 = SummaryFrame.of(mono_g), SummaryFrame.of(part_g)
        var, key = vs[0], vs[-1]
        t0 = f0.group_by(key, n="count", s=("sum", var), lo=("min", var))
        t1 = f1.group_by(key, n="count", s=("sum", var), lo=("min", var))
        report = part_gj._executor.shard_report or {}
        ok = (part_g.join_size == mono_g.join_size
              and np.array_equal(_row_multiset(mono_gj, mono_g, vs),
                                 _row_multiset(part_gj, part_g, vs))
              and f1.count() == f0.count()
              and f1.sum(var) == f0.sum(var)
              and f1.min(var) == f0.min(var)
              and f1.max(var) == f0.max(var)
              and report.get("executor") == executor
              and all(np.array_equal(np.asarray(t0[k]), np.asarray(t1[k]))
                      for k in t0))
        print(f"dist-smoke {name} [{executor} x{parts}]: "
              f"join_size={mono_g.join_size} "
              f"shards={part_g.shard_sizes()} "
              f"retries={report.get('retries', 0)} "
              f"{'OK' if ok else 'MISMATCH'}")
        if not ok:
            failures += 1
    shutdown_shared_executor()
    try:
        import jax
        ndev = jax.device_count()
    except Exception:
        ndev = 0
    print(f"dist-smoke devices={ndev} cpus={_cpus()}")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="exact-equality gate (partitioned vs numpy oracle)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the csv rows as a JSON summary")
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--shard-executor", default="both",
                    choices=("thread", "process", "both"),
                    help="which shard-executor rows to measure "
                         "(smoke always covers both paths)")
    ap.add_argument("--workers", type=int, default=2,
                    help="process-pool workers for the smoke gate")
    ap.add_argument("--scale", type=float,
                    default=float(os.environ.get("BENCH_SCALE", "1.0")))
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke(args.workers)
    lines = bench_dist(args.partitions, args.scale, args.shard_executor)
    print("name,us_per_call,derived")
    for line in lines:
        print(line, flush=True)
    if args.json:
        from benchmarks.kernels_bench import write_json
        write_json(lines, args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
