"""Incremental refresh vs full rebuild — the maintenance-cost benchmark.

Two modes:

* ``python -m benchmarks.incremental_bench``           — lastfm-shaped
  tables at paper-adjacent scale (>= 1e6-row joins; duplication supplies
  the result redundancy the paper's workloads have): append <= 1% of a
  base table and time ``GraphicalJoin.refresh`` against a from-scratch
  rebuild under the same plan, for both append shapes:
    - ``reinforce`` — rows that repeat existing key pairs (event/playback
      style growth): psi structure is preserved, so the refresh is a pure
      weight re-propagation over the spliced summary;
    - ``novel``     — rows with previously-unseen pairs: the refresh
      re-expands from the first structurally-changed level down.
* ``python -m benchmarks.incremental_bench --smoke``   — CI gate: small
  instances, every refresh checked for *exact* GFJS equality against the
  rebuild (plus a service-level append -> "refreshed" round trip); FAILs
  (exit 1) on any mismatch or if the dirty-step machinery never engages.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import csv_line, timer
from repro.core.api import GraphicalJoin
from repro.relational.synth import duplicate_rows, lastfm_like


def _append_block(rng, table, kind: str, n: int):
    """A block of ``n`` rows: resampled existing rows, or novel pairs."""
    if kind == "reinforce":
        idx = rng.integers(0, table.num_rows, n)
        return {c: table[c][idx] for c in table.column_names}
    cols = {}
    for c in table.column_names:
        hi = int(table[c].max()) + 1
        cols[c] = rng.integers(0, hi + max(hi // 8, 2), n).astype(np.int64)
    return cols


def _one_case(cat, query, table: str, kind: str, frac: float, seed: int):
    """Returns (rebuild_s, refresh_s, report, join_size) for one append."""
    gj = GraphicalJoin(cat, query, record_trace=True)
    gfjs = gj.run()
    state = gj.capture_state(gfjs)
    rng = np.random.default_rng(seed)
    n = max(1, int(cat[table].num_rows * frac))
    delta = cat.append(table, _append_block(rng, cat[table], kind, n))

    state, refresh_s = timer(gj.refresh, state, delta)
    report = state.last_report

    rebuilt, rebuild_s = timer(
        lambda: GraphicalJoin(cat, query, plan=gj.plan()).run())
    if rebuilt.join_size != gj.generator.join_size:
        raise AssertionError(
            f"refresh diverged: {gj.generator.join_size} vs "
            f"{rebuilt.join_size}")
    return rebuild_s, refresh_s, report, rebuilt.join_size


def bench() -> None:
    print("name,us_per_call,derived")
    cat0, qs = lastfm_like(n_users=1200, n_artists=800, artists_per_user=15,
                           friends_per_user=6, alpha=1.2, seed=0)
    for qname in ("lastfm_A1", "lastfm_B"):
        for table in ("user_friends", "user_artists"):
            for kind in ("reinforce", "novel"):
                cat = duplicate_rows(cat0, factor=25)
                rebuild_s, refresh_s, report, join = _one_case(
                    cat, qs[qname], table, kind, frac=0.005, seed=7)
                speedup = rebuild_s / max(refresh_s, 1e-9)
                derived = (
                    f"join={join:.3g};speedup={speedup:.1f}x;"
                    f"rebuild_ms={rebuild_s * 1e3:.1f};"
                    f"dirty={report['dirty_steps']:.0f}/"
                    f"{report['total_steps']:.0f};"
                    f"spliced={report['spliced_levels']:.0f}/"
                    f"{report['total_levels']:.0f}")
                print(csv_line(
                    f"incremental/{qname}/{table}/{kind}",
                    refresh_s * 1e6, derived), flush=True)


def smoke() -> int:
    failures = []

    def check_exact(cat, query, table, kind, seed):
        gj = GraphicalJoin(cat, query, record_trace=True)
        state = gj.capture_state(gj.run())
        rng = np.random.default_rng(seed)
        n = max(1, cat[table].num_rows // 20)
        delta = cat.append(table, _append_block(rng, cat[table], kind, n))
        state = gj.refresh(state, delta)
        rebuilt = GraphicalJoin(cat, query, plan=state.plan).run()
        name = f"{query.name}/{table}/{kind}"
        if rebuilt.join_size != state.gfjs.join_size:
            failures.append(f"{name}: join size diverged")
            return
        for la, lb in zip(state.gfjs.levels, rebuilt.levels):
            if la.vars != lb.vars or not np.array_equal(la.freq, lb.freq) \
                    or any(not np.array_equal(la.key_cols[v], lb.key_cols[v])
                           for v in la.vars):
                failures.append(f"{name}: level {la.vars} diverged")
                return
        print(f"  {name}: exact  (dirty "
              f"{state.last_report['dirty_steps']:.0f}/"
              f"{state.last_report['total_steps']:.0f}, spliced "
              f"{state.last_report['spliced_levels']:.0f}/"
              f"{state.last_report['total_levels']:.0f})")

    cat0, qs = lastfm_like(n_users=120, n_artists=90, artists_per_user=5,
                           friends_per_user=3, seed=0)
    for qname in ("lastfm_A1", "lastfm_tri"):
        for kind in ("reinforce", "novel"):
            cat = duplicate_rows(cat0, factor=2)
            check_exact(cat, qs[qname], "user_friends", kind, seed=13)

    # service round trip: append -> lazy refresh -> cache upgrade
    from repro.summary.service import JoinService
    cat = duplicate_rows(cat0, factor=2)
    svc = JoinService(cat)
    q = qs["lastfm_A1"]
    svc.frame(q)
    rng = np.random.default_rng(3)
    svc.append("user_friends", {"userID": rng.integers(0, 120, 5),
                                "friendID": rng.integers(0, 120, 5)})
    reply = svc.frame(q)
    if reply.source != "refreshed":
        failures.append(f"service append did not refresh: {reply.source}")
    cold = JoinService(cat, incremental=False)
    if reply.frame.count() != cold.count(q):
        failures.append("service refresh diverged from cold compute")

    if failures:
        print("INCREMENTAL SMOKE FAILURES:")
        for f in failures:
            print(" -", f)
        return 1
    print("incremental smoke: OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI correctness gate instead of the timing sweep")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    bench()


if __name__ == "__main__":
    main()
