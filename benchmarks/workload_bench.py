"""Workload-level benchmark: elimination-message reuse across a suite.

Single queries measure one build; serving tiers run *suites* — many
queries over one catalog whose snowflake arms repeat.  This bench drives
the JOB-like overlapping suite (``benchmarks.tables.job_like_suite``)
through three passes and prices the message cache (DESIGN.md §20):

  cold   — every query built with message reuse disabled (the baseline)
  prime  — a fresh :class:`MessageCache`, first pass: hits here are pure
           *cross-query* sharing (different queries, same chain subtrees)
  warm   — second pass on the primed cache: every step's message is
           resident, so builds reduce to fingerprint + adopt

  PYTHONPATH=src python -m benchmarks.workload_bench
  PYTHONPATH=src python -m benchmarks.workload_bench --smoke   # CI gate
  PYTHONPATH=src python -m benchmarks.workload_bench --smoke \
      --trace BENCH_workload.trace.json
      # then: python -m repro.obs.check BENCH_workload.trace.json \
      #           --expect-msgcache

``--smoke`` gates on (1) warm answers exactly equal to the cache-disabled
cold builds — level-for-level when the plans agree, row-multiset always —
(2) a non-zero hit rate, and (3) warm build_generator wall at least 3x
faster than cold.  Exactness is also asserted on every non-smoke run;
speed is only *gated* under --smoke.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import Workload, csv_line, timer
from benchmarks.tables import job_like_suite
from repro.core.api import GraphicalJoin
from repro.core.gfjs import GFJS, desummarize
from repro.summary.msgcache import MessageCache

#: smoke gate from the acceptance criteria: warm suite >= 3x faster than
#: cold on build_generator wall (the phase message reuse actually skips)
SPEEDUP_GATE = 3.0


def _rows_sorted(gfjs: GFJS) -> np.ndarray:
    """The flat result as one row-sorted matrix (order-insensitive)."""
    cols = desummarize(gfjs, decode=False)
    mat = np.stack([np.asarray(cols[v]) for v in sorted(cols)], axis=0)
    return mat[:, np.lexsort(mat[::-1])]


def _same_answers(a: GFJS, b: GFJS) -> Tuple[bool, str]:
    """Exact-equality oracle: level-for-level when the summaries share a
    column order (same plan), row-multiset regardless."""
    if a.join_size != b.join_size:
        return False, f"join_size {a.join_size} != {b.join_size}"
    if tuple(a.column_order) == tuple(b.column_order):
        if len(a.levels) != len(b.levels):
            return False, "level count differs"
        for i, (la, lb) in enumerate(zip(a.levels, b.levels)):
            if tuple(la.vars) != tuple(lb.vars):
                return False, f"level {i} vars differ"
            if not np.array_equal(la.freq, lb.freq):
                return False, f"level {i} freq differs"
            if set(la.key_cols) != set(lb.key_cols):
                return False, f"level {i} key columns differ"
            for k in la.key_cols:
                if not np.array_equal(la.key_cols[k], lb.key_cols[k]):
                    return False, f"level {i} key[{k}] differs"
        return True, "levels"
    if not np.array_equal(_rows_sorted(a), _rows_sorted(b)):
        return False, "row multiset differs"
    return True, "rows"


def _run_suite(suite: List[Workload],
               cache: Optional[MessageCache]) -> Tuple[
                   List[GFJS], float, float]:
    """Build every workload; returns (summaries, build_generator wall,
    end-to-end wall)."""
    out: List[GFJS] = []
    bg = 0.0
    total = 0.0
    for w in suite:
        gj = GraphicalJoin(w.catalog, w.query, message_cache=cache)
        gfjs, t = timer(gj.run)
        out.append(gfjs)
        bg += gj.timings["build_generator"]
        total += t
    return out, bg, total


def bench_workload(scale: float = 1.0, *, skew: float = 0.0,
                   smoke: bool = False) -> Tuple[List[str], int]:
    """Returns (csv lines, exit code); exit code != 0 only under smoke."""
    _, suite = job_like_suite(scale=scale, skew=skew)
    n_q = len(suite)

    cold, cold_bg, cold_total = _run_suite(suite, None)

    mc = MessageCache()
    _, prime_bg, _ = _run_suite(suite, mc)
    prime = mc.stats.as_dict()

    warm, warm_bg, warm_total = _run_suite(suite, mc)
    after = mc.stats.as_dict()
    probes = (after["hits"] + after["disk_hits"] + after["misses"]
              - prime["hits"] - prime["disk_hits"] - prime["misses"])
    hits = (after["hits"] + after["disk_hits"]
            - prime["hits"] - prime["disk_hits"])
    hit_rate = hits / max(probes, 1)

    failures = []
    modes = set()
    for w, g_cold, g_warm in zip(suite, cold, warm):
        ok, how = _same_answers(g_cold, g_warm)
        modes.add(how)
        if not ok:
            failures.append(f"{w.name}: {how}")
    if failures:
        raise AssertionError(
            "warm builds diverged from cache-disabled cold builds: "
            + "; ".join(failures))

    speedup = cold_bg / max(warm_bg, 1e-9)
    lines = [
        csv_line(f"workload/suite{n_q}/cold", cold_bg * 1e6 / n_q,
                 f"build_generator_s={cold_bg:.3f};"
                 f"total_s={cold_total:.3f};queries={n_q};skew={skew:g}"),
        csv_line(f"workload/suite{n_q}/prime", prime_bg * 1e6 / n_q,
                 f"build_generator_s={prime_bg:.3f};"
                 f"cross_query_hits={prime['hits'] + prime['disk_hits']};"
                 f"puts={prime['puts']}"),
        csv_line(f"workload/suite{n_q}/warm", warm_bg * 1e6 / n_q,
                 f"build_generator_s={warm_bg:.3f};"
                 f"total_s={warm_total:.3f};speedup={speedup:.1f}x;"
                 f"hit_rate={hit_rate:.2f};"
                 f"exact={'+'.join(sorted(modes))};"
                 f"resident_bytes={mc.resident_bytes};"
                 f"evictions={after['evictions']}"),
    ]

    rc = 0
    if smoke:
        gates = [
            ("exactness", not failures),
            ("hit_rate>0", hit_rate > 0.0),
            (f"speedup>={SPEEDUP_GATE:g}x", speedup >= SPEEDUP_GATE),
            ("cross_query_hits>0",
             prime["hits"] + prime["disk_hits"] > 0),
        ]
        for name, ok in gates:
            print(f"workload-smoke {name}: {'OK' if ok else 'FAIL'}")
            if not ok:
                rc = 1
        print(f"workload-smoke: queries={n_q} cold_bg={cold_bg:.3f}s "
              f"warm_bg={warm_bg:.3f}s speedup={speedup:.1f}x "
              f"hit_rate={hit_rate:.2f}")
    return lines, rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="gate: warm == cold exactly, hit rate > 0, "
                         f"warm >= {SPEEDUP_GATE:g}x faster")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the csv rows as a JSON summary")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace (validate with "
                         "repro.obs.check --expect-msgcache)")
    ap.add_argument("--skew", type=float, default=0.0,
                    help="fact-FK head skew in [0, 1]")
    ap.add_argument("--scale", type=float,
                    default=float(os.environ.get("BENCH_SCALE", "1.0")))
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer
        tracer = Tracer()

    if tracer is not None:
        with tracer.span("bench:workload", cat="bench"):
            lines, rc = bench_workload(args.scale, skew=args.skew,
                                       smoke=args.smoke)
        print(f"trace,workload,{tracer.write_chrome_trace(args.trace)}")
    else:
        lines, rc = bench_workload(args.scale, skew=args.skew,
                                   smoke=args.smoke)

    print("name,us_per_call,derived")
    for line in lines:
        print(line, flush=True)
    if args.json:
        from benchmarks.kernels_bench import write_json
        write_json(lines, args.json)
    return rc


if __name__ == "__main__":
    sys.exit(main())
