"""Summary-side aggregates vs desummarize-then-aggregate.

The acceptance experiment for the summary subsystem (DESIGN.md §9): on a
high-redundancy join of >= 10^7 rows, COUNT / SUM / GROUP BY answered from
the RLE runs must beat materializing the rows first by >= 10x, and repeated
requests through the JoinService must be cache hits that skip the build
phases entirely.

Workload: the lastFM chain with the paper's ``*_dup`` redundancy knob —
duplicating base-table tuples multiplies run *frequencies* while leaving
run *counts* unchanged, which is exactly the regime (|Q| >> num_runs) the
paper credits for GJ's storage wins and this subsystem turns into compute
wins.

    PYTHONPATH=src python -m benchmarks.summary_bench [--rows 2e7]
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import csv_line, timer
from repro.core.api import GraphicalJoin
from repro.core.gfjs import desummarize
from repro.relational.synth import duplicate_rows, lastfm_like
from repro.summary.algebra import SummaryFrame
from repro.summary.service import JoinService


def build_workload(target_rows: float):
    """lastfm_A1 + tuple duplication until the join crosses target_rows."""
    cat, qs = lastfm_like(n_users=700, n_artists=600, artists_per_user=8,
                          friends_per_user=4, seed=0)
    query = qs["lastfm_A1"]
    factor = 1
    while True:
        dup = duplicate_rows(cat, factor) if factor > 1 else cat
        gj = GraphicalJoin(dup, query)
        if gj.join_size() >= target_rows or factor >= 64:
            return dup, query
        factor *= 2


def bench_summary(target_rows: float = 1e7, group_var: str = "A1",
                  sum_var: str = "A2") -> List[str]:
    out: List[str] = []
    cat, query = build_workload(target_rows)

    gj = GraphicalJoin(cat, query)
    gfjs, t_summarize = timer(gj.run)
    frame = SummaryFrame.of(gfjs)
    rows, runs = gfjs.join_size, gfjs.num_runs()
    out.append(csv_line("summary/join", t_summarize * 1e6,
                        f"rows={rows};runs={runs};x={rows / max(runs, 1):.0f}"))

    # warm the jit caches once; measurements below are steady-state
    frame.count(), frame.sum(sum_var), frame.group_by(group_var, n="count")

    # ---- desummarize-then-aggregate (the O(|Q|) baseline) -----------------
    # decode=True: the baseline answers over raw values, like the summary does
    t0 = time.perf_counter()
    flat = desummarize(gfjs, decode=True)
    t_mat = time.perf_counter() - t0
    t0 = time.perf_counter()
    base_count = len(flat[group_var])
    base_sum = int(flat[sum_var].sum())
    _, base_groups = np.unique(flat[group_var], return_counts=True)
    t_agg = time.perf_counter() - t0
    t_flat = t_mat + t_agg
    out.append(csv_line("summary/flat_path", t_flat * 1e6,
                        f"materialize={t_mat:.3f}s;aggregate={t_agg:.3f}s"))

    # ---- summary-side -----------------------------------------------------
    c, t_count = timer(frame.count)
    s, t_sum = timer(frame.sum, sum_var)
    g, t_group = timer(frame.group_by, group_var, n="count")
    assert c == base_count, (c, base_count)
    assert s == base_sum, (s, base_sum)
    assert np.array_equal(np.asarray(g["n"], np.int64), base_groups)
    t_summary = t_count + t_sum + t_group
    speedup = t_flat / max(t_summary, 1e-12)
    out.append(csv_line("summary/count", t_count * 1e6,
                        f"rows={c};speedup_vs_flat={t_flat / max(t_count, 1e-12):.0f}x"))
    out.append(csv_line("summary/sum", t_sum * 1e6, f"value={s}"))
    out.append(csv_line("summary/group_by", t_group * 1e6,
                        f"groups={len(g['n'])}"))
    out.append(csv_line("summary/all_three", t_summary * 1e6,
                        f"speedup_vs_flat={speedup:.0f}x"))
    # the acceptance gate applies at the paper-relevant scale; below it the
    # fixed dispatch overheads dominate and the ratio is uninformative
    if rows >= 1e7:
        assert speedup >= 10, (
            f"summary-side path must be >=10x faster at {rows} rows; "
            f"got {speedup:.1f}x ({t_summary:.4f}s vs {t_flat:.4f}s)")
    else:
        out.append(csv_line("summary/note", 0.0,
                            f"below acceptance scale (rows<1e7): gate skipped"))

    # ---- compute-and-reuse: cache hits skip the build phases --------------
    svc = JoinService(cat)
    _, t_cold = timer(svc.frame, query)
    reply, t_warm = timer(svc.frame, query)
    assert reply.cache_hit
    assert "build_model" not in reply.timings
    assert "build_generator" not in reply.timings
    out.append(csv_line("summary/service_cold", t_cold * 1e6, "source=computed"))
    out.append(csv_line("summary/service_warm", t_warm * 1e6,
                        f"source={reply.source};"
                        f"speedup={t_cold / max(t_warm, 1e-12):.0f}x"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=float, default=1e7,
                    help="minimum join size (default 1e7)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in bench_summary(args.rows):
        print(line, flush=True)


if __name__ == "__main__":
    main()
