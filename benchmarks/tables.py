"""Benchmark functions, one per paper table (Tables 1-6) + Figs 11-14.

Each emits ``name,us_per_call,derived`` CSV rows (benchmarks/run.py wires
them together) and caches per-workload runs so the six tables don't
recompute the same joins.
"""

from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import (MATERIALIZE_LIMIT, Workload, csv_line, timer,
                               workloads)
from repro.core.api import GraphicalJoin
from repro.core.baselines import binary_join_plan, leapfrog_join, \
    store_result_binary
from repro.core.gfjs import desummarize
from repro.core.storage import load_gfjs, save_gfjs
from repro.relational.synth import duplicate_rows, lastfm_like


@dataclass
class RunRecord:
    join_size: int = 0
    # compute-and-forget (in-memory) seconds
    gj_inmem: float = 0.0
    gj_build_model: float = 0.0
    lf_inmem: Optional[float] = None
    bp_inmem: Optional[float] = None
    # compute-and-reuse seconds + storage bytes
    gj_store: float = 0.0
    gj_bytes: int = 0
    gj_load: float = 0.0
    base_store: Optional[float] = None
    base_bytes: Optional[int] = None
    base_load: Optional[float] = None
    fail_reason: Dict[str, str] = field(default_factory=dict)


_CACHE: Dict[str, RunRecord] = {}


def run_workload(w: Workload, tmpdir: str) -> RunRecord:
    if w.name in _CACHE:
        return _CACHE[w.name]
    rec = RunRecord()

    # ---- GJ: compute-and-forget --------------------------------------------
    gj = GraphicalJoin(w.catalog, w.query)
    gfjs, t_sum = timer(gj.run)
    rec.join_size = gfjs.join_size
    can_mat = gfjs.join_size <= MATERIALIZE_LIMIT
    if can_mat:
        _, t_desum = timer(desummarize, gfjs, decode=False)
    else:
        t_desum = 0.0
        rec.fail_reason["materialize"] = f"|Q|={gfjs.join_size} > limit"
    rec.gj_inmem = t_sum + t_desum
    rec.gj_build_model = gj.timings["build_model"]

    # ---- GJ: compute-and-reuse ----------------------------------------------
    path = os.path.join(tmpdir, f"{w.name}.gfjs")
    _, rec.gj_store = timer(save_gfjs, gfjs, path)
    rec.gj_store += t_sum                      # generate + store
    rec.gj_bytes = os.path.getsize(path)
    back, t_load = timer(load_gfjs, path)
    if can_mat:
        _, t_expand = timer(desummarize, back, decode=False)
    else:
        t_expand = 0.0
    rec.gj_load = t_load + t_expand

    # ---- competitors ----------------------------------------------------------
    if can_mat:
        lf = leapfrog_join(gj.enc)
        rec.lf_inmem = lf.seconds
        bp = binary_join_plan(gj.enc)
        rec.bp_inmem = bp.seconds
        bpath = os.path.join(tmpdir, f"{w.name}.flat")
        _, t_bstore = timer(store_result_binary, lf.columns, bpath)
        rec.base_store = lf.seconds + t_bstore
        rec.base_bytes = os.path.getsize(bpath)

        def _load_flat():
            import struct

            from repro.core.storage import decompress_bytes
            with open(bpath, "rb") as f:
                raw = f.read()
            # length-prefixed column frames (see store_result_binary)
            out, off = [], 0
            while off < len(raw):
                codec, n = struct.unpack_from("<4sQ", raw, off)
                off += 12
                out.append(decompress_bytes(raw[off:off + n],
                                            codec.rstrip(b" \x00").decode()))
                off += n
            return out

        _, rec.base_load = timer(_load_flat)
    else:
        rec.fail_reason["baseline"] = "exceeds materialization limit (paper: crashed/1TB)"

    _CACHE[w.name] = rec
    return rec


def bench_table1(tmpdir: str) -> List[str]:
    """Table 1: join sizes per query."""
    out = []
    for w in workloads():
        rec = run_workload(w, tmpdir)
        out.append(csv_line(f"table1/{w.name}/join_size", 0.0,
                            f"rows={rec.join_size}"))
    return out


def bench_table2(tmpdir: str) -> List[str]:
    """Table 2: generate + store the join result on disk (GJ stores GFJS)."""
    out = []
    for w in workloads():
        rec = run_workload(w, tmpdir)
        out.append(csv_line(f"table2/{w.name}/GJ", rec.gj_store * 1e6,
                            f"seconds={rec.gj_store:.3f}"))
        if rec.base_store is not None:
            out.append(csv_line(f"table2/{w.name}/WCOJ", rec.base_store * 1e6,
                                f"seconds={rec.base_store:.3f};"
                                f"speedup={rec.base_store / max(rec.gj_store, 1e-9):.1f}x"))
        else:
            out.append(csv_line(f"table2/{w.name}/WCOJ", -1.0,
                                "FAIL:" + rec.fail_reason.get("baseline", "")))
    return out


def bench_table3(tmpdir: str) -> List[str]:
    """Table 3: load the result into memory (GJ: load summary + desummarize)."""
    out = []
    for w in workloads():
        rec = run_workload(w, tmpdir)
        out.append(csv_line(f"table3/{w.name}/GJ", rec.gj_load * 1e6,
                            f"seconds={rec.gj_load:.3f}"))
        if rec.base_load is not None:
            out.append(csv_line(f"table3/{w.name}/flat", rec.base_load * 1e6,
                                f"seconds={rec.base_load:.3f}"))
        else:
            out.append(csv_line(f"table3/{w.name}/flat", -1.0, "FAIL"))
    return out


def bench_table4(tmpdir: str) -> List[str]:
    """Table 4: storage cost in bytes."""
    out = []
    for w in workloads():
        rec = run_workload(w, tmpdir)
        out.append(csv_line(f"table4/{w.name}/GJ", 0.0,
                            f"bytes={rec.gj_bytes}"))
        if rec.base_bytes is not None:
            out.append(csv_line(
                f"table4/{w.name}/flat", 0.0,
                f"bytes={rec.base_bytes};"
                f"ratio={rec.base_bytes / max(rec.gj_bytes, 1):.0f}x"))
        else:
            out.append(csv_line(f"table4/{w.name}/flat", 0.0, "FAIL"))
    return out


def bench_table5(tmpdir: str) -> List[str]:
    """Table 5: in-memory join computation (compute-and-forget)."""
    out = []
    for w in workloads():
        rec = run_workload(w, tmpdir)
        out.append(csv_line(f"table5/{w.name}/GJ", rec.gj_inmem * 1e6,
                            f"seconds={rec.gj_inmem:.3f}"))
        if rec.lf_inmem is not None:
            d = (f"seconds={rec.lf_inmem:.3f};"
                 f"speedup={rec.lf_inmem / max(rec.gj_inmem, 1e-9):.1f}x")
            out.append(csv_line(f"table5/{w.name}/WCOJ", rec.lf_inmem * 1e6, d))
        if rec.bp_inmem is not None:
            d = (f"seconds={rec.bp_inmem:.3f};"
                 f"speedup={rec.bp_inmem / max(rec.gj_inmem, 1e-9):.1f}x")
            out.append(csv_line(f"table5/{w.name}/binary_plan",
                                rec.bp_inmem * 1e6, d))
        if rec.lf_inmem is None:
            out.append(csv_line(f"table5/{w.name}/WCOJ", -1.0, "FAIL"))
            out.append(csv_line(f"table5/{w.name}/binary_plan", -1.0, "FAIL"))
    return out


def bench_table6(tmpdir: str) -> List[str]:
    """Table 6: % of GJ in-memory time spent building the PGM (potentials)."""
    out = []
    for w in workloads():
        rec = run_workload(w, tmpdir)
        pct = 100.0 * rec.gj_build_model / max(
            rec.gj_build_model + rec.gj_inmem, 1e-9)
        out.append(csv_line(f"table6/{w.name}/pgm_build_pct",
                            rec.gj_build_model * 1e6, f"pct={pct:.1f}%"))
    return out


def bench_planner(tmpdir: str) -> List[str]:
    """Planner-chosen vs. min-fill order, side by side.

    Runs the full pipeline twice per query — once with the cost-based
    search (the default) and once pinned to lone min-fill — so the perf
    trajectory shows what the cost model buys.  ``lastfm_hot`` is the
    skew-stress case: hotter artist popularity (alpha=1.4) makes the
    min-fill-preferred artist-first elimination pay a quadratic
    pairs-sharing-an-artist product that the degree-vector cost model
    sees and sidesteps.
    """
    out = []
    cases = [(w.name, w.catalog, w.query) for w in workloads()
             if w.name in ("lastfm_cyc", "lastfm_A2", "job_D")]
    s = float(os.environ.get("BENCH_SCALE", "1.0"))
    hot_cat, hot_qs = lastfm_like(
        n_users=int(1500 * s), n_artists=int(1200 * s), artists_per_user=10,
        friends_per_user=4, alpha=1.4, seed=0)
    cases.append(("lastfm_hot", hot_cat, hot_qs["lastfm_cyc"]))

    for name, cat, query in cases:
        times: Dict[str, float] = {}
        orders: Dict[str, str] = {}
        for mode in ("cost", "min_fill"):
            gj = GraphicalJoin(cat, query, planner=mode)
            gfjs, t = timer(gj.run)
            times[mode] = t
            plan = gj.plan()
            orders[mode] = f"{plan.source}:{'|'.join(plan.order)}"
        speedup = times["min_fill"] / max(times["cost"], 1e-9)
        out.append(csv_line(
            f"planner/{name}/cost", times["cost"] * 1e6,
            f"seconds={times['cost']:.3f};{orders['cost']}"))
        out.append(csv_line(
            f"planner/{name}/min_fill", times["min_fill"] * 1e6,
            f"seconds={times['min_fill']:.3f};{orders['min_fill']};"
            f"planner_speedup={speedup:.2f}x"))
    return out


# ---------------------------------------------------------------------------
# Cyclic-pattern generators (DESIGN §19) — thin wrappers over
# repro.relational.synth.cyclic_pattern_like with the skew knob exposed.
# ---------------------------------------------------------------------------

def gen_triangle(m: int = 1500, *, hub_frac: float = 1.0, seed: int = 0):
    """Hub-skewed triangle: pairwise joins quadratic, output near-linear."""
    from repro.relational.synth import cyclic_pattern_like
    return cyclic_pattern_like("triangle", m=m, hub_frac=hub_frac, seed=seed)


def gen_clique4(m: int = 400, *, hub_frac: float = 1.0, seed: int = 0):
    """Hub-skewed 4-clique (6 edge tables over A,B,C,D)."""
    from repro.relational.synth import cyclic_pattern_like
    return cyclic_pattern_like("clique4", m=m, hub_frac=hub_frac, seed=seed)


def gen_star_cyclic(m: int = 400, *, hub_frac: float = 1.0, seed: int = 0):
    """Wheel W3: star hub over a triangle rim (star + cycle in one query)."""
    from repro.relational.synth import cyclic_pattern_like
    return cyclic_pattern_like("star_cyclic", m=m, hub_frac=hub_frac,
                               seed=seed)


def bench_cyclic(tmpdir: str) -> List[str]:
    """Hybrid GJ/WCOJ vs pure GJ across the skew knob.

    Sweeps ``hub_frac`` on each cyclic pattern: at 0.0 (uniform edges)
    hybrid and pure GJ should be within noise of each other — and the
    cost model should mostly keep pure GJ; at 1.0 (the full AGM-gap
    instance) the bag step's per-level intersection sidesteps the
    quadratic pairwise products and the model picks hybrid.  Exactness is
    asserted on every cell.
    """
    s = float(os.environ.get("BENCH_SCALE", "1.0"))
    # clique/star sizes stay modest: the pure-GJ side is quadratic through
    # the hub and exists only as the comparison baseline
    gens = [("triangle", gen_triangle, int(1500 * s)),
            ("clique4", gen_clique4, int(400 * s)),
            ("star_cyclic", gen_star_cyclic, int(400 * s))]
    out = []
    for name, gen, m in gens:
        for hub_frac in (0.0, 0.5, 1.0):
            cat, query = gen(m, hub_frac=hub_frac, seed=0)
            gj_h = GraphicalJoin(cat, query, hybrid=True)
            plan_h = gj_h.plan()
            g_h, t_h = timer(gj_h.run)
            gj_p = GraphicalJoin(cat, query, hybrid=False,
                                 elimination_order=list(plan_h.order))
            g_p, t_p = timer(gj_p.run)
            assert g_h.join_size == g_p.join_size, (name, hub_frac)
            picked = GraphicalJoin(cat, query).plan().source
            out.append(csv_line(
                f"cyclic/{name}/hub{hub_frac:g}", t_h * 1e6,
                f"pure_us={t_p * 1e6:.1f};"
                f"hybrid_speedup={t_p / max(t_h, 1e-9):.2f}x;"
                f"picked={picked};bags={len(plan_h.bags)};"
                f"join_size={g_h.join_size};m={m}"))
    return out


# ---------------------------------------------------------------------------
# JOB-like overlapping star/snowflake suite (DESIGN §20) — the workload the
# elimination-message cache is built for: many queries over one catalog
# whose snowflake arms repeat, so elimination subtrees recur across queries.
# ---------------------------------------------------------------------------

def job_like_suite(*, scale: float = 1.0, n_chains: int = 4,
                   chains_per_query: int = 2, n_facts: int = 3,
                   queries_per_fact: int = 2, skew: float = 0.0,
                   seed: int = 0):
    """A JOB-shaped suite: shared snowflake chains under several fact tables.

    One catalog holds ``n_chains`` dimension chains (``dim<c>(id, sub)`` ->
    ``sub<c>(id, val)``) and ``n_facts`` fact tables, each carrying a user
    column plus an FK into every chain.  Queries join a fact through a
    rotating window of ``chains_per_query`` chains, so consecutive queries
    overlap on chains and *different facts reuse the same chains outright*
    — the chain-side elimination messages (eliminate val, then the subkey)
    are identical across all of them, which is exactly the cross-query
    sharing the message cache monetizes.

    ``skew`` in [0, 1] mixes uniform fact FKs with a heavy head (top 2% of
    dimension keys): 0 is uniform, 1 routes every FK through the head —
    the knob stresses residency pricing on skew-inflated products.

    Returns ``(catalog, workloads)``; each workload is a
    :class:`~benchmarks.common.Workload` over the shared catalog.
    """
    from repro.relational.query import JoinQuery, QueryTable
    from repro.relational.table import Catalog, Table

    rng = np.random.default_rng(seed)
    n_dim = max(int(4000 * scale), 64)
    n_sub = max(int(64 * scale), 8)
    n_rows = max(int(30000 * scale), 512)

    cat = Catalog()
    for c in range(n_chains):
        cat.add(Table(f"dim{c}", {
            "id": np.arange(n_dim),
            "sub": rng.integers(0, n_sub, n_dim)}))
        cat.add(Table(f"sub{c}", {
            "id": np.arange(n_sub),
            "val": rng.integers(0, 16, n_sub)}))

    def fk(n: int) -> np.ndarray:
        unif = rng.integers(0, n_dim, n)
        if skew <= 0.0:
            return unif
        head = rng.integers(0, max(n_dim // 50, 1), n)
        return np.where(rng.random(n) < skew, head, unif)

    for f in range(n_facts):
        cols = {"u": rng.integers(0, 16, n_rows)}
        for c in range(n_chains):
            cols[f"d{c}"] = fk(n_rows)
        cat.add(Table(f"fact{f}", cols))

    out: List[Workload] = []
    for f in range(n_facts):
        for j in range(queries_per_fact):
            chains = [(f + j + k) % n_chains
                      for k in range(chains_per_query)]
            vmap = {"u": "U"}
            vmap.update({f"d{c}": f"D{c}" for c in chains})
            tabs = [QueryTable.of(f"fact{f}", vmap)]
            for c in chains:
                tabs.append(QueryTable.of(
                    f"dim{c}", {"id": f"D{c}", "sub": f"S{c}"}))
                tabs.append(QueryTable.of(
                    f"sub{c}", {"id": f"S{c}", "val": f"V{c}"}))
            name = f"job_f{f}q{j}"
            out.append(Workload(name, cat, JoinQuery(name, tabs,
                                                     output=["U"])))
    return cat, out


def bench_sensitivity(tmpdir: str) -> List[str]:
    """Figs 11-14: UIR (A2) and redundancy (A1_dup) sensitivity."""
    out = []
    cat, qs = lastfm_like(n_users=800, n_artists=700, artists_per_user=10,
                          friends_per_user=4, seed=3)
    cat_dup = duplicate_rows(cat, 2)
    cases = [
        ("lastfm_A1", cat, qs["lastfm_A1"]),
        ("lastfm_A1_dup", cat_dup, qs["lastfm_A1"]),
        ("lastfm_A2", cat, qs["lastfm_A2"]),
    ]
    for name, c, q in cases:
        gj = GraphicalJoin(c, q)
        gfjs, t_sum = timer(gj.run)
        can = gfjs.join_size <= MATERIALIZE_LIMIT
        t_desum = timer(desummarize, gfjs, decode=False)[1] if can else 0.0
        path = os.path.join(tmpdir, f"sens_{name}.gfjs")
        _, t_store = timer(save_gfjs, gfjs, path)
        row = (f"rows={gfjs.join_size};gfjs_bytes={os.path.getsize(path)};"
               f"inmem_s={t_sum + t_desum:.3f}")
        if can:
            lf = leapfrog_join(gj.enc)
            row += f";wcoj_s={lf.seconds:.3f}"
        out.append(csv_line(f"sensitivity/{name}/GJ",
                            (t_sum + t_desum) * 1e6, row))
    return out
