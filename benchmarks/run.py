"""Benchmark entry point: one function per paper table.

Prints ``name,us_per_call,derived`` CSV.  Set BENCH_SCALE to stretch the
workloads (default 1.0 runs the full suite in a few minutes on one core).

  PYTHONPATH=src python -m benchmarks.run [--only tableN]

The kernels section also writes ``BENCH_kernels.json`` (override with
``--kernels-json``) so the kernel-level perf trajectory is machine-readable
across PRs.
"""

from __future__ import annotations

import argparse
import sys
import tempfile


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single section (table1..table6, "
                         "sensitivity, planner, summary, kernels)")
    ap.add_argument("--kernels-json", default="BENCH_kernels.json",
                    metavar="PATH",
                    help="where to write the kernels-section JSON summary "
                         "('' disables)")
    args = ap.parse_args()

    from benchmarks import tables
    from benchmarks.kernels_bench import bench_kernels, write_json
    from benchmarks.summary_bench import bench_summary

    sections = {
        "table1": tables.bench_table1,
        "table2": tables.bench_table2,
        "table3": tables.bench_table3,
        "table4": tables.bench_table4,
        "table5": tables.bench_table5,
        "table6": tables.bench_table6,
        "sensitivity": tables.bench_sensitivity,
        "planner": tables.bench_planner,
        "summary": lambda tmp: bench_summary(),
    }

    print("name,us_per_call,derived")
    with tempfile.TemporaryDirectory() as tmp:
        for name, fn in sections.items():
            if args.only and args.only != name:
                continue
            for line in fn(tmp):
                print(line, flush=True)
        if args.only in (None, "kernels"):
            lines = bench_kernels()
            for line in lines:
                print(line, flush=True)
            if args.kernels_json:
                write_json(lines, args.kernels_json)


if __name__ == "__main__":
    main()
