"""Benchmark entry point: one function per paper table.

Prints ``name,us_per_call,derived`` CSV.  Set BENCH_SCALE to stretch the
workloads (default 1.0 runs the full suite in a few minutes on one core).

  PYTHONPATH=src python -m benchmarks.run [--only tableN]

The kernels section writes ``BENCH_kernels.json`` and the dist section
``BENCH_dist.json`` (override/disable with ``--kernels-json`` /
``--dist-json``) so the perf trajectory is machine-readable across PRs.

Sections degrade, never crash: a missing optional dependency (zstandard,
hypothesis), an absent accelerator, or a jax import problem prints a
``skip,<section>,<reason>`` line and the run continues — the entry point
must be runnable on any dev box.
"""

from __future__ import annotations

import argparse
import sys
import tempfile
import traceback


def _skip_line(name: str, exc: BaseException) -> str:
    reason = f"{type(exc).__name__}: {exc}".replace(",", ";").splitlines()[0]
    return f"skip,{name},{reason}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single section (table1..table6, "
                         "sensitivity, planner, cyclic, summary, kernels, "
                         "dist, serve, workload)")
    ap.add_argument("--kernels-json", default="BENCH_kernels.json",
                    metavar="PATH",
                    help="where to write the kernels-section JSON summary "
                         "('' disables)")
    ap.add_argument("--dist-json", default="BENCH_dist.json",
                    metavar="PATH",
                    help="where to write the dist-section JSON summary "
                         "('' disables)")
    ap.add_argument("--serve-json", default="BENCH_serve.json",
                    metavar="PATH",
                    help="where to write the serve-section JSON summary "
                         "('' disables)")
    ap.add_argument("--workload-json", default="BENCH_workload.json",
                    metavar="PATH",
                    help="where to write the workload-section JSON summary "
                         "('' disables)")
    ap.add_argument("--trace", action="store_true",
                    help="write a Chrome trace (BENCH_<section>.trace.json) "
                         "per section, viewable at ui.perfetto.dev")
    ap.add_argument("--shard-executor", default="both",
                    choices=("thread", "process", "both"),
                    help="which shard-executor rows the dist section runs")
    args = ap.parse_args()

    Tracer = None
    if args.trace:
        try:
            from repro.obs.trace import Tracer
        except ImportError as exc:
            # obs export deps absent on this box: run untraced, say so
            print(_skip_line("trace", exc), flush=True)
            Tracer = None

    from benchmarks import tables
    from benchmarks.summary_bench import bench_summary

    def kernels_section(tmp):
        from benchmarks.kernels_bench import bench_kernels, write_json
        lines = bench_kernels()
        if args.kernels_json:
            write_json(lines, args.kernels_json)
        return lines

    def dist_section(tmp):
        from benchmarks.dist_bench import bench_dist
        from benchmarks.kernels_bench import write_json
        lines = bench_dist(shard_executor=args.shard_executor)
        if args.dist_json:
            write_json(lines, args.dist_json)
        return lines

    def serve_section(tmp):
        import os
        from benchmarks.kernels_bench import write_json
        from benchmarks.serve_bench import bench_serve
        lines = bench_serve(float(os.environ.get("BENCH_SCALE", "1.0")))
        if args.serve_json:
            write_json(lines, args.serve_json)
        return lines

    def workload_section(tmp):
        import os
        from benchmarks.kernels_bench import write_json
        from benchmarks.workload_bench import bench_workload
        lines, _ = bench_workload(
            float(os.environ.get("BENCH_SCALE", "1.0")))
        if args.workload_json:
            write_json(lines, args.workload_json)
        return lines

    sections = {
        "table1": tables.bench_table1,
        "table2": tables.bench_table2,
        "table3": tables.bench_table3,
        "table4": tables.bench_table4,
        "table5": tables.bench_table5,
        "table6": tables.bench_table6,
        "sensitivity": tables.bench_sensitivity,
        "planner": tables.bench_planner,
        "cyclic": tables.bench_cyclic,
        "summary": lambda tmp: bench_summary(),
        "kernels": kernels_section,
        "dist": dist_section,
        "serve": serve_section,
        "workload": workload_section,
    }

    print("name,us_per_call,derived")
    with tempfile.TemporaryDirectory() as tmp:
        for name, fn in sections.items():
            if args.only and args.only != name:
                continue
            try:
                if Tracer is not None:
                    tracer = Tracer()
                    # the root span makes the tracer ambient for the whole
                    # section: every executor phase, elimination step,
                    # shard, kernel, and cache op lands in the file
                    with tracer.span(f"bench:{name}", cat="bench"):
                        lines = list(fn(tmp))
                    path = tracer.write_chrome_trace(
                        f"BENCH_{name}.trace.json")
                    lines.append(f"trace,{name},{path}")
                else:
                    lines = fn(tmp)
                for line in lines:
                    print(line, flush=True)
            except (ImportError, RuntimeError, OSError) as exc:
                # optional deps (zstandard/hypothesis) or accelerator
                # plumbing may be absent on a dev box: report, move on
                print(_skip_line(name, exc), flush=True)


if __name__ == "__main__":
    main()
