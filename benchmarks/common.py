"""Shared benchmark infrastructure.

The harness mirrors the paper's experimental design (Section 4) at
CPU-benchable scale: the same *structural* workloads (JOB-like many-to-many
redundancy, lastFM-like UIR chains + a cyclic query, TPCH-like FK joins),
the same two scenarios (compute-and-forget / compute-and-reuse), and the
same competitors modeled as execution strategies on identical inputs:

  GJ          — summarize (+store/load GFJS) + desummarize       [the paper]
  leapfrog    — generic WCOJ, full flat result                   [~Umbra]
  binary_plan — left-deep sorted-merge plan, full intermediates  [~PSQL/MonetDB]

A MATERIALIZE_LIMIT emulates the paper's 1TB-disk ceiling: a strategy that
would materialize more than the limit reports FAIL (the paper's '>' / '-'
entries) — GJ keeps working because it only touches the summary.
"""

from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.api import GraphicalJoin
from repro.core.baselines import (binary_join_plan, leapfrog_join,
                                  store_result_binary)
from repro.core.gfjs import desummarize
from repro.core.storage import load_gfjs, save_gfjs
from repro.relational.query import JoinQuery
from repro.relational.synth import (duplicate_rows, job_like, lastfm_like,
                                    tpch_fk_like)
from repro.relational.table import Catalog

MATERIALIZE_LIMIT = 60_000_000  # rows; the paper's disk-ceiling analog


def timer(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


@dataclass
class Workload:
    name: str
    catalog: Catalog
    query: JoinQuery


def build_workloads(scale: float = 1.0) -> List[Workload]:
    """The benchmark suite; `scale` stretches table sizes."""
    s = scale
    out: List[Workload] = []

    cat, qs = lastfm_like(n_users=int(900 * s), n_artists=int(800 * s),
                          artists_per_user=10, friends_per_user=4, seed=0)
    for name in ("lastfm_A1", "lastfm_A2", "lastfm_B", "lastfm_cyc"):
        out.append(Workload(name, cat, qs[name]))

    catj, qj = job_like(n_movies=int(1000 * s), keywords_per_movie=4,
                        companies_per_movie=2, cast_per_movie=4,
                        alpha=1.05, seed=1)
    for name in ("job_A", "job_B", "job_C", "job_D"):
        out.append(Workload(name, catj, qj[name]))

    catf, qf = tpch_fk_like(n_customers=int(15000 * s), seed=2)
    for name in ("fk_A", "fk_B"):
        out.append(Workload(name, catf, qf[name]))
    return out


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


_WORKLOADS: Optional[List[Workload]] = None


def workloads() -> List[Workload]:
    global _WORKLOADS
    if _WORKLOADS is None:
        _WORKLOADS = build_workloads(
            float(os.environ.get("BENCH_SCALE", "1.0")))
    return _WORKLOADS
