"""Order-search micro-benchmark + planner smoke check.

Two modes:

* ``python -m benchmarks.plan_bench``          — time `plan_query` on every
  benchmark workload; print chosen vs. min-fill orders and estimated costs
  (the planner must stay a sub-millisecond-per-variable affair: it runs on
  statistics, never on data).
* ``python -m benchmarks.plan_bench --smoke``  — CI gate: plan the
  quickstart (Figure 1) query + one skewed cyclic query, print `explain()`,
  and FAIL (exit 1) if the search emits an inadmissible order, a candidate
  disagrees on join size, or planning takes absurdly long.  Planner
  regressions fail fast here, before any slow benchmark runs.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import csv_line
from repro.core.api import GraphicalJoin
from repro.plan.search import plan_query
from repro.relational.encoding import encode_query
from repro.relational.synth import figure1, lastfm_like

SEARCH_BUDGET_S = 2.0      # smoke ceiling for one plan_query call


def bench_search() -> None:
    from benchmarks.common import workloads
    print("name,us_per_call,derived")
    for w in workloads():
        enc = encode_query(w.catalog, w.query)
        t0 = time.perf_counter()
        logical, phys = plan_query(enc)
        dt = time.perf_counter() - t0
        mf = next((c for c in phys.alternatives if c.source == "min_fill"),
                  None)
        derived = (f"chosen={phys.source};order={'|'.join(phys.order)};"
                   f"est={phys.est_cost:.3g}")
        if mf is not None:
            derived += f";minfill_est={mf.cost:.3g}"
        print(csv_line(f"plan_search/{w.name}", dt * 1e6, derived), flush=True)


def smoke() -> int:
    failures = []

    def check(name, catalog, query):
        enc = encode_query(catalog, query)
        t0 = time.perf_counter()
        logical, phys = plan_query(enc)
        dt = time.perf_counter() - t0
        print(f"== {name} (search {dt * 1e3:.2f}ms) ==")
        if dt > SEARCH_BUDGET_S:
            failures.append(f"{name}: search took {dt:.2f}s")
        out = set(query.output_variables)
        sizes = set()
        for cand in phys.alternatives:
            if sorted(cand.order) != sorted(query.variables):
                failures.append(f"{name}: {cand.source} order not a permutation")
            if cand.order and cand.order[-1] not in out:
                failures.append(f"{name}: {cand.source} root is projected out")
            gj = GraphicalJoin(catalog, query,
                               elimination_order=list(cand.order))
            sizes.add(gj.join_size())
        if len(sizes) > 1:
            failures.append(f"{name}: candidates disagree on join size {sizes}")
        gj = GraphicalJoin(catalog, query)
        gj.run()
        print(gj.explain())
        print()

    def check_hybrid(name, catalog, query):
        """Decomposition validity + exactness on a cyclic instance."""
        import numpy as np
        gj = GraphicalJoin(catalog, query)        # hybrid=None: model picks
        phys = gj.plan()
        print(f"== {name} (hybrid gate, chosen={phys.source}) ==")
        if phys.source != "hybrid" or not phys.bags:
            failures.append(f"{name}: cost model did not pick the hybrid "
                            f"plan on the AGM-gap instance "
                            f"(chosen={phys.source})")
            return
        seen = set()
        for bag in phys.bags:
            if sorted(bag.bind_order) != sorted(bag.vars):
                failures.append(f"{name}: bag bind_order not a permutation "
                                f"of its scope {bag.vars}")
            for i in bag.occurrences:
                if not 0 <= i < len(query.tables):
                    failures.append(f"{name}: bag occurrence {i} out of range")
                elif i in seen:
                    failures.append(f"{name}: occurrence {i} in two bags")
                elif not set(query.tables[i].variables) <= set(bag.vars):
                    failures.append(f"{name}: occurrence {i} vars "
                                    f"{query.tables[i].variables} escape "
                                    f"bag scope {bag.vars}")
                seen.add(i)
        g_h = gj.run()
        pure = GraphicalJoin(catalog, query, hybrid=False,
                             elimination_order=list(phys.order))
        g_p = pure.run()
        if pure.plan().bags:
            failures.append(f"{name}: hybrid=False plan still has bags")
        vs = sorted(query.variables)
        def rows(g, gfjs):
            res = g.desummarize(gfjs, decode=False)
            if gfjs.join_size == 0:
                return np.zeros((0, len(vs)), np.int64)
            m = np.stack([res[v] for v in vs], axis=1)
            return m[np.lexsort(m.T[::-1])]
        if g_h.join_size != g_p.join_size or \
                not np.array_equal(rows(gj, g_h), rows(pure, g_p)):
            failures.append(f"{name}: hybrid result differs from pure GJ")
        print(gj.explain())
        print()

    cat, query = figure1()
    check("quickstart/figure1", cat, query)
    if GraphicalJoin(cat, query).plan().bags:
        failures.append("figure1: acyclic plan must never carry bags")

    cat, qs = lastfm_like(n_users=300, n_artists=250, artists_per_user=8,
                          friends_per_user=4, alpha=1.4, seed=0)
    check("skewed/lastfm_cyc", cat, qs["lastfm_cyc"])

    from repro.relational.synth import cyclic_pattern_like
    cat, query = cyclic_pattern_like("triangle", m=400, domain=2000,
                                     dense=80, dense_domain=20, seed=0)
    check_hybrid("hybrid/triangle_hub", cat, query)

    if failures:
        print("PLANNER SMOKE FAILURES:")
        for f in failures:
            print(" -", f)
        return 1
    print("planner smoke: OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate instead of the full sweep")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    bench_search()


if __name__ == "__main__":
    main()
