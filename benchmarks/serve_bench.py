"""Serving front-end benchmarks (DESIGN.md §18) — the ISSUE 8 closed loop.

Measures :class:`repro.serve.server.JoinServer` under the traffic shapes a
serving tier actually sees:

* **cold stampede** — 16 threads racing one cold query.  The collapse
  invariant is the row: builds must be 1, collapsed 15, and the reported
  amplification (builds / racers) is the bugfix headline (the raw
  service ran one full GJ build per racer);
* **closed loop** — W worker threads drive skewed (Zipf) per-key probe
  traffic (``keys_per_req`` keys each) through ``server.lookup`` against
  a table a background appender keeps growing.  Reports sustained
  keys/s, request p50/p99 latency, and the collapse rate (share of
  requests answered from someone else's work: batched probes + collapsed
  builds).  The acceptance bar is >= 10k keys/s with live appends.

Run as a module (jax-free — the server fronts the numpy-side service):

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke        # CI gate
  PYTHONPATH=src python -m benchmarks.serve_bench --json BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.serve_bench --smoke --trace \
      BENCH_serve.trace.json   # then: repro.obs.check --expect-server

``--smoke`` is an exact-equality gate: every row ``server.lookup``
returns under concurrency (appends quiesced) must equal the direct
JoinService group-by oracle bit for bit, and a gated 16-thread stampede
must produce exactly one service build.
"""

from __future__ import annotations

import argparse
import os
import sys
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import csv_line


def _events_workload(n_rows: int, n_keys: int, seed: int = 0):
    from repro.relational.query import JoinQuery
    from repro.relational.table import Catalog, Table
    rng = np.random.default_rng(seed)
    t = Table("events",
              {"x0": rng.integers(0, n_keys, n_rows).astype(np.int64),
               "x1": rng.integers(0, 50, n_rows).astype(np.int64)})
    q = JoinQuery.of("events_q", [("events", {"x0": "A", "x1": "B"})])
    return Catalog.of(t), q


def _zipf_keys(rng, n: int, n_keys: int, alpha: float = 1.3) -> np.ndarray:
    return ((rng.zipf(alpha, n) - 1) % n_keys).astype(np.int64)


# ---------------------------------------------------------------------------
# rows
# ---------------------------------------------------------------------------

def _stampede_row(scale: float, tracer=None) -> str:
    """16 racers x one cold query: 1 build, 15 collapsed replies."""
    from repro.relational.synth import lastfm_like
    from repro.serve.server import JoinServer
    from repro.summary.service import JoinService

    cat, qs = lastfm_like(n_users=int(400 * scale) or 50,
                          n_artists=int(300 * scale) or 40,
                          artists_per_user=8, friends_per_user=4, seed=11)
    q = qs["lastfm_A1"]
    svc = JoinService(cat)
    plan = svc.compile(q)
    server = JoinServer(svc, tracer=tracer)

    # gate the build so every racer is provably parked on the latch
    # before it runs — the measured collapse is structural, not lucky
    entered, release = threading.Event(), threading.Event()
    orig, calls = svc.frame, []

    def gated(query, plan=None):
        calls.append(query.name)
        entered.set()
        release.wait(30.0)
        return orig(query, plan=plan)

    svc.frame = gated
    N = 16
    replies: List = [None] * N

    def racer(i):
        replies[i] = server.frame(q, plan=plan)

    t0 = time.perf_counter()
    ts = [threading.Thread(target=racer, args=(i,)) for i in range(N)]
    ts[0].start()
    entered.wait(30.0)
    for t in ts[1:]:
        t.start()
    while sum(fl.waiters for fl in server._flights._flights.values()) < N - 1:
        time.sleep(0.0005)
    release.set()
    for t in ts:
        t.join()
    wall = time.perf_counter() - t0

    sources = [r.source for r in replies]
    st = server.stats()
    return csv_line(
        "serve/cold_stampede_x16", wall * 1e6 / N,
        f"builds={len(calls)};computed={sources.count('computed')};"
        f"collapsed={st['collapsed']};racers={N};"
        f"amplification={len(calls) / N:.3f};"
        f"join_size={replies[0].frame.gfjs.join_size}")


def _closed_loop_row(scale: float, *, workers: int = 8,
                     keys_per_req: int = 16, duration: float = 3.0,
                     tracer=None) -> str:
    """Skewed probe traffic + live appends: keys/s, p50/p99, collapse."""
    from repro.serve.server import JoinServer
    from repro.summary.service import JoinService

    n_keys = int(2000 * scale) or 200
    cat, q = _events_workload(int(20000 * scale) or 2000, n_keys, seed=1)
    svc = JoinService(cat)
    plan = svc.compile(q)
    server = JoinServer(svc, tracer=tracer, batch_window=0.0)
    aggs = {"n": "count", "s": ("sum", "B")}
    server.lookup(q, "A", np.arange(4), aggs, plan=plan)   # warm the table

    stop = threading.Event()
    lat: List[List[float]] = [[] for _ in range(workers)]
    nreq = [0] * workers
    errors: List[BaseException] = []
    appends = [0]

    def worker(w: int):
        rng = np.random.default_rng(100 + w)
        try:
            while not stop.is_set():
                ks = _zipf_keys(rng, keys_per_req, n_keys)
                t0 = time.perf_counter()
                server.lookup(q, "A", ks, aggs, plan=plan)
                lat[w].append(time.perf_counter() - t0)
                nreq[w] += 1
        except BaseException as e:  # pragma: no cover - surfaced below
            errors.append(e)

    def appender():
        rng = np.random.default_rng(999)
        try:
            while not stop.is_set():
                svc.append("events",
                           {"x0": _zipf_keys(rng, 64, n_keys),
                            "x1": rng.integers(0, 50, 64).astype(np.int64)})
                appends[0] += 1
                time.sleep(0.02)
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(w,)) for w in range(workers)]
    ta = threading.Thread(target=appender)
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    ta.start()
    time.sleep(duration)
    stop.set()
    for t in ts:
        t.join()
    ta.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]

    all_lat = np.asarray([x for per in lat for x in per])
    total_req = int(sum(nreq))
    total_keys = total_req * keys_per_req
    st = server.stats()
    collapse_rate = (st["batched"] + st["collapsed"]) / max(st["requests"], 1)
    return csv_line(
        "serve/closed_loop_zipf", wall * 1e6 / max(total_req, 1),
        f"keys_per_s={total_keys / wall:.0f};requests={total_req};"
        f"workers={workers};keys_per_req={keys_per_req};"
        f"p50_ms={np.percentile(all_lat, 50) * 1e3:.3f};"
        f"p99_ms={np.percentile(all_lat, 99) * 1e3:.3f};"
        f"collapse_rate={collapse_rate:.3f};batched={st['batched']};"
        f"probes={st['probes']};table_recomputes={st['table_recomputes']};"
        f"appends={appends[0]};live_rows={svc.catalog['events'].num_rows}")


def bench_serve(scale: float = 1.0, *, duration: float = 3.0,
                tracer=None) -> List[str]:
    return [_stampede_row(scale, tracer=tracer),
            _closed_loop_row(scale, duration=duration, tracer=tracer)]


# ---------------------------------------------------------------------------
# CI smoke: server answers == direct JoinService answers, exactly
# ---------------------------------------------------------------------------

def smoke(tracer=None) -> int:
    from repro.serve.server import JoinServer
    from repro.summary.service import JoinService

    scale = float(os.environ.get("BENCH_SCALE", "1.0"))
    failures = 0

    # 1. collapse invariant (gated, deterministic)
    line = _stampede_row(scale, tracer=tracer)
    derived = dict(kv.split("=") for kv in line.split(",", 2)[2].split(";"))
    ok = (derived["builds"] == "1" and derived["computed"] == "1"
          and derived["collapsed"] == "15")
    print(f"serve-smoke stampede: builds={derived['builds']} "
          f"collapsed={derived['collapsed']} {'OK' if ok else 'MISMATCH'}")
    failures += 0 if ok else 1

    # 2. concurrent lookups + live appends, then quiesce and compare the
    # server's rows against a fresh direct-service oracle bit for bit
    n_keys = 300
    cat, q = _events_workload(4000, n_keys, seed=2)
    svc = JoinService(cat)
    plan = svc.compile(q)
    server = JoinServer(svc, tracer=tracer)
    aggs = {"n": "count", "s": ("sum", "B")}
    stop = threading.Event()
    errors: List[BaseException] = []

    def prober(w: int):
        rng = np.random.default_rng(w)
        try:
            while not stop.is_set():
                ks = _zipf_keys(rng, 8, n_keys)
                out = server.lookup(q, "A", ks, aggs, plan=plan)
                # count monotone + internally consistent shape
                if out.shape != (8, 2) or (out[:, 0] < 0).any():
                    errors.append(AssertionError("bad probe rows"))
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    def appender():
        rng = np.random.default_rng(77)
        try:
            for _ in range(10):
                svc.append("events",
                           {"x0": _zipf_keys(rng, 32, n_keys),
                            "x1": rng.integers(0, 50, 32).astype(np.int64)})
                time.sleep(0.01)
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=prober, args=(w,)) for w in range(6)]
    ta = threading.Thread(target=appender)
    for t in ts:
        t.start()
    ta.start()
    ta.join()
    time.sleep(0.05)
    stop.set()
    for t in ts:
        t.join()

    oracle = JoinService(svc.catalog, incremental=False)
    tab = oracle.frame(q, plan=plan).frame.group_by(["A"], **aggs)
    keys = np.arange(n_keys)
    got = server.lookup(q, "A", keys, aggs, plan=plan)
    want = np.zeros((n_keys, 2), np.float32)
    pos = np.asarray(tab["A"])
    want[pos, 0] = np.asarray(tab["n"], np.float32)
    want[pos, 1] = np.asarray(tab["s"], np.float32)
    eq = np.array_equal(got, want)
    st = server.stats()
    print(f"serve-smoke equality: rows={svc.catalog['events'].num_rows} "
          f"requests={st['requests']} batched={st['batched']} "
          f"probes={st['probes']} errors={len(errors)} "
          f"{'OK' if eq and not errors else 'MISMATCH'}")
    failures += 0 if eq and not errors else 1
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="exact-equality gate (server vs direct service)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the csv rows as a JSON summary")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace of the run (validate with "
                         "repro.obs.check --expect-server)")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="closed-loop seconds")
    ap.add_argument("--scale", type=float,
                    default=float(os.environ.get("BENCH_SCALE", "1.0")))
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer
        tracer = Tracer()

    if args.smoke:
        rc = smoke(tracer=tracer)
        if tracer is not None:
            print(f"trace,serve,{tracer.write_chrome_trace(args.trace)}")
        return rc

    lines = bench_serve(args.scale, duration=args.duration, tracer=tracer)
    print("name,us_per_call,derived")
    for line in lines:
        print(line, flush=True)
    if tracer is not None:
        print(f"trace,serve,{tracer.write_chrome_trace(args.trace)}")
    if args.json:
        from benchmarks.kernels_bench import write_json
        write_json(lines, args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
