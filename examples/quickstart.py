"""Quickstart: Graphical Join on the paper's own running example.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import GraphicalJoin, desummarize, row_at
from repro.relational.synth import figure1


def main() -> None:
    catalog, query = figure1()

    # the paper's pipeline: build PGM -> Algorithm 2 -> Algorithms 3/4
    gj = GraphicalJoin(catalog, query, elimination_order=["D", "C", "B", "A"])
    gfjs = gj.run()

    print(f"join size (from the root marginal, no join executed): "
          f"{gj.join_size()}")                      # 32, as in Figure 2
    print(f"GFJS columns: {gfjs.column_order}")
    for lvl in gfjs.levels:
        for v in lvl.vars:
            pairs = list(zip(gfjs.domains[v].decode(lvl.key_cols[v]),
                             lvl.freq))
            print(f"  column {v}: {pairs}")

    # desummarize: the flat join result, sorted
    flat = desummarize(gfjs)
    print("\nfirst 5 rows of the flat result:")
    for i in range(5):
        print(" ", {v: flat[v][i] for v in gfjs.column_order})

    # beyond-paper: O(log) random access without materializing anything
    print("\nrow 17 via random access:", row_at(gfjs, 17))

    # timings per phase
    print("\nphase timings:", {k: f"{v * 1e3:.2f}ms"
                               for k, v in gj.timings.items()})

    # the plan behind the run: cost-based order search over candidates
    # (min-fill included), per-step estimates, chosen backends
    planned = GraphicalJoin(catalog, query)   # no forced order: search runs
    planned.run()
    print("\n" + planned.explain())


if __name__ == "__main__":
    main()
