"""Batched serving example: prefill a prompt batch, decode with the KV cache.

Optionally augments each request with relational features pulled through the
Graphical-Join summary service under a pre-compiled physical plan
(``--features``): the steady state per request is a summary-cache hit plus
an O(runs) group-by — no joins, no re-planning.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3_8b] [--features]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models.model import LM
from repro.serve.engine import (RelationalFeatureProvider, ServeConfig,
                                ServeEngine)


def make_feature_provider() -> RelationalFeatureProvider:
    """GJ-backed per-user features (listen counts over a friend join).

    Routed through a :class:`JoinServer` front-end: concurrent request
    threads collapse onto one summary build, per-key probes batch
    against the resident group-by table, and a deadline bounds how long
    any request waits on someone else's build (DESIGN.md §18).
    """
    from repro.relational.synth import lastfm_like
    from repro.serve.server import JoinServer
    from repro.summary.service import JoinService
    cat, qs = lastfm_like(n_users=200, n_artists=150, artists_per_user=6,
                          friends_per_user=3)
    svc = JoinService(cat)
    server = JoinServer(svc, default_deadline=5.0)
    prov = RelationalFeatureProvider(
        svc, qs["lastfm_A1"], key_var="U1", aggs={"n_paths": "count"},
        server=server)
    print("serve plan:", " -> ".join(prov.plan.order),
          f"(chosen={prov.plan.source})")
    return prov


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--features", action="store_true",
                    help="attach GJ relational features to each request")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.vlm.num_image_tokens,
                             cfg.vlm.vision_dim)), jnp.float32)

    provider = make_feature_provider() if args.features else None
    engine = ServeEngine(lm, params,
                         ServeConfig(max_seq=args.prompt_len + args.max_new,
                                     temperature=0.8),
                         feature_provider=provider)
    if provider is not None:
        user_ids = rng.integers(0, 200, args.batch)
        enriched = engine.attach_features(batch, user_ids)
        print("request features:", np.asarray(enriched["features"]).ravel())
        print("join server:", provider.server.stats())

    out = engine.generate(batch, max_new=args.max_new, seed=1)
    for i, row in enumerate(out):
        print(f"request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
