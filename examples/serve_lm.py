"""Batched serving example: prefill a prompt batch, decode with the KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3_8b]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models.model import LM
from repro.serve.engine import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.vlm.num_image_tokens,
                             cfg.vlm.vision_dim)), jnp.float32)

    engine = ServeEngine(lm, params,
                         ServeConfig(max_seq=args.prompt_len + args.max_new,
                                     temperature=0.8))
    out = engine.generate(batch, max_new=args.max_new, seed=1)
    for i, row in enumerate(out):
        print(f"request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
