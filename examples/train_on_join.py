"""End-to-end driver: GJ-fed LM training (the framework's integration story).

A relational corpus is joined with GJ; each data host materializes only its
own GFJS row-range (beyond-paper random access); token batches feed a small
LM trained for a few hundred steps with checkpointing enabled.

    PYTHONPATH=src python examples/train_on_join.py [--steps 200]
"""

import argparse
import tempfile

from repro.configs import get_smoke
from repro.data.pipeline import JoinCorpus, TokenBatcher
from repro.models.model import LM
from repro.relational.synth import lastfm_like
from repro.train.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3_8b")
    args = ap.parse_args()

    # 1. the data pipeline: GJ join -> GFJS -> token stream
    cat, queries = lastfm_like(n_users=400, n_artists=300,
                               artists_per_user=6, friends_per_user=3)
    cfg = get_smoke(args.arch).scaled(num_layers=4, d_model=128, d_ff=256)
    corpus = JoinCorpus.build(cat, queries["lastfm_A1"], vocab=cfg.vocab)
    print(f"corpus: {corpus.num_rows:,} join rows "
          f"({corpus.gfjs.nbytes():,} GFJS bytes in memory)")
    batcher = TokenBatcher(corpus, batch=8, seq=64)

    # 2. the model + trainer (checkpointing + resume on by default)
    lm = LM(cfg)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            lm,
            AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
            batcher,
            TrainerConfig(steps=args.steps, checkpoint_every=50,
                          checkpoint_dir=ckpt_dir, log_every=20),
        )
        trainer.run(seed=0)

    for m in trainer.metrics_log:
        print(f"step {m['step']:>4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  |grad| {m['grad_norm']:.3f}")


if __name__ == "__main__":
    main()
