"""Compute-and-reuse as a *service*: summarize once, answer forever.

The paper's second scenario stores the GFJS so later requests skip the
join.  The summary subsystem pushes that further: later requests skip the
*rows* too — COUNT / SUM / GROUP BY are answered straight from the RLE runs
in O(num_runs), and a JoinService keeps hot summaries in an LRU cache
(spilling evictions to disk) keyed by query fingerprint + table versions.

    PYTHONPATH=src python examples/compute_and_reuse.py
"""

import os
import tempfile
import time

import numpy as np

from repro.relational.synth import duplicate_rows, lastfm_like
from repro.summary import JoinService


def main() -> None:
    cat, queries = lastfm_like(n_users=800, n_artists=700,
                               artists_per_user=10, friends_per_user=4)
    # the paper's *_dup redundancy knob: tuple duplication multiplies run
    # frequencies, not run counts — the |Q| >> num_runs regime where
    # summary-side answering shines
    cat = duplicate_rows(cat, 3)
    query = queries["lastfm_A1"]

    with tempfile.TemporaryDirectory() as tmp:
        svc = JoinService(cat, byte_budget=64 << 20,
                          spill_dir=os.path.join(tmp, "spill"))

        # ---- request 1: cold — runs the Graphical Join, caches the summary
        t0 = time.perf_counter()
        reply = svc.frame(query)
        t_cold = time.perf_counter() - t0
        frame = reply.frame
        print(f"join size            : {frame.count():,} rows "
              f"({frame.gfjs.num_runs():,} RLE runs)")
        print(f"cold request         : {t_cold:6.3f}s  source={reply.source}  "
              f"build={reply.timings.get('build_model', 0):.3f}s+"
              f"{reply.timings.get('build_generator', 0):.3f}s+"
              f"{reply.timings.get('summarize', 0):.3f}s")

        # ---- request 2: warm — same query answered from the cache
        t0 = time.perf_counter()
        reply2 = svc.frame(query)
        t_warm = time.perf_counter() - t0
        print(f"warm request         : {t_warm:6.3f}s  source={reply2.source}  "
              f"({t_cold / max(t_warm, 1e-9):,.0f}x faster, no build phases)")

        # ---- summary-side answering: aggregates without materializing ----
        frame.group_by("A1", listeners="count")   # warm the jit caches once
        t0 = time.perf_counter()
        n_pairs = frame.count()
        top = frame.group_by("A1", listeners="count")
        t_summary = time.perf_counter() - t0
        order = np.argsort(np.asarray(top["listeners"]))[::-1][:3]
        print(f"summary-side answers : {t_summary:6.3f}s for COUNT + GROUP BY "
              f"over {n_pairs:,} logical rows")
        for i in order:
            print(f"   artist {int(top['A1'][i]):>5}  "
                  f"reaches {int(top['listeners'][i]):,} friend-pairs")

        # ---- the O(|Q|) alternative the algebra avoids -------------------
        t0 = time.perf_counter()
        flat = svc.frame(query).frame  # cache hit; now pay materialization
        from repro.core.gfjs import desummarize
        cols = desummarize(flat.gfjs, decode=False)
        vals, counts = np.unique(cols["A1"], return_counts=True)
        t_flat = time.perf_counter() - t0
        print(f"desummarize+aggregate: {t_flat:6.3f}s for the same GROUP BY "
              f"({t_flat / max(t_summary, 1e-9):,.0f}x slower)")

        # ---- filters push into the runs ----------------------------------
        active = frame.filter(U1=lambda u: u < 100)
        print(f"filtered count       : {active.count():,} pairs with U1 < 100 "
              f"(predicate ran on runs, not rows)")

        print(f"service stats        : {svc.stats()}")


if __name__ == "__main__":
    main()
