"""The paper's compute-and-reuse scenario, end to end, vs the competitors.

Summarize a many-to-many join once, store the (tiny) GFJS, reload it later
and materialize — against a WCOJ baseline that must store the flat result.

    PYTHONPATH=src python examples/compute_and_reuse.py
"""

import os
import tempfile
import time

from repro.core import GraphicalJoin, desummarize, load_gfjs
from repro.core.baselines import leapfrog_join, store_result_binary
from repro.relational.synth import lastfm_like


def main() -> None:
    cat, queries = lastfm_like(n_users=800, n_artists=700,
                               artists_per_user=10, friends_per_user=4)
    query = queries["lastfm_A1"]

    with tempfile.TemporaryDirectory() as tmp:
        # ---- GJ: summarize + store ------------------------------------
        t0 = time.perf_counter()
        gj = GraphicalJoin(cat, query)
        gfjs = gj.run()
        gpath = os.path.join(tmp, "a1.gfjs")
        gbytes = gj.store(gfjs, gpath)
        t_gj = time.perf_counter() - t0

        # ---- WCOJ baseline: compute + store flat result ----------------
        t0 = time.perf_counter()
        lf = leapfrog_join(gj.enc)
        fpath = os.path.join(tmp, "a1.flat")
        fbytes = store_result_binary(lf.columns, fpath)
        t_lf = time.perf_counter() - t0

        print(f"join size           : {gfjs.join_size:,} rows")
        print(f"GJ summarize+store  : {t_gj:6.2f}s  {gbytes:>12,} bytes")
        print(f"WCOJ compute+store  : {t_lf:6.2f}s  {fbytes:>12,} bytes")
        print(f"storage ratio       : {fbytes / gbytes:.0f}x smaller with GFJS")

        # ---- later: reload + desummarize -------------------------------
        t0 = time.perf_counter()
        back = load_gfjs(gpath)
        flat = desummarize(back, decode=False)
        t_load = time.perf_counter() - t0
        print(f"GJ load+desummarize : {t_load:6.2f}s "
              f"({len(flat[back.column_order[0]]):,} rows rebuilt)")


if __name__ == "__main__":
    main()
