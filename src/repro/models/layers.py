"""Primitive layers: norms, projections, embeddings, rotary, activations.

Parameters are plain pytrees (nested dicts of jax.Arrays).  Every parameter
leaf is created through :func:`param` which attaches a *logical axis* tuple
via the parallel "specs" tree — the distribution layer
(repro/dist/sharding.py) turns logical axes into mesh PartitionSpecs, so
models never mention mesh axes directly.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Initializer = Callable[[jax.Array, Tuple[int, ...], jnp.dtype], jax.Array]


def normal_init(stddev: float = 0.02) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)
    return init


def zeros_init() -> Initializer:
    def init(key, shape, dtype):
        return jnp.zeros(shape, dtype)
    return init


def ones_init() -> Initializer:
    def init(key, shape, dtype):
        return jnp.ones(shape, dtype)
    return init


class ParamCollector:
    """Collects (init_fn, logical_axes) while a model definition runs.

    ``init(rng)`` materializes the parameter pytree; ``abstract()`` gives
    ShapeDtypeStructs (used by the dry-run: no allocation); ``specs()``
    gives the logical-axes pytree.
    """

    def __init__(self) -> None:
        self.inits: Dict[str, Tuple[Callable, Tuple[int, ...], jnp.dtype]] = {}
        self.axes: Dict[str, Tuple[Optional[str], ...]] = {}

    def declare(self, name: str, shape: Tuple[int, ...], dtype,
                axes: Tuple[Optional[str], ...], init: Initializer) -> str:
        if name in self.inits:
            raise ValueError(f"duplicate param {name}")
        assert len(axes) == len(shape), (name, shape, axes)
        self.inits[name] = (init, tuple(shape), dtype)
        self.axes[name] = tuple(axes)
        return name

    def init(self, key: jax.Array) -> Dict[str, jax.Array]:
        names = sorted(self.inits)
        keys = jax.random.split(key, max(len(names), 1))
        out = {}
        for k, name in zip(keys, names):
            fn, shape, dtype = self.inits[name]
            out[name] = fn(k, shape, dtype)
        return out

    def abstract(self) -> Dict[str, jax.ShapeDtypeStruct]:
        return {n: jax.ShapeDtypeStruct(s, d)
                for n, (_, s, d) in self.inits.items()}

    def specs(self) -> Dict[str, Tuple[Optional[str], ...]]:
        return dict(self.axes)


# ---------------------------------------------------------------------------
# functional layer ops (params passed explicitly)
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def activation_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":       # squared ReLU (nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over valid positions; logits in f32 for stability."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
