from repro.models.config import ModelConfig, smoke_variant
from repro.models.model import LM
