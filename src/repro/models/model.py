"""The LM: assembles blocks per architecture family and exposes the four
entry points the rest of the framework consumes:

* ``init(rng)`` / ``abstract_params()``   (the latter: dry-run, no alloc)
* ``forward(params, batch)``              -> logits          (train path)
* ``loss(params, batch)``                 -> scalar
* ``prefill(params, batch)``              -> (logits, caches)
* ``decode_step(params, tokens, caches)`` -> (logits, caches)

Families map to segment lists (see blocks.ScanStack for why):

  dense/moe/audio : [stack(block) x L]            (+ leading dense layers)
  gemma3          : [unit(5 local + 1 global) x U, local x tail]
  vlm             : [unit(4 self + 1 cross) x U, self x tail]
  hybrid (zamba2) : [unit(shared-attn + mamba x k) x U, mamba x tail]
  ssm (xlstm)     : [unit(mLSTM + sLSTM) x U, mLSTM x tail]
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import KVCache
from repro.models.blocks import (MLP, Mamba2Layer, ScanStack,
                                 TransformerBlock, XLSTMLayer)
from repro.models.config import ModelConfig
from repro.dist.act_sharding import constrain
from repro.models.layers import (ParamCollector, cross_entropy, normal_init,
                                 rms_norm, zeros_init)


# ---------------------------------------------------------------------------
# unit blocks (heterogeneous repeating patterns)
# ---------------------------------------------------------------------------

class GemmaUnit:
    """k sliding-window layers followed by one global-attention layer."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, k: int,
                 use_moe: bool = False) -> None:
        self.local = ScanStack(pc, "loc", k, lambda c: TransformerBlock(
            cfg, c, "b", window=cfg.sliding_window, use_moe=use_moe),
            remat=cfg.remat != "none")
        inner = ParamCollector()
        self.glob = TransformerBlock(cfg, inner, "g", window=0, use_moe=use_moe)
        for rel in sorted(inner.inits):
            fn, shape, dtype = inner.inits[rel]
            pc.declare(rel, shape, dtype, inner.axes[rel], fn)

    def forward(self, p, x, positions, **kw):
        x = self.local.forward(p, x, positions)
        return self.glob.forward(p, x, positions)

    def init_cache(self, batch, s_max):
        return (self.local.init_cache(batch, s_max),
                self.glob.init_cache(batch, s_max))

    def prefill(self, p, x, positions, cache):
        lc, gc = cache
        x, lc = self.local.prefill(p, x, positions, lc)
        x, gc = self.glob.prefill(p, x, positions, gc)
        return x, (lc, gc)

    def decode(self, p, x, cache):
        lc, gc = cache
        x, lc = self.local.decode(p, x, lc)
        x, gc = self.glob.decode(p, x, gc)
        return x, (lc, gc)


class ZambaUnit:
    """One shared attention block (params passed in, shared across units)
    followed by k Mamba2 layers."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, k: int,
                 shared_block: TransformerBlock) -> None:
        self.shared = shared_block
        self.mamba = ScanStack(pc, "mam", k, lambda c: Mamba2Layer(cfg, c, "m"),
                               remat=cfg.remat != "none")

    def forward(self, p, x, positions, *, shared_p=None, **kw):
        x = self.shared.forward(shared_p, x, positions)
        return self.mamba.forward(p, x, positions)

    def init_cache(self, batch, s_max):
        return (self.shared.init_cache(batch, s_max),
                self.mamba.init_cache(batch, s_max))

    def prefill(self, p, x, positions, cache, *, shared_p=None):
        sc, mc = cache
        x, sc = self.shared.prefill(shared_p, x, positions, sc)
        x, mc = self.mamba.prefill(p, x, positions, mc)
        return x, (sc, mc)

    def decode(self, p, x, cache, *, shared_p=None):
        sc, mc = cache
        x, sc = self.shared.decode(shared_p, x, sc)
        x, mc = self.mamba.decode(p, x, mc)
        return x, (sc, mc)


class XLSTMUnit:
    """mLSTM block + sLSTM block (xLSTM[1:1]-style alternation)."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector) -> None:
        self.m = XLSTMLayer(cfg, pc, "xm", "m")
        self.s = XLSTMLayer(cfg, pc, "xs", "s")

    def forward(self, p, x, positions, **kw):
        x = self.m.forward(p, x, positions)
        return self.s.forward(p, x, positions)

    def init_cache(self, batch, s_max):
        return (self.m.init_cache(batch, s_max), self.s.init_cache(batch, s_max))

    def prefill(self, p, x, positions, cache):
        mc, sc = cache
        x, mc = self.m.prefill(p, x, positions, mc)
        x, sc = self.s.prefill(p, x, positions, sc)
        return x, (mc, sc)

    def decode(self, p, x, cache):
        mc, sc = cache
        x, mc = self.m.decode(p, x, mc)
        x, sc = self.s.decode(p, x, sc)
        return x, (mc, sc)


class VLMUnit:
    """k self-attention layers + one image cross-attention layer."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, k: int) -> None:
        self.selfs = ScanStack(pc, "sa", k, lambda c: TransformerBlock(cfg, c, "b"),
                               remat=cfg.remat != "none")
        inner = ParamCollector()
        self.cross = TransformerBlock(cfg, inner, "x", cross=True)
        for rel in sorted(inner.inits):
            fn, shape, dtype = inner.inits[rel]
            pc.declare(rel, shape, dtype, inner.axes[rel], fn)

    def forward(self, p, x, positions, *, vision=None, **kw):
        x = self.selfs.forward(p, x, positions)
        return self.cross.forward(p, x, positions, kv_src=vision)

    def init_cache(self, batch, s_max):
        return self.selfs.init_cache(batch, s_max)

    def prefill(self, p, x, positions, cache, *, vision=None):
        x, cache = self.selfs.prefill(p, x, positions, cache)
        x = self.cross.forward(p, x, positions, kv_src=vision)
        return x, cache

    def decode(self, p, x, cache, *, vision=None):
        x, cache = self.selfs.decode(p, x, cache)
        x = self.cross.forward(
            p, x, jnp.zeros((x.shape[0], 1), jnp.int32), kv_src=vision)
        return x, cache


# ---------------------------------------------------------------------------
# the LM
# ---------------------------------------------------------------------------

class LM:
    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg
        pc = ParamCollector()
        self.pc = pc
        d = cfg.d_model
        dt = jnp.dtype(cfg.param_dtype)

        # vocab padded to a multiple of 256 so the 'vocab' axis always
        # divides the TP mesh axis (Megatron-style; padded logits are masked
        # to -inf in _head so loss/sampling semantics are unchanged).
        self.vocab_padded = -(-cfg.vocab // 256) * 256
        if cfg.family == "audio":
            pc.declare("frontend_proj", (512, d), dt, (None, "embed"),
                       normal_init(512 ** -0.5))
            pc.declare("head", (d, self.vocab_padded), dt, ("embed", "vocab"),
                       normal_init(d ** -0.5))
        else:
            pc.declare("embed", (self.vocab_padded, d), dt, ("vocab", "embed"),
                       normal_init(1.0))
            if not cfg.tie_embeddings:
                pc.declare("head", (d, self.vocab_padded), dt,
                           ("embed", "vocab"), normal_init(d ** -0.5))
        pc.declare("final_norm", (d,), dt, ("embed",), zeros_init())
        if cfg.family == "vlm":
            v = cfg.vlm.vision_dim
            pc.declare("vision_norm", (v,), dt, (None,), zeros_init())

        self.segments: List[Tuple[str, Any]] = []
        self.shared_block: Optional[TransformerBlock] = None
        self._build_segments(pc)

    # -- assembly -------------------------------------------------------------
    def _build_segments(self, pc: ParamCollector) -> None:
        cfg = self.cfg
        L = cfg.num_layers
        moe_cfg = cfg.moe

        def seg_stack(name, n, make):
            if n > 0:
                self.segments.append(
                    ("stack", ScanStack(pc, name, n, make,
                                        remat=cfg.remat != "none")))

        if cfg.family in ("dense", "audio"):
            if cfg.local_global_pattern:
                k = cfg.local_global_pattern
                units, tail = L // (k + 1), L % (k + 1)
                seg_stack("units", units, lambda c: GemmaUnit(cfg, c, k))
                seg_stack("tail", tail, lambda c: TransformerBlock(
                    cfg, c, "b", window=cfg.sliding_window))
            else:
                seg_stack("blocks", L, lambda c: TransformerBlock(cfg, c, "b"))
        elif cfg.family == "moe":
            nd = moe_cfg.first_dense_layers
            seg_stack("dense0", nd, lambda c: TransformerBlock(cfg, c, "b"))
            seg_stack("moe", L - nd, lambda c: TransformerBlock(
                cfg, c, "b", use_moe=True))
        elif cfg.family == "vlm":
            k = cfg.vlm.cross_attn_every - 1
            units, tail = L // (k + 1), L % (k + 1)
            seg_stack("units", units, lambda c: VLMUnit(cfg, c, k))
            seg_stack("tail", tail, lambda c: TransformerBlock(cfg, c, "b"))
        elif cfg.family == "hybrid":
            k = cfg.ssm.attn_every
            inner = ParamCollector()
            self.shared_block = TransformerBlock(cfg, inner, "shared")
            for rel in sorted(inner.inits):
                fn, shape, dtype = inner.inits[rel]
                pc.declare(f"shared.{rel}", shape, dtype, inner.axes[rel], fn)
            units, tail = L // k, L % k
            seg_stack("units", units,
                      lambda c: ZambaUnit(cfg, c, k, self.shared_block))
            seg_stack("tail", tail, lambda c: Mamba2Layer(cfg, c, "m"))
        elif cfg.family == "ssm":
            units, tail = L // 2, L % 2
            seg_stack("units", units, lambda c: XLSTMUnit(cfg, c))
            seg_stack("tail", tail, lambda c: XLSTMLayer(cfg, c, "xm", "m"))
        else:
            raise ValueError(cfg.family)

    # -- params ----------------------------------------------------------------
    def init(self, key: jax.Array) -> Dict[str, jax.Array]:
        return self.pc.init(key)

    def abstract_params(self) -> Dict[str, jax.ShapeDtypeStruct]:
        return self.pc.abstract()

    def logical_axes(self) -> Dict[str, Tuple[Optional[str], ...]]:
        return self.pc.specs()

    # -- shared plumbing ---------------------------------------------------------
    def _embed(self, p, batch) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        if cfg.family == "audio":
            x = batch["frames"].astype(cdt) @ p["frontend_proj"].astype(cdt)
        else:
            x = p["embed"].astype(cdt)[batch["tokens"]]
            x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return x, positions

    def _seg_kw(self, p, batch) -> Dict[str, Any]:
        cfg = self.cfg
        kw: Dict[str, Any] = {}
        if cfg.family == "hybrid":
            pre = "shared."
            kw["shared_p"] = {k[len(pre):]: v for k, v in p.items()
                              if k.startswith(pre)}
        if cfg.family == "vlm":
            v = batch["vision"].astype(jnp.dtype(cfg.compute_dtype))
            v = rms_norm(v, p["vision_norm"], cfg.norm_eps)
            kw["vision"] = v
        return kw

    def _head(self, p, x) -> jax.Array:
        cfg = self.cfg
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        w = p["embed"].T if (cfg.tie_embeddings and cfg.family != "audio") \
            else p["head"]
        logits = (x @ w.astype(x.dtype)).astype(jnp.dtype(cfg.logits_dtype))
        if self.vocab_padded != cfg.vocab:
            pad_mask = jnp.arange(self.vocab_padded) < cfg.vocab
            logits = jnp.where(pad_mask, logits, -1e30)
        return logits

    # -- entry points -------------------------------------------------------------
    def forward(self, p, batch) -> jax.Array:
        x, positions = self._embed(p, batch)
        x = constrain(x)
        kw = self._seg_kw(p, batch)
        for _, seg in self.segments:
            x = constrain(seg.forward(p, x, positions, **kw))
        return self._head(p, x)

    def loss(self, p, batch) -> jax.Array:
        logits = self.forward(p, batch)
        mask = batch.get("mask")
        return cross_entropy(logits, batch["labels"], mask)

    # -- serving -----------------------------------------------------------------
    def init_caches(self, batch: int, s_max: int):
        return [seg.init_cache(batch, s_max) for _, seg in self.segments]

    def prefill(self, p, batch, s_max: int):
        x, positions = self._embed(p, batch)
        x = constrain(x)
        kw = self._seg_kw(p, batch)
        caches = self.init_caches(x.shape[0], s_max)
        new_caches = []
        for (_, seg), cache in zip(self.segments, caches):
            x, c = seg.prefill(p, x, positions, cache, **kw)
            x = constrain(x)
            new_caches.append(c)
        return self._head(p, x[:, -1:]), new_caches

    def decode_step(self, p, tokens, caches, *, vision=None):
        """tokens: [B, 1] -> (logits [B, 1, V], new caches).

        ``vision``: pre-normed image context for the vlm family (threaded by
        serve/engine.py; cross-attention K/V could also be cached — a noted
        serving optimization)."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        x = p["embed"].astype(cdt)[tokens] * jnp.asarray(cfg.d_model ** 0.5, cdt)
        kw = self._seg_kw_decode(p, vision)
        new_caches = []
        for (_, seg), cache in zip(self.segments, caches):
            x, c = seg.decode(p, x, cache, **kw)
            new_caches.append(c)
        return self._head(p, x), new_caches

    def _seg_kw_decode(self, p, vision=None) -> Dict[str, Any]:
        cfg = self.cfg
        kw: Dict[str, Any] = {}
        if cfg.family == "hybrid":
            pre = "shared."
            kw["shared_p"] = {k[len(pre):]: v for k, v in p.items()
                              if k.startswith(pre)}
        if cfg.family == "vlm":
            if vision is None:
                raise ValueError("vlm decode requires the vision context")
            v = vision.astype(jnp.dtype(cfg.compute_dtype))
            kw["vision"] = rms_norm(v, p["vision_norm"], cfg.norm_eps)
        return kw
