"""Layer blocks and the ScanStack mechanism.

Every architecture is assembled from *blocks* (attention+FFN, MoE, Mamba2,
mLSTM/sLSTM, cross-attention) grouped into *ScanStacks*: n structurally
identical layers whose parameters are stacked on a leading axis and applied
with ``jax.lax.scan``.  Heterogeneous patterns (gemma3 5:1 local:global,
zamba2 shared-attention every 6 Mamba layers, vlm cross-attention every 5,
xLSTM alternating m/sLSTM) become *unit blocks* — a unit contains its own
inner stacks — and the unit itself is scan-stacked.  This keeps the HLO one
block-body per group regardless of depth (compile times stay sane at 60+
layers under 512-way SPMD) and is also what makes remat-per-block cheap.

Blocks declare parameters with *relative* names into a private collector;
ScanStack re-declares them stacked into the parent collector.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import GQAttention, KVCache, MLAttention
from repro.models.config import ModelConfig
from repro.models.layers import (ParamCollector, activation_fn, normal_init,
                                 rms_norm, zeros_init)
from repro.models.moe import MoEBlock
from repro.models.ssm import Mamba2Block, SSMState
from repro.models.xlstm import MLSTMBlock, SLSTMBlock


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

class MLP:
    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str,
                 d_ff: Optional[int] = None) -> None:
        self.cfg = cfg
        self.prefix = prefix
        d = cfg.d_model
        ff = d_ff or cfg.d_ff
        dt = jnp.dtype(cfg.param_dtype)
        init = normal_init(d ** -0.5)
        if cfg.gated_mlp:
            pc.declare(f"{prefix}.w_gate", (d, ff), dt, ("embed", "ff"), init)
        pc.declare(f"{prefix}.w_up", (d, ff), dt, ("embed", "ff"), init)
        pc.declare(f"{prefix}.w_down", (ff, d), dt, ("ff", "embed"),
                   normal_init(ff ** -0.5))

    def __call__(self, p, x):
        cfg, pre = self.cfg, self.prefix
        act = activation_fn(cfg.activation)
        u = x @ p[f"{pre}.w_up"].astype(x.dtype)
        if cfg.gated_mlp:
            g = act(x @ p[f"{pre}.w_gate"].astype(x.dtype))
            h = g * u
        else:
            h = act(u)
        return h @ p[f"{pre}.w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# standard pre-norm transformer block (attention + MLP/MoE)
# ---------------------------------------------------------------------------

class TransformerBlock:
    """Pre-norm block.  Variants: GQA/MLA attention, window, MoE FFN."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str = "b",
                 *, window: int = 0, use_moe: bool = False,
                 cross: bool = False) -> None:
        self.cfg = cfg
        self.prefix = prefix
        self.window = window
        self.cross = cross
        dt = jnp.dtype(cfg.param_dtype)
        pc.declare(f"{prefix}.ln1", (cfg.d_model,), dt, ("embed",), zeros_init())
        pc.declare(f"{prefix}.ln2", (cfg.d_model,), dt, ("embed",), zeros_init())
        if cfg.mla is not None and not cross:
            self.attn: Any = MLAttention(cfg, pc, f"{prefix}.attn")
        else:
            kv_dim = cfg.vlm.vision_dim if (cross and cfg.vlm) else None
            self.attn = GQAttention(cfg, pc, f"{prefix}.attn", cross=cross,
                                    kv_dim=kv_dim)
        if use_moe:
            self.ffn: Any = MoEBlock(cfg, pc, f"{prefix}.moe")
        else:
            self.ffn = MLP(cfg, pc, f"{prefix}.mlp")

    def _ffn(self, p, h):
        return self.ffn(p, h)

    def forward(self, p, x, positions, *, kv_src=None, **kw):
        cfg, pre = self.cfg, self.prefix
        h = rms_norm(x, p[f"{pre}.ln1"], cfg.norm_eps)
        a = self.attn.forward(p, h, positions, window=self.window,
                              kv_src=kv_src)
        x = x + a
        h = rms_norm(x, p[f"{pre}.ln2"], cfg.norm_eps)
        return x + self._ffn(p, h)

    def init_cache(self, batch: int, s_max: int) -> KVCache:
        return self.attn.init_cache(batch, s_max)

    def prefill(self, p, x, positions, cache: KVCache, **kw):
        cfg, pre = self.cfg, self.prefix
        h = rms_norm(x, p[f"{pre}.ln1"], cfg.norm_eps)
        a, cache = self.attn.prefill(p, h, positions, cache, window=self.window)
        x = x + a
        h = rms_norm(x, p[f"{pre}.ln2"], cfg.norm_eps)
        return x + self._ffn(p, h), cache

    def decode(self, p, x, cache: KVCache, **kw):
        cfg, pre = self.cfg, self.prefix
        h = rms_norm(x, p[f"{pre}.ln1"], cfg.norm_eps)
        a, cache = self.attn.decode(p, h, cache, window=self.window)
        x = x + a
        h = rms_norm(x, p[f"{pre}.ln2"], cfg.norm_eps)
        return x + self._ffn(p, h), cache


class Mamba2Layer:
    """Pre-norm Mamba2 block (the zamba2 backbone layer)."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str = "m"):
        self.cfg = cfg
        self.prefix = prefix
        dt = jnp.dtype(cfg.param_dtype)
        pc.declare(f"{prefix}.ln", (cfg.d_model,), dt, ("embed",), zeros_init())
        self.ssm = Mamba2Block(cfg, pc, f"{prefix}.ssm")

    def forward(self, p, x, positions=None, **kw):
        h = rms_norm(x, p[f"{self.prefix}.ln"], self.cfg.norm_eps)
        return x + self.ssm.forward(p, h)

    def init_cache(self, batch: int, s_max: int) -> SSMState:
        return self.ssm.init_state(batch)

    def prefill(self, p, x, positions, cache: SSMState, **kw):
        h = rms_norm(x, p[f"{self.prefix}.ln"], self.cfg.norm_eps)
        y, state = self.ssm.forward(p, h, return_state=True)
        return x + y, state

    def decode(self, p, x, cache: SSMState, **kw):
        h = rms_norm(x, p[f"{self.prefix}.ln"], self.cfg.norm_eps)
        y, state = self.ssm.decode(p, h, cache)
        return x + y, state


class XLSTMLayer:
    """Pre-norm wrapper around an mLSTM or sLSTM block."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str,
                 kind: str) -> None:
        self.cfg = cfg
        self.prefix = prefix
        self.kind = kind
        dt = jnp.dtype(cfg.param_dtype)
        pc.declare(f"{prefix}.ln", (cfg.d_model,), dt, ("embed",), zeros_init())
        self.cell = (MLSTMBlock if kind == "m" else SLSTMBlock)(
            cfg, pc, f"{prefix}.cell")

    def forward(self, p, x, positions=None, **kw):
        h = rms_norm(x, p[f"{self.prefix}.ln"], self.cfg.norm_eps)
        return x + self.cell.forward(p, h)

    def init_cache(self, batch: int, s_max: int):
        return self.cell.init_state(batch)

    def prefill(self, p, x, positions, cache, **kw):
        # recurrent families prefill by running forward then re-deriving the
        # state with a decode pass over the last token only is NOT exact; we
        # run the scan-based exact path: forward with state return.
        h = rms_norm(x, p[f"{self.prefix}.ln"], self.cfg.norm_eps)
        if self.kind == "m":
            y, state = self.cell.forward(p, h, return_state=True)
            return x + y, state
        else:
            xg = h[:, :].astype(jnp.float32) @ p[f"{self.prefix}.cell.wx"]

            def step(state, xt):
                hh, state = self.cell._cell(p, xt, state)
                return state, hh
            state, hs = jax.lax.scan(step, self.cell.init_state(x.shape[0]),
                                     xg.transpose(1, 0, 2))
            hseq = hs.transpose(1, 0, 2).astype(x.dtype)
            hseq = rms_norm(hseq, p[f"{self.prefix}.cell.norm"], self.cfg.norm_eps)
            u, g = jnp.split(hseq @ p[f"{self.prefix}.cell.up"].astype(x.dtype), 2, -1)
            out = (jax.nn.gelu(u) * g) @ p[f"{self.prefix}.cell.down"].astype(x.dtype)
            return x + out, state

    def decode(self, p, x, cache, **kw):
        h = rms_norm(x, p[f"{self.prefix}.ln"], self.cfg.norm_eps)
        y, state = self.cell.decode(p, h, cache)
        return x + y, state


# ---------------------------------------------------------------------------
# ScanStack
# ---------------------------------------------------------------------------

class ScanStack:
    """n structurally identical blocks, parameters stacked, applied via scan.

    ``make_block(pc) -> block`` builds one layer against a private collector;
    the stack re-declares every param with a leading (n,) axis named
    'layers'.  ``forward/prefill/decode`` run lax.scan over the stack, with
    optional per-layer remat.
    """

    def __init__(self, pc: ParamCollector, prefix: str, n: int,
                 make_block: Callable[[ParamCollector], Any],
                 *, remat: bool = True) -> None:
        self.prefix = prefix
        self.n = n
        self.remat = remat
        inner = ParamCollector()
        self.block = make_block(inner)
        self.rel_names = sorted(inner.inits)
        for rel in self.rel_names:
            fn, shape, dtype = inner.inits[rel]
            axes = inner.axes[rel]

            def stacked_init(key, s, d, fn=fn, base_shape=shape):
                keys = jax.random.split(key, s[0])
                return jax.vmap(lambda k: fn(k, base_shape, d))(keys)

            pc.declare(f"{prefix}.{rel}", (n,) + shape, dtype,
                       ("layers",) + axes, stacked_init)

    def sub(self, p: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Extract this stack's stacked params as a relative dict."""
        pre = self.prefix + "."
        return {k[len(pre):]: v for k, v in p.items() if k.startswith(pre)}

    def _wrap(self, fn):
        return jax.checkpoint(fn) if self.remat else fn

    def forward(self, p, x, positions, **kw):
        sub = self.sub(p)

        def body(carry, layer_p):
            fn = self._wrap(lambda c, lp: self.block.forward(lp, c, positions, **kw))
            return fn(carry, layer_p), None

        out, _ = jax.lax.scan(body, x, sub)
        return out

    def init_cache(self, batch: int, s_max: int):
        one = self.block.init_cache(batch, s_max)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (self.n,) + a.shape).copy()
                            if hasattr(a, "shape") else a, one)

    def prefill(self, p, x, positions, cache, **kw):
        sub = self.sub(p)

        def body(carry, xs):
            layer_p, layer_cache = xs
            out, new_cache = self.block.prefill(layer_p, carry, positions,
                                                layer_cache, **kw)
            return out, new_cache

        out, new_cache = jax.lax.scan(body, x, (sub, cache))
        return out, new_cache

    def decode(self, p, x, cache, **kw):
        sub = self.sub(p)

        def body(carry, xs):
            layer_p, layer_cache = xs
            out, new_cache = self.block.decode(layer_p, carry, layer_cache, **kw)
            return out, new_cache

        out, new_cache = jax.lax.scan(body, x, (sub, cache))
        return out, new_cache
