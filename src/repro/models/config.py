"""Model configuration covering every assigned architecture family.

One dataclass drives dense, MoE, MLA, hybrid-SSM, xLSTM, encoder-only and
VLM assemblies.  The exact per-architecture instances live in
``repro/configs/<id>.py`` (full scale) with reduced smoke variants.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0
    shared_experts: int = 0
    d_ff_expert: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    first_dense_layers: int = 0     # leading layers use dense FFN (deepseek)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention compression dims."""
    q_lora_rank: int = 0            # 0 => full-rank q projection
    kv_lora_rank: int = 512
    rope_head_dim: int = 64         # decoupled rope dims per head
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64             # N (per the assignment: ssm_state=64)
    conv_width: int = 4
    expand: int = 2                 # inner dim = expand * d_model
    num_heads: int = 0              # 0 => inner_dim // head_dim
    head_dim: int = 64
    chunk: int = 256                # SSD chunk length
    attn_every: int = 6             # hybrid: shared attention block period
    shared_attn: bool = True        # zamba2: the attention block is shared


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 2            # one sLSTM block every N blocks
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3333
    mlstm_head_dim: int = 256


@dataclass(frozen=True)
class VLMConfig:
    cross_attn_every: int = 5       # cross-attention layer period
    vision_dim: int = 1280          # stub frontend embedding width
    num_image_tokens: int = 1601    # tokens per image tile (stub)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // num_heads
    max_seq: int = 8192

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # 0 => global attention
    local_global_pattern: int = 0  # k => k local layers then 1 global (gemma3)
    attn_logit_softcap: float = 0.0
    causal: bool = True            # False => encoder-only (hubert)

    # mlp
    activation: str = "silu"       # silu | gelu | relu2
    gated_mlp: bool = True         # gated (SwiGLU-style) vs plain 2-matrix

    # norms
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    vlm: Optional[VLMConfig] = None

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logits_dtype: str = "float32"

    # execution
    remat: str = "block"           # none | block | full
    scan_layers: bool = True

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6*N*D accounting."""
        d, L = self.d_model, self.num_layers
        hd = self.head_dim_
        n = self.vocab * d  # embed
        if not self.tie_embeddings:
            n += self.vocab * d
        for i in range(L):
            n += self._block_params(i)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed-in experts)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d
        for i in range(L):
            n += self._block_params(i, active_only=True)
        return n

    # -- internals ---------------------------------------------------------
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim_
        if self.mla is not None:
            m = self.mla
            qdim = self.num_heads * (m.nope_head_dim + m.rope_head_dim)
            n = (d * m.q_lora_rank + m.q_lora_rank * qdim) if m.q_lora_rank \
                else d * qdim
            n += d * (m.kv_lora_rank + m.rope_head_dim)
            n += m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
            n += self.num_heads * m.v_head_dim * d
            return n
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _ffn_params(self, dff: int) -> int:
        mult = 3 if self.gated_mlp else 2
        return mult * self.d_model * dff

    def _block_params(self, i: int, active_only: bool = False) -> int:
        d = self.d_model
        if self.family == "ssm" and self.xlstm is not None:
            inner = int(d * self.xlstm.mlstm_proj_factor)
            return 2 * d * inner + 3 * inner * inner // 4 + inner * d  # approx
        if self.family == "hybrid" and self.ssm is not None:
            s = self.ssm
            inner = s.expand * d
            n = d * 2 * inner + inner * d + inner * (2 * s.state_dim)
            if (i % s.attn_every) == 0 and not (s.shared_attn and i > 0):
                n += self._attn_params() + self._ffn_params(self.d_ff)
            return n
        n = self._attn_params()
        if self.moe is not None and i >= self.moe.first_dense_layers:
            m = self.moe
            per_expert = self._ffn_params(m.d_ff_expert)
            if active_only:
                n += (m.experts_per_token + m.shared_experts) * per_expert
            else:
                n += (m.num_experts + m.shared_experts) * per_expert
            n += d * m.num_experts  # router
        else:
            n += self._ffn_params(self.d_ff)
        n += 2 * d  # norms
        return n

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        num_layers=max(2, min(4, cfg.num_layers)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads)),
        d_ff=128,
        vocab=256,
        head_dim=16,
        max_seq=64,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4,
            experts_per_token=min(2, cfg.moe.experts_per_token),
            shared_experts=min(1, cfg.moe.shared_experts),
            d_ff_expert=64,
            first_dense_layers=min(1, cfg.moe.first_dense_layers))
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, q_lora_rank=32 if cfg.mla.q_lora_rank else 0,
            kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16,
                                        chunk=16, attn_every=2)
    if cfg.xlstm is not None:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, mlstm_head_dim=32)
    if cfg.vlm is not None:
        kw["vlm"] = dataclasses.replace(cfg.vlm, cross_attn_every=2,
                                        vision_dim=64, num_image_tokens=16)
    if cfg.local_global_pattern:
        kw["local_global_pattern"] = 2
        kw["sliding_window"] = 16
    elif cfg.sliding_window:
        kw["sliding_window"] = 16
    return cfg.scaled(name=cfg.name + "-smoke", **kw)
