"""xLSTM blocks: mLSTM (matrix memory, parallel form) and sLSTM (scalar
memory, recurrent scan), per Beck et al. 2024 (arXiv:2405.04517).

mLSTM trains with the stabilized parallel formulation (attention-like
[S, S] matmuls with log-sigmoid cumulative forget-gate decay) and decodes
with the O(1) recurrent matrix state C [B, H, dk, dv].  sLSTM is inherently
recurrent (its recurrent gate connections break the parallel form), so both
train and decode run a lax.scan over time with per-head block-diagonal
recurrence — faithful to the paper, and the reason xLSTM long-context decode
is O(1) in sequence length (long_500k runs for this family).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (ParamCollector, normal_init, rms_norm,
                                 zeros_init)


class MLSTMState(NamedTuple):
    c: jax.Array   # [B, H, dk, dv]
    n: jax.Array   # [B, H, dk]
    m: jax.Array   # [B, H]


class SLSTMState(NamedTuple):
    c: jax.Array   # [B, inner]
    n: jax.Array   # [B, inner]
    h: jax.Array   # [B, inner]
    m: jax.Array   # [B, inner]


class MLSTMBlock:
    """Up-proj (pf=2) -> mLSTM cell -> gated skip -> down-proj."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str) -> None:
        assert cfg.xlstm is not None
        self.cfg = cfg
        self.prefix = prefix
        x = cfg.xlstm
        d = cfg.d_model
        inner = int(d * x.mlstm_proj_factor)
        self.inner = inner
        self.dk = x.mlstm_head_dim
        self.heads = max(inner // self.dk, 1)
        self.dv = inner // self.heads
        dt = jnp.dtype(cfg.param_dtype)
        init = normal_init(d ** -0.5)
        pc.declare(f"{prefix}.up", (d, 2 * inner), dt, ("embed", "ff"), init)
        pc.declare(f"{prefix}.wq", (inner, self.heads, self.dk), dt,
                   ("ff", "heads", "head"), init)
        pc.declare(f"{prefix}.wk", (inner, self.heads, self.dk), dt,
                   ("ff", "heads", "head"), init)
        pc.declare(f"{prefix}.wv", (inner, self.heads, self.dv), dt,
                   ("ff", "heads", "head"), init)
        pc.declare(f"{prefix}.wif", (inner, 2 * self.heads), jnp.float32,
                   ("ff", None), init)
        pc.declare(f"{prefix}.norm", (inner,), dt, ("ff",), zeros_init())
        pc.declare(f"{prefix}.down", (inner, d), dt, ("ff", "embed"),
                   normal_init(inner ** -0.5))

    def _proj(self, p, x):
        up = x @ p[f"{self.prefix}.up"].astype(x.dtype)
        u, z = jnp.split(up, 2, axis=-1)
        q = jnp.einsum("bsi,ihk->bshk", u, p[f"{self.prefix}.wq"].astype(x.dtype))
        k = jnp.einsum("bsi,ihk->bshk", u, p[f"{self.prefix}.wk"].astype(x.dtype))
        v = jnp.einsum("bsi,ihk->bshk", u, p[f"{self.prefix}.wv"].astype(x.dtype))
        gates = u.astype(jnp.float32) @ p[f"{self.prefix}.wif"]
        i_raw, f_raw = jnp.split(gates, 2, axis=-1)       # [B,S,H]
        return u, z, q, k, v, i_raw, f_raw

    def forward(self, p, x, *, return_state: bool = False, chunk: int = 256):
        """Chunkwise-parallel mLSTM (the memory-bounded form).

        The naive parallel form materializes [B, S, S, H] — terabytes at
        32k — so, like Mamba2's SSD, we run intra-chunk attention-with-decay
        matmuls plus an inter-chunk recurrence over the stabilized matrix
        state (C, n, m).  Exactly equal to the recurrent cell (tests)."""
        B, S, _ = x.shape
        H, dk, dv = self.heads, self.dk, self.dv
        Q = min(chunk, S)
        assert S % Q == 0, (S, Q)
        nc = S // Q
        u, z, q, k, v, i_raw, f_raw = self._proj(p, x)
        logf = jax.nn.log_sigmoid(f_raw)                  # [B,S,H] f32
        qs = (q.astype(jnp.float32) * dk ** -0.5).reshape(B, nc, Q, H, dk)
        ks = k.astype(jnp.float32).reshape(B, nc, Q, H, dk)
        vs = v.astype(jnp.float32).reshape(B, nc, Q, H, dv)
        ic = i_raw.reshape(B, nc, Q, H)
        Fc = jnp.cumsum(logf.reshape(B, nc, Q, H), axis=2)  # incl. cumsum
        Ftot = Fc[:, :, -1, :]                              # [B,nc,H]

        # intra-chunk decay matrix rel[i,j] = F_i - F_j + itilde_j (j <= i)
        rel = Fc[:, :, :, None, :] - Fc[:, :, None, :, :] + ic[:, :, None, :, :]
        iq = jnp.arange(Q)
        causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
        rel = jnp.where(causal, rel, -jnp.inf)              # [B,nc,Q,Q,H]
        m_intra = jnp.maximum(jnp.max(rel, axis=3), -1e30)  # [B,nc,Q,H]

        # per-chunk state summaries (for the recurrence)
        g_tail = Ftot[:, :, None, :] - Fc + ic              # [B,nc,Q,H]
        m_state = jnp.max(g_tail, axis=2)                   # [B,nc,H]

        def chunk_step(carry, inp):
            C, n, m_prev = carry                            # [B,H,dk,dv] ...
            qb, kb, vb, relb, m_in, Fb, Ftb, gtb, msb = inp
            # combined stabilizer per position
            m_i = jnp.maximum(m_in, Fb + m_prev[:, None])   # [B,Q,H]
            w_intra = jnp.exp(relb - m_i[:, :, None, :])    # [B,Q,Q,H]
            sc = jnp.einsum("bqhk,bshk->bqsh", qb, kb) * w_intra
            num = jnp.einsum("bqsh,bshv->bqhv", sc, vb)
            den = sc.sum(2)                                 # [B,Q,H]
            w_inter = jnp.exp(Fb + m_prev[:, None] - m_i)   # [B,Q,H]
            num = num + w_inter[..., None] * jnp.einsum(
                "bqhk,bhkv->bqhv", qb, C)
            den = den + w_inter * jnp.einsum("bqhk,bhk->bqh", qb, n)
            den = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
            h = num / den[..., None]                        # [B,Q,H,dv]
            # state update to chunk end
            m_next = jnp.maximum(Ftb + m_prev, msb)         # [B,H]
            wk = jnp.exp(gtb - m_next[:, None])             # [B,Q,H]
            C_new = (jnp.exp(Ftb + m_prev - m_next)[:, :, None, None] * C +
                     jnp.einsum("bqh,bqhk,bqhv->bhkv", wk, kb, vb))
            n_new = (jnp.exp(Ftb + m_prev - m_next)[:, :, None] * n +
                     jnp.einsum("bqh,bqhk->bhk", wk, kb))
            return (C_new, n_new, m_next), h

        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
        xs = (qs.transpose(1, 0, 2, 3, 4), ks.transpose(1, 0, 2, 3, 4),
              vs.transpose(1, 0, 2, 3, 4), rel.transpose(1, 0, 2, 3, 4),
              m_intra.transpose(1, 0, 2, 3), Fc.transpose(1, 0, 2, 3),
              Ftot.transpose(1, 0, 2), g_tail.transpose(1, 0, 2, 3),
              m_state.transpose(1, 0, 2))
        (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
        h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, self.inner).astype(x.dtype)
        h = rms_norm(h, p[f"{self.prefix}.norm"], self.cfg.norm_eps)
        h = h * jax.nn.silu(z)
        out = h @ p[f"{self.prefix}.down"].astype(x.dtype)
        if return_state:
            return out, MLSTMState(C, n, m)
        return out

    def init_state(self, batch: int) -> MLSTMState:
        return MLSTMState(
            jnp.zeros((batch, self.heads, self.dk, self.dv), jnp.float32),
            jnp.zeros((batch, self.heads, self.dk), jnp.float32),
            jnp.full((batch, self.heads), -1e30, jnp.float32))

    def decode(self, p, x, state: MLSTMState):
        B = x.shape[0]
        u, z, q, k, v, i_raw, f_raw = self._proj(p, x)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]               # [B,H,dk/dv]
        i_t = i_raw[:, 0]
        logf = jax.nn.log_sigmoid(f_raw[:, 0])            # [B,H]
        m_new = jnp.maximum(logf + state.m, i_t)
        a = jnp.exp(logf + state.m - m_new)
        b = jnp.exp(i_t - m_new)
        c = (state.c * a[..., None, None] +
             b[..., None, None] * jnp.einsum("bhk,bhv->bhkv",
                                             k.astype(jnp.float32),
                                             v.astype(jnp.float32)))
        n = state.n * a[..., None] + b[..., None] * k.astype(jnp.float32)
        # q is pre-scaled by dk^-1/2 so num/den match the parallel form
        qs = q.astype(jnp.float32) * (self.dk ** -0.5)
        num = jnp.einsum("bhk,bhkv->bhv", qs, c)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n))
        den = jnp.maximum(den, jnp.exp(-m_new))
        y = (num / den[..., None]).astype(x.dtype).reshape(B, 1, self.inner)
        y = rms_norm(y, p[f"{self.prefix}.norm"], self.cfg.norm_eps)
        y = y * jax.nn.silu(z)
        out = y @ p[f"{self.prefix}.down"].astype(x.dtype)
        return out, MLSTMState(c, n, m_new)


class SLSTMBlock:
    """sLSTM with per-head recurrent gate connections + pf=4/3 FFN."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str) -> None:
        assert cfg.xlstm is not None
        self.cfg = cfg
        self.prefix = prefix
        d = cfg.d_model
        self.inner = d
        self.heads = cfg.num_heads
        self.hd = d // self.heads
        # 128-align the up-projection so the TP axis always divides it
        ff = -(-int(d * cfg.xlstm.slstm_proj_factor) // 128) * 128
        dt = jnp.dtype(cfg.param_dtype)
        init = normal_init(d ** -0.5)
        pc.declare(f"{prefix}.wx", (d, 4 * d), jnp.float32, ("embed", "ff"), init)
        pc.declare(f"{prefix}.r", (self.heads, self.hd, 4 * self.hd), jnp.float32,
                   ("heads", "head", None), normal_init(self.hd ** -0.5))
        pc.declare(f"{prefix}.norm", (d,), dt, ("embed",), zeros_init())
        pc.declare(f"{prefix}.up", (d, 2 * ff), dt, ("embed", "ff"), init)
        pc.declare(f"{prefix}.down", (ff, d), dt, ("ff", "embed"),
                   normal_init(ff ** -0.5))

    def init_state(self, batch: int) -> SLSTMState:
        z = jnp.zeros((batch, self.inner), jnp.float32)
        return SLSTMState(z, z, z, jnp.full_like(z, -1e30))

    def _cell(self, p, xt, state: SLSTMState) -> Tuple[jax.Array, SLSTMState]:
        """One timestep. xt: [B, 4d] pre-activations from the input side."""
        B = xt.shape[0]
        h_heads = state.h.reshape(B, self.heads, self.hd)
        rec = jnp.einsum("bhk,hkg->bhg", h_heads, p[f"{self.prefix}.r"])
        rec = rec.reshape(B, 4 * self.inner)
        zi, ii, fi, oi = jnp.split(xt + rec, 4, axis=-1)
        zt = jnp.tanh(zi)
        it = ii                                           # exp gate (log space)
        ft = jax.nn.log_sigmoid(fi)
        ot = jax.nn.sigmoid(oi)
        m_new = jnp.maximum(ft + state.m, it)
        a = jnp.exp(ft + state.m - m_new)
        b = jnp.exp(it - m_new)
        c = a * state.c + b * zt
        n = a * state.n + b
        h = ot * c / jnp.maximum(n, 1.0)
        return h, SLSTMState(c, n, h, m_new)

    def forward(self, p, x):
        B, S, d = x.shape
        xg = x.astype(jnp.float32) @ p[f"{self.prefix}.wx"]   # [B,S,4d]

        def step(state, xt):
            h, state = self._cell(p, xt, state)
            return state, h

        _, hs = jax.lax.scan(step, self.init_state(B), xg.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2).astype(x.dtype)
        h = rms_norm(h, p[f"{self.prefix}.norm"], self.cfg.norm_eps)
        u, g = jnp.split(h @ p[f"{self.prefix}.up"].astype(x.dtype), 2, -1)
        return (jax.nn.gelu(u) * g) @ p[f"{self.prefix}.down"].astype(x.dtype)

    def decode(self, p, x, state: SLSTMState):
        B = x.shape[0]
        xg = x[:, 0].astype(jnp.float32) @ p[f"{self.prefix}.wx"]
        h, state = self._cell(p, xg, state)
        h = h[:, None].astype(x.dtype)
        h = rms_norm(h, p[f"{self.prefix}.norm"], self.cfg.norm_eps)
        u, g = jnp.split(h @ p[f"{self.prefix}.up"].astype(x.dtype), 2, -1)
        out = (jax.nn.gelu(u) * g) @ p[f"{self.prefix}.down"].astype(x.dtype)
        return out, state
