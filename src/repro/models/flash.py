"""Online-softmax (flash-style) chunked attention in pure JAX.

XLA materializes softmax(QK^T) — at 32k context that is a [B, H, S, S]
tensor measured in terabytes, so every long-sequence path routes through
this chunked formulation instead: an outer scan over query blocks and an
inner scan over key blocks carrying the running (row-max, denominator,
accumulator).  Memory per step is O(Qc * Kc) regardless of S, which is what
lets the prefill_32k / train_4k cells actually FIT in the dry-run memory
analysis.  (A Pallas flash kernel is the logical next step and is listed as
a §Perf hillclimb candidate; the scan formulation already bounds memory and
lets XLA pipeline the blocks.)

Supports: GQA grouping, causal and sliding-window masks, logit softcap,
bidirectional (encoder) attention.  Blocks that a causal mask fully kills
are still computed (dense scan) — the block-skip optimization is measured
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def online_attention(
    q: jax.Array,            # [B, Sq, KV, G, hd]
    k: jax.Array,            # [B, Sk, KV, hd]
    v: jax.Array,            # [B, Sk, KV, hd]
    *,
    causal: bool,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,       # position of q[0] within the key timeline
    chunk_q: int = 1024,
    chunk_k: int = 1024,
) -> jax.Array:
    B, Sq, KV, G, hd = q.shape
    dv = v.shape[-1]                 # v head dim may differ (MLA)
    Sk = k.shape[1]
    Qc = min(chunk_q, Sq)
    Kc = min(chunk_k, Sk)
    assert Sq % Qc == 0 and Sk % Kc == 0, (Sq, Qc, Sk, Kc)
    nq, nk = Sq // Qc, Sk // Kc
    scale = hd ** -0.5

    qs = q.reshape(B, nq, Qc, KV, G, hd)
    ks = k.reshape(B, nk, Kc, KV, hd)
    vs = v.reshape(B, nk, Kc, KV, dv)

    def q_block(carry, qi):
        qb = qs[:, qi]                                    # [B,Qc,KV,G,hd]
        qpos = q_offset + qi * Qc + jnp.arange(Qc)

        def k_block(state, ki):
            m, l, acc = state
            kb = ks[:, ki]
            vb = vs[:, ki]
            kpos = ki * Kc + jnp.arange(Kc)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32)
            s = s * scale
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            mask = jnp.ones((Qc, Kc), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(qb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, Qc), NEG, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_block, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # [B,KV,G,Qc,hd] -> [B,Qc,KV,G,hd]
        return carry, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))
    # blocks: [nq, B, Qc, KV, G, dv]
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, dv)
    return out


DENSE_LIMIT = 1 << 22   # Sq*Sk above this routes to the online path


def should_chunk(sq: int, sk: int) -> bool:
    return sq * sk > DENSE_LIMIT and sq > 1
