"""Mamba2 (SSD) block — the state-space half of the zamba2 hybrid.

Training/prefill uses the chunked SSD algorithm (intra-chunk attention-like
matmuls + inter-chunk state recurrence over S/Q steps), which keeps all the
heavy work in MXU-shaped einsums.  Decode keeps the O(1) recurrent state
[B, H, N, P] — this is what makes the long_500k shape runnable for the
hybrid/SSM architectures while pure-attention archs skip it (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (ParamCollector, normal_init, ones_init,
                                 rms_norm, zeros_init)


class SSMState(NamedTuple):
    s: jax.Array        # [B, H, N, P] state
    conv: jax.Array     # [B, W-1, conv_dim] rolling conv inputs


class Mamba2Block:
    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str) -> None:
        assert cfg.ssm is not None
        self.cfg = cfg
        self.prefix = prefix
        s = cfg.ssm
        d = cfg.d_model
        inner = s.expand * d
        self.inner = inner
        self.heads = s.num_heads or inner // s.head_dim
        self.P = inner // self.heads
        self.N = s.state_dim
        self.conv_dim = inner + 2 * self.N  # x + B + C share the conv
        dt = jnp.dtype(cfg.param_dtype)
        init = normal_init(d ** -0.5)
        pc.declare(f"{prefix}.in_proj",
                   (d, 2 * inner + 2 * self.N + self.heads), dt,
                   ("embed", "ff"), init)
        pc.declare(f"{prefix}.conv_w", (s.conv_width, self.conv_dim), dt,
                   (None, "ff"), normal_init(s.conv_width ** -0.5))
        pc.declare(f"{prefix}.A_log", (self.heads,), jnp.float32, (None,),
                   zeros_init())
        pc.declare(f"{prefix}.D", (self.heads,), jnp.float32, (None,), ones_init())
        pc.declare(f"{prefix}.dt_bias", (self.heads,), jnp.float32, (None,),
                   zeros_init())
        pc.declare(f"{prefix}.norm", (inner,), dt, ("ff",), zeros_init())
        pc.declare(f"{prefix}.out_proj", (inner, d), dt, ("ff", "embed"),
                   normal_init(inner ** -0.5))

    # -- shared pieces -------------------------------------------------------
    def _project(self, p, x):
        pre = self.prefix
        proj = x @ p[f"{pre}.in_proj"].astype(x.dtype)
        z, xbc, dt_raw = jnp.split(
            proj, [self.inner, self.inner + self.conv_dim], axis=-1)
        return z, xbc, dt_raw

    def _split_xbc(self, xbc):
        return jnp.split(xbc, [self.inner, self.inner + self.N], axis=-1)

    def _gates(self, p, dt_raw):
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                             p[f"{self.prefix}.dt_bias"])
        A = -jnp.exp(p[f"{self.prefix}.A_log"])          # [H] negative
        return dt, A

    def _out(self, p, y, z):
        y = rms_norm(y * jax.nn.silu(z), p[f"{self.prefix}.norm"],
                     self.cfg.norm_eps)
        return y @ p[f"{self.prefix}.out_proj"].astype(y.dtype)

    # -- training / prefill: chunked SSD -------------------------------------
    def forward(self, p, x, *, return_state: bool = False):
        cfg, s = self.cfg, self.cfg.ssm
        B, S, d = x.shape
        H, P, N, Q = self.heads, self.P, self.N, min(s.chunk, S)
        assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
        z, xbc, dt_raw = self._project(p, x)

        # causal depthwise conv over (x, B, C)
        w = p[f"{self.prefix}.conv_w"].astype(x.dtype)
        pad = jnp.zeros((B, s.conv_width - 1, self.conv_dim), x.dtype)
        xbc_pad = jnp.concatenate([pad, xbc], axis=1)
        conv = sum(xbc_pad[:, i:i + S] * w[i] for i in range(s.conv_width))
        conv = jax.nn.silu(conv)
        xs, Bm, Cm = self._split_xbc(conv)

        dt, A = self._gates(p, dt_raw)                    # [B,S,H], [H]
        xh = xs.reshape(B, S, H, P)
        xbar = xh * dt[..., None].astype(x.dtype)         # dt-scaled input
        loga = dt * A                                     # [B,S,H] log decay

        nc = S // Q
        xbar = xbar.reshape(B, nc, Q, H, P)
        Bc = Bm.reshape(B, nc, Q, N)
        Cc = Cm.reshape(B, nc, Q, N)
        la = loga.reshape(B, nc, Q, H)
        g = jnp.cumsum(la, axis=2)                        # [B,nc,Q,H]

        # intra-chunk (attention-like, strictly causal within chunk)
        rel = g[:, :, :, None, :] - g[:, :, None, :, :]   # [B,nc,Q,Q,H]
        iq = jnp.arange(Q)
        causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
        L = jnp.where(causal, jnp.exp(rel), 0.0).astype(x.dtype)
        cb = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)        # [B,nc,Q,Q]
        y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", cb, L, xbar)

        # chunk summary states  [B,nc,H,N,P]
        decay_tail = jnp.exp(g[:, :, -1:, :] - g)         # [B,nc,Q,H]
        states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                            Bc, decay_tail.astype(x.dtype), xbar)

        # inter-chunk recurrence (scan over nc)
        chunk_decay = jnp.exp(g[:, :, -1, :])             # [B,nc,H]

        def step(s_prev, inp):
            st, dec = inp
            s_new = s_prev * dec[..., None, None].astype(s_prev.dtype) + st
            return s_new, s_prev

        s0 = jnp.zeros((B, H, N, P), x.dtype)
        s_last, s_prevs = jax.lax.scan(
            step, s0, (states.transpose(1, 0, 2, 3, 4),
                       chunk_decay.transpose(1, 0, 2)))
        s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)        # [B,nc,H,N,P]

        y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                             Cc, jnp.exp(g).astype(x.dtype), s_prevs)
        y = (y_intra + y_inter).reshape(B, S, H, P)
        y = y + xh * p[f"{self.prefix}.D"].astype(x.dtype)[None, None, :, None]
        y = y.reshape(B, S, self.inner)
        out = self._out(p, y, z)
        if return_state:
            tail = jnp.concatenate([pad, xbc], axis=1)[:, -(s.conv_width - 1):]
            return out, SSMState(s_last, tail)
        return out

    # -- decode ---------------------------------------------------------------
    def init_state(self, batch: int) -> SSMState:
        dt = jnp.dtype(self.cfg.compute_dtype)
        return SSMState(
            jnp.zeros((batch, self.heads, self.N, self.P), dt),
            jnp.zeros((batch, self.cfg.ssm.conv_width - 1, self.conv_dim), dt))

    def decode(self, p, x, state: SSMState):
        """x: [B, 1, d] -> ([B, 1, d], new state)."""
        s_cfg = self.cfg.ssm
        B = x.shape[0]
        z, xbc, dt_raw = self._project(p, x)
        window = jnp.concatenate([state.conv, xbc], axis=1)  # [B, W, conv_dim]
        w = p[f"{self.prefix}.conv_w"].astype(x.dtype)
        conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w))[:, None]
        xs, Bm, Cm = self._split_xbc(conv)
        dt, A = self._gates(p, dt_raw)                    # [B,1,H]
        xh = xs.reshape(B, 1, self.heads, self.P)
        a = jnp.exp(dt * A)[..., 0, :]                    # [B,H]
        xbar = (xh * dt[..., None].astype(x.dtype))[:, 0]  # [B,H,P]
        s_new = (state.s * a[..., None, None].astype(state.s.dtype)
                 + jnp.einsum("bn,bhp->bhnp", Bm[:, 0], xbar))
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], s_new)
        y = y + xh[:, 0] * p[f"{self.prefix}.D"].astype(x.dtype)[None, :, None]
        y = y.reshape(B, 1, self.inner)
        out = self._out(p, y, z)
        return out, SSMState(s_new, window[:, 1:])
