"""Mixture-of-Experts block: top-k routing, shared experts, capacity-based
dispatch (GShard-style), expert parallelism over the 'model' mesh axis.

Dispatch is the sort-free masked-scatter formulation: every (token, k) slot
computes its rank among slots routed to the same expert; slots with rank <
capacity scatter into per-expert buffers [E, C, d].  Two batched einsums run
all expert FFNs (expert dim sharded over 'model' = EP; capacity dim sharded
over 'data' so the buffers scale with the mesh), and a scatter-add combines
weighted expert outputs back to tokens.

The paper-faithful baseline lets GSPMD place the collectives for the
token->expert reshuffle; the §Perf hillclimb replaces this with an explicit
shard_map all-to-all schedule (see EXPERIMENTS.md).

Capacity drops (rank >= C) follow GShard/Switch; the roofline accounting in
launch/roofline.py uses capacity-based active FLOPs accordingly.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ParamCollector, activation_fn, normal_init

ShardFn = Callable[[jax.Array, tuple], jax.Array]


def _noshard(x, names):
    return x


class MoEBlock:
    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str) -> None:
        assert cfg.moe is not None
        self.cfg = cfg
        self.prefix = prefix
        m = cfg.moe
        d = cfg.d_model
        dt = jnp.dtype(cfg.param_dtype)
        init = normal_init(d ** -0.5)
        pc.declare(f"{prefix}.router", (d, m.num_experts), jnp.float32,
                   ("embed", "experts"), init)
        pc.declare(f"{prefix}.w_gate", (m.num_experts, d, m.d_ff_expert), dt,
                   ("experts", "embed", "moe_ff"), init)
        pc.declare(f"{prefix}.w_up", (m.num_experts, d, m.d_ff_expert), dt,
                   ("experts", "embed", "moe_ff"), init)
        pc.declare(f"{prefix}.w_down", (m.num_experts, m.d_ff_expert, d), dt,
                   ("experts", "moe_ff", "embed"), normal_init(m.d_ff_expert ** -0.5))
        if m.shared_experts:
            ff = m.d_ff_expert * m.shared_experts
            pc.declare(f"{prefix}.sh_gate", (d, ff), dt, ("embed", "ff"), init)
            pc.declare(f"{prefix}.sh_up", (d, ff), dt, ("embed", "ff"), init)
            pc.declare(f"{prefix}.sh_down", (ff, d), dt, ("ff", "embed"),
                       normal_init(ff ** -0.5))

    def __call__(self, p, x: jax.Array, *, shard: ShardFn = _noshard) -> jax.Array:
        cfg, m, pre = self.cfg, self.cfg.moe, self.prefix
        B, S, d = x.shape
        n_tok = B * S
        k = m.experts_per_token
        E = m.num_experts
        act = activation_fn(cfg.activation)

        xt = x.reshape(n_tok, d)
        # --- routing (f32 for stable softmax) -------------------------------
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            p[f"{pre}.router"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)               # [T, k]
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        # --- capacity-based dispatch ----------------------------------------
        cap = int(math.ceil(n_tok * k / E * m.capacity_factor))
        cap = max(cap, 1)
        slot_e = top_e.reshape(-1)                            # [T*k]
        slot_w = top_w.reshape(-1).astype(x.dtype)
        slot_t = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), k)
        # rank of each slot within its expert (cumulative count formulation)
        onehot = jax.nn.one_hot(slot_e, E, dtype=jnp.int32)   # [T*k, E]
        rank = (jnp.cumsum(onehot, axis=0) - onehot)          # exclusive
        rank = jnp.take_along_axis(rank, slot_e[:, None], axis=1)[:, 0]
        keep = rank < cap
        buf_idx = jnp.where(keep, slot_e * cap + rank, E * cap)  # drop slot

        buf = jnp.zeros((E * cap + 1, d), x.dtype)
        buf = buf.at[buf_idx].add(xt[slot_t])
        buf = buf[:-1].reshape(E, cap, d)
        buf = shard(buf, ("experts", "expert_cap", None))

        # --- expert FFNs (EP: expert dim sharded over 'model') --------------
        g = act(jnp.einsum("ecd,edf->ecf", buf, p[f"{pre}.w_gate"].astype(x.dtype)))
        u = jnp.einsum("ecd,edf->ecf", buf, p[f"{pre}.w_up"].astype(x.dtype))
        h = jnp.einsum("ecf,efd->ecd", g * u, p[f"{pre}.w_down"].astype(x.dtype))
        h = shard(h, ("experts", "expert_cap", None))

        # --- combine ---------------------------------------------------------
        hflat = h.reshape(E * cap, d)
        slot_out = hflat[jnp.minimum(buf_idx, E * cap - 1)] * keep[:, None]
        y = jnp.zeros((n_tok, d), x.dtype)
        y = y.at[slot_t].add(slot_out * slot_w[:, None])

        # --- shared experts ---------------------------------------------------
        if m.shared_experts:
            sg = act(xt @ p[f"{pre}.sh_gate"].astype(x.dtype))
            su = xt @ p[f"{pre}.sh_up"].astype(x.dtype)
            y = y + (sg * su) @ p[f"{pre}.sh_down"].astype(x.dtype)

        return y.reshape(B, S, d)
