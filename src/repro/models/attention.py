"""Attention variants: GQA (covers MHA), sliding-window, qk-norm, softcap,
cross-attention (VLM), and DeepSeek-style MLA (multi-head latent attention).

All functions are stateless; parameters live in a flat dict under a prefix.
Three entry modes share one code path:

* ``forward``   — full-sequence training / encoder forward
* ``prefill``   — forward + returns the KV cache it built
* ``decode``    — one new token against the cache (the ``serve_step`` path)

The KV cache for GQA is [B, S_max, KV, hd] per layer; MLA caches only the
compressed latent [B, S_max, kv_lora + rope_dim] — the paper-accurate memory
saving that makes deepseek-v2 decode shapes fit (see configs/deepseek).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ParamCollector, apply_rope, normal_init, rms_norm


class KVCache(NamedTuple):
    k: jax.Array      # [B, S_max, KV, hd]  (or latent [B, S_max, Dl] for MLA)
    v: Optional[jax.Array]
    pos: jax.Array    # [] int32 — filled length


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def attn_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
              window: int = 0) -> jax.Array:
    """[..., q, k] boolean mask. window > 0 => sliding-window attention."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

class GQAttention:
    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str,
                 *, cross: bool = False, kv_dim: Optional[int] = None) -> None:
        self.cfg = cfg
        self.prefix = prefix
        self.cross = cross
        d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
        dt = jnp.dtype(cfg.param_dtype)
        kvd = kv_dim or d
        init = normal_init(d ** -0.5)
        pc.declare(f"{prefix}.wq", (d, H, hd), dt, ("embed", "heads", "head"), init)
        pc.declare(f"{prefix}.wk", (kvd, KV, hd), dt, ("embed", "kv_heads", "head"), init)
        pc.declare(f"{prefix}.wv", (kvd, KV, hd), dt, ("embed", "kv_heads", "head"), init)
        pc.declare(f"{prefix}.wo", (H, hd, d), dt, ("heads", "head", "embed"),
                   normal_init((H * hd) ** -0.5))
        if cfg.qk_norm:
            from repro.models.layers import zeros_init
            pc.declare(f"{prefix}.q_norm", (hd,), dt, ("head",), zeros_init())
            pc.declare(f"{prefix}.k_norm", (hd,), dt, ("head",), zeros_init())

    # -- projections --------------------------------------------------------
    def _qkv(self, p, x, kv_src, positions, kv_positions, *, rope: bool):
        cfg, pre = self.cfg, self.prefix
        q = jnp.einsum("bsd,dhk->bshk", x, p[f"{pre}.wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", kv_src, p[f"{pre}.wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", kv_src, p[f"{pre}.wv"].astype(x.dtype))
        if cfg.qk_norm:
            q = rms_norm(q, p[f"{pre}.q_norm"], cfg.norm_eps)
            k = rms_norm(k, p[f"{pre}.k_norm"], cfg.norm_eps)
        if rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, kv_positions, cfg.rope_theta)
        return q, k, v

    # GQA grouping layout: 'repeat' expands K/V to H heads so the head dim
    # stays a single axis the TP mesh can shard (32 heads / 16-way model
    # axis).  The 'grouped' [KV, G] reshape splits the head axis into dims
    # of size KV and G, neither of which divides the mesh when KV < 16 —
    # GSPMD then replicates the whole attention computation (measured:
    # EXPERIMENTS.md §Perf LM-2).  Numerically identical; tests assert it.
    kv_layout = "repeat"

    def _group(self, q, k, v):
        B, Sq, H, hd = q.shape
        KV = k.shape[2]
        if self.kv_layout == "repeat" and KV != H:
            k = jnp.repeat(k, H // KV, axis=2)
            v = jnp.repeat(v, H // KV, axis=2)
            KV = H
        G = H // KV
        return q.reshape(B, Sq, KV, G, hd), k, v

    def _attend(self, p, q, k, v, mask):
        """q: [B,Sq,H,hd]; k/v: [B,Sk,KV,hd]; mask: [Sq,Sk]/[B?,Sk] or None."""
        cfg = self.cfg
        B, Sq, H, hd = q.shape
        qg, k, v = self._group(q, k, v)
        KV, G = qg.shape[2], qg.shape[3]
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
        scores *= hd ** -0.5
        if cfg.attn_logit_softcap > 0:
            c = cfg.attn_logit_softcap
            scores = c * jnp.tanh(scores / c)
        if mask is not None:
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", w, v).reshape(B, Sq, H, hd)
        return jnp.einsum("bqhk,hkd->bqd", out, p[f"{self.prefix}.wo"].astype(q.dtype))

    def _attend_seq(self, p, q, k, v, *, causal: bool, window: int):
        """Full-sequence attention; routes long contexts through the
        online-softmax chunked path (repro.models.flash)."""
        from repro.models import flash

        cfg = self.cfg
        B, Sq, H, hd = q.shape
        if flash.should_chunk(Sq, k.shape[1]):
            qg, k, v = self._group(q, k, v)
            out = flash.online_attention(
                qg, k, v, causal=causal, window=window,
                softcap=cfg.attn_logit_softcap).reshape(B, Sq, H, hd)
            return jnp.einsum("bqhk,hkd->bqd", out,
                              p[f"{self.prefix}.wo"].astype(q.dtype))
        pos = jnp.arange(Sq, dtype=jnp.int32)
        mask = attn_mask(pos, pos, causal=causal, window=window)
        return self._attend(p, q, k, v, mask)

    # -- entry points --------------------------------------------------------
    def forward(self, p, x, positions, *, window: int = 0,
                kv_src: Optional[jax.Array] = None,
                kv_positions: Optional[jax.Array] = None) -> jax.Array:
        cross = kv_src is not None
        kv_src = x if kv_src is None else kv_src
        kv_positions = positions if kv_positions is None else kv_positions
        q, k, v = self._qkv(p, x, kv_src, positions, kv_positions,
                            rope=not cross)
        if cross:
            return self._attend(p, q, k, v, None)
        return self._attend_seq(p, q, k, v, causal=self.cfg.causal,
                                window=window)

    def init_cache(self, batch: int, s_max: int) -> KVCache:
        cfg = self.cfg
        shape = (batch, s_max, cfg.num_kv_heads, cfg.head_dim_)
        dt = jnp.dtype(cfg.compute_dtype)
        return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt),
                       jnp.zeros((), jnp.int32))

    def prefill(self, p, x, positions, cache: KVCache, *, window: int = 0):
        q, k, v = self._qkv(p, x, x, positions, positions, rope=True)
        S = x.shape[1]
        cache = KVCache(
            jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0)),
            jnp.asarray(S, jnp.int32))
        out = self._attend_seq(p, q, k, v, causal=self.cfg.causal,
                               window=window)
        return out, cache

    def decode(self, p, x, cache: KVCache, *, window: int = 0):
        """x: [B, 1, d]; attends over cache[:pos] + the new token."""
        cfg = self.cfg
        pos = cache.pos
        positions = pos[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)
        q, k, v = self._qkv(p, x, x, positions, positions, rope=True)
        # index dtypes must match even under x64 (core enables it globally)
        z = jnp.zeros((), pos.dtype)
        ck = jax.lax.dynamic_update_slice(cache.k, k, (z, pos, z, z))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (z, pos, z, z))
        s_max = ck.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        valid = k_pos <= pos
        if window > 0:
            valid &= k_pos > pos - window
        mask = valid[None, :]
        out = self._attend(p, q, ck, cv, mask)
        return out, KVCache(ck, cv, pos + 1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

class MLAttention:
    """DeepSeek-V2 Multi-head Latent Attention.

    Prefill/train expand the latent to per-head K/V and run (chunked)
    attention.  Decode uses the ABSORBED form: q_nope is folded through
    wkv_b so scores are taken directly against the cached latent — per-step
    cost O(S * (kv_lora + rope)) instead of O(S * H * head_dim), and the
    cache holds only [B, S, kv_lora + rope_dim].  This is the serving trick
    that makes deepseek-v2's decode_32k shape fit (DESIGN.md / configs)."""

    def __init__(self, cfg: ModelConfig, pc: ParamCollector, prefix: str) -> None:
        assert cfg.mla is not None
        self.cfg = cfg
        self.prefix = prefix
        m = cfg.mla
        d, H = cfg.d_model, cfg.num_heads
        dt = jnp.dtype(cfg.param_dtype)
        init = normal_init(d ** -0.5)
        qdim = m.nope_head_dim + m.rope_head_dim
        if m.q_lora_rank:
            pc.declare(f"{prefix}.wq_a", (d, m.q_lora_rank), dt, ("embed", None), init)
            pc.declare(f"{prefix}.q_norm", (m.q_lora_rank,), dt, (None,),
                       normal_init(0.0))
            pc.declare(f"{prefix}.wq_b", (m.q_lora_rank, H, qdim), dt,
                       (None, "heads", "head"), init)
        else:
            pc.declare(f"{prefix}.wq", (d, H, qdim), dt, ("embed", "heads", "head"), init)
        pc.declare(f"{prefix}.wkv_a", (d, m.kv_lora_rank + m.rope_head_dim), dt,
                   ("embed", None), init)
        pc.declare(f"{prefix}.kv_norm", (m.kv_lora_rank,), dt, (None,), normal_init(0.0))
        pc.declare(f"{prefix}.wkv_b", (m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim),
                   dt, (None, "heads", "head"), init)
        pc.declare(f"{prefix}.wo", (H, m.v_head_dim, d), dt,
                   ("heads", "head", "embed"), normal_init((H * m.v_head_dim) ** -0.5))

    def _q(self, p, x):
        m, pre = self.cfg.mla, self.prefix
        if m.q_lora_rank:
            cq = jnp.einsum("bsd,dr->bsr", x, p[f"{pre}.wq_a"].astype(x.dtype))
            cq = rms_norm(cq, p[f"{pre}.q_norm"], self.cfg.norm_eps)
            q = jnp.einsum("bsr,rhk->bshk", cq, p[f"{pre}.wq_b"].astype(x.dtype))
        else:
            q = jnp.einsum("bsd,dhk->bshk", x, p[f"{pre}.wq"].astype(x.dtype))
        return q

    def _latent(self, p, x):
        m, pre = self.cfg.mla, self.prefix
        ckv = jnp.einsum("bsd,dr->bsr", x, p[f"{pre}.wkv_a"].astype(x.dtype))
        c, k_rope = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
        c = rms_norm(c, p[f"{pre}.kv_norm"], self.cfg.norm_eps)
        return c, k_rope

    def _full_attention(self, p, x, positions):
        """Expanded-KV path (train/prefill), chunked when long."""
        from repro.models import flash

        cfg, m, pre = self.cfg, self.cfg.mla, self.prefix
        B, S, _ = x.shape
        H = cfg.num_heads
        q = self._q(p, x)
        c, k_rope = self._latent(p, x)
        kv = jnp.einsum("bsr,rhk->bshk", c, p[f"{pre}.wkv_b"].astype(x.dtype))
        k_nope, v = kv[..., :m.nope_head_dim], kv[..., m.nope_head_dim:]
        q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)
        k_rope = jnp.broadcast_to(k_rope, (B, S, H, m.rope_head_dim))
        qf = jnp.concatenate([q_nope, q_rope], -1)
        kf = jnp.concatenate([k_nope, k_rope], -1)
        # treat as MHA: KV groups = H, group size 1
        qg = qf[:, :, :, None, :]
        if flash.should_chunk(S, S):
            out = flash.online_attention(qg, kf, v, causal=cfg.causal,
                                         window=0)[:, :, :, 0]
        else:
            scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
            sc = jnp.einsum("bqhk,bshk->bhqs", qf, kf).astype(jnp.float32) * scale
            pos = jnp.arange(S)
            mask = pos[None, :] <= pos[:, None] if cfg.causal else None
            if mask is not None:
                sc = jnp.where(mask[None, None], sc, -1e30)
            w = jax.nn.softmax(sc, -1).astype(x.dtype)
            out = jnp.einsum("bhqs,bshk->bqhk", w, v)
        y = jnp.einsum("bqhk,hkd->bqd", out, p[f"{pre}.wo"].astype(x.dtype))
        return y, c, k_rope_raw_cache(c, k_rope)

    def forward(self, p, x, positions, *, window: int = 0, **_):
        y, _, _ = self._full_attention(p, x, positions)
        return y

    def init_cache(self, batch: int, s_max: int) -> KVCache:
        m = self.cfg.mla
        dt = jnp.dtype(self.cfg.compute_dtype)
        lat = jnp.zeros((batch, s_max, m.kv_lora_rank + m.rope_head_dim), dt)
        return KVCache(lat, None, jnp.zeros((), jnp.int32))

    def prefill(self, p, x, positions, cache: KVCache, *, window: int = 0):
        cfg, m = self.cfg, self.cfg.mla
        y, c, _ = self._full_attention(p, x, positions)
        # cache the latent + the *roped* shared key part
        k_rope_r = self._roped_krope(p, x, positions)
        lat = jnp.concatenate([c, k_rope_r], axis=-1)
        cache = KVCache(
            jax.lax.dynamic_update_slice(cache.k, lat, (0, 0, 0)),
            None, jnp.asarray(x.shape[1], jnp.int32))
        return y, cache

    def _roped_krope(self, p, x, positions):
        cfg, m = self.cfg, self.cfg.mla
        _, k_rope = self._latent(p, x)
        return apply_rope(k_rope[..., None, :], positions,
                          cfg.rope_theta)[..., 0, :]

    def decode(self, p, x, cache: KVCache, *, window: int = 0):
        """Absorbed-form single-token decode against the latent cache."""
        cfg, m, pre = self.cfg, self.cfg.mla, self.prefix
        B = x.shape[0]
        H = cfg.num_heads
        pos = cache.pos
        positions = pos[None, None] + jnp.zeros((B, 1), jnp.int32)
        q = self._q(p, x)                               # [B,1,H,dn+dr]
        q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

        c_new, _ = self._latent(p, x)
        kr_new = self._roped_krope(p, x, positions)
        lat_new = jnp.concatenate([c_new, kr_new], axis=-1)
        z = jnp.zeros((), pos.dtype)
        lat = jax.lax.dynamic_update_slice(cache.k, lat_new, (z, pos, z))
        c_all = lat[..., :m.kv_lora_rank]               # [B,S,r]
        kr_all = lat[..., m.kv_lora_rank:]              # [B,S,dr] (roped)

        wkv_b = p[f"{pre}.wkv_b"].astype(x.dtype)       # [r,H,dn+dv]
        wk = wkv_b[..., :m.nope_head_dim]               # [r,H,dn]
        wv = wkv_b[..., m.nope_head_dim:]               # [r,H,dv]

        # absorb: q_lat[b,h,r] = sum_dn q_nope * wk
        q_lat = jnp.einsum("bxhn,rhn->bxhr", q_nope, wk)[:, 0]   # [B,H,r]
        sc = (jnp.einsum("bhr,bsr->bhs", q_lat, c_all) +
              jnp.einsum("bxhn,bsn->bhs", q_rope, kr_all)).astype(jnp.float32)
        sc *= (m.nope_head_dim + m.rope_head_dim) ** -0.5
        s_max = lat.shape[1]
        valid = (jnp.arange(s_max, dtype=jnp.int32) <= pos)[None, None, :]
        sc = jnp.where(valid, sc, -1e30)
        w = jax.nn.softmax(sc, -1).astype(x.dtype)               # [B,H,S]
        ctx_lat = jnp.einsum("bhs,bsr->bhr", w, c_all)           # [B,H,r]
        out = jnp.einsum("bhr,rhv->bhv", ctx_lat, wv)            # [B,H,dv]
        y = jnp.einsum("bhv,hvd->bd", out, p[f"{pre}.wo"].astype(x.dtype))
        return y[:, None], KVCache(lat, None, pos + 1)


def k_rope_raw_cache(c, k_rope):
    return None  # placeholder: prefill re-derives the roped key part
