"""Fault-tolerant checkpointing.

Design points (what 1000-node fleets need):

* **atomic commits** — writes land in ``step_<n>.tmp`` and are renamed only
  after every leaf + manifest is fsynced; a crash mid-write can never corrupt
  the latest checkpoint.
* **async** — `save_async` snapshots device arrays to host then hands the IO
  to a background thread; training continues immediately (the join happens
  on the next save or at shutdown).
* **integrity** — every leaf carries a crc32; restore verifies before use.
* **elastic restore** — checkpoints store logical arrays, not device tiles;
  `restore` re-shards onto whatever mesh is current, so a job can resume on
  a different topology (node failures, resizes).
* **retention** — keep the last K checkpoints, delete older ones only after
  a newer commit succeeded.
"""

from repro.checkpoint.store import (CheckpointManager, restore_checkpoint,
                                    save_checkpoint)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint"]
