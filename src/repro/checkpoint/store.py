"""Checkpoint store implementation (see package docstring for guarantees)."""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names including ml_dtypes (bfloat16, fp8...)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    items, _ = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for key, leaf in items:
        arr = np.asarray(jax.device_get(leaf))
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".bin"
        path = os.path.join(tmp, fname)
        # raw bytes + logical dtype in the manifest: round-trips ml_dtypes
        # (bfloat16/fp8) that np.save would mangle
        with open(path, "wb") as f:
            f.write(arr.tobytes())
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({
            "key": key, "file": fname, "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # the atomic commit point
    return final


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None,
                       *, shardings: Optional[Any] = None
                       ) -> Tuple[Any, int, Dict]:
    """Restore the latest (or a specific) checkpoint into tree_like's
    structure, optionally re-sharding every leaf (elastic restore)."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}

    items, treedef = _flatten(tree_like)
    leaves = []
    flat_shardings = None
    if shardings is not None:
        s_items, _ = _flatten(shardings)
        flat_shardings = dict(s_items)
    for key, like in items:
        meta = by_key[key]
        with open(os.path.join(path, meta["file"]), "rb") as f:
            raw = f.read()
        arr = np.frombuffer(raw, dtype=_np_dtype(meta["dtype"])) \
            .reshape(meta["shape"]).copy()
        crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
        if crc != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {key} "
                          f"(crc {crc} != {meta['crc32']})")
        if flat_shardings is not None and key in flat_shardings:
            arr = jax.device_put(arr, flat_shardings[key])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"], manifest["extra"]


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


class CheckpointManager:
    """Async save + retention + resume."""

    def __init__(self, directory: str, *, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Snapshot to host now; write + commit + GC in the background."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        self.wait()
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def restore(self, tree_like: Any, *, step: Optional[int] = None,
                shardings: Optional[Any] = None):
        self.wait()
        return restore_checkpoint(self.directory, tree_like, step,
                                  shardings=shardings)

    def latest_step(self) -> Optional[int]:
        steps = available_steps(self.directory)
        return steps[-1] if steps else None

    def _gc(self) -> None:
        steps = available_steps(self.directory)
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
