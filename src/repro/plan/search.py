"""Elimination-order search: min-fill joins a candidate pool it used to own.

``plan_query`` builds the LogicalPlan (graph + projection split + stats),
generates candidate orders, scores each with the :class:`CostModel`, and
pins the winner into a :class:`PhysicalPlan`:

* **min_fill**  — the paper's structural heuristic (always in the pool, so
  the planner can never regress below the old behavior *by its own
  estimate*);
* **greedy**    — pick the cheapest next variable by simulated step cost
  (skew-aware through the degree vectors);
* **beam**      — width-``beam_width`` search over prefixes ranked by
  accumulated step cost.

Admissibility (what `build_generator` requires) is enforced structurally:
projected-out variables (O') are eliminated before output variables (O),
so the root — the last variable — is always an output variable.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import (QueryGraph, decompose_bags, min_fill_order,
                              structurally_acyclic)
from repro.plan.cost import CostModel
from repro.plan.ir import BagStep, LogicalPlan, OrderCandidate, PhysicalPlan
from repro.plan.stats import QueryStats
from repro.relational.encoding import EncodedQuery

STREAM_THRESHOLD = 60_000_000  # est rows above which desummarize streams


def build_logical_plan(enc: EncodedQuery, *,
                       early_projection: bool = True,
                       stats: Optional[QueryStats] = None) -> LogicalPlan:
    query = enc.query
    graph = QueryGraph.from_query(query)
    out_vars = tuple(query.output_variables)
    projected_out = tuple(v for v in graph.variables if v not in out_vars) \
        if early_projection else ()
    if stats is None:
        stats = QueryStats.of(enc)
    return LogicalPlan(query, graph, out_vars, projected_out, stats)


def _pool(remaining: List[str], first_set: frozenset) -> List[str]:
    """Eligible next variables: O' while any remain, then O."""
    early = [v for v in remaining if v in first_set]
    return early if early else remaining


def greedy_order(model: CostModel, variables: Sequence[str],
                 first: Sequence[str]) -> Tuple[str, ...]:
    """Cheapest-next-step order (ties break by name for determinism)."""
    first_set = frozenset(first)
    remaining = list(variables)
    factors = model.initial_factors()
    order: List[str] = []
    while remaining:
        pool = _pool(remaining, first_set)
        if len(remaining) == 1:
            v = remaining[0]
        else:
            v = min(pool, key=lambda u: (model.step_cost(factors, u), u))
        est, factors = model.eliminate(factors, v)
        remaining.remove(v)
        order.append(v)
    return tuple(order)


def beam_orders(model: CostModel, variables: Sequence[str],
                first: Sequence[str], *, beam_width: int = 4
                ) -> List[Tuple[str, ...]]:
    """Beam search over elimination prefixes; returns ranked full orders."""
    first_set = frozenset(first)
    # state: (accumulated cost, order-so-far, remaining, sim factors)
    states = [(0.0, (), tuple(variables), model.initial_factors())]
    n = len(variables)
    for depth in range(n):
        nxt = []
        for cost, order, remaining, factors in states:
            pool = _pool(list(remaining), first_set)
            for v in pool:
                est, nf = model.eliminate(factors, v)
                step = est.cost if depth < n - 1 else 0.0  # root is free
                nxt.append((cost + step, order + (v,),
                            tuple(u for u in remaining if u != v), nf))
        nxt.sort(key=lambda s: (s[0], s[1]))
        states = nxt[:max(beam_width, 1)]
    return [s[1] for s in states]


def _select_backends() -> Dict[str, str]:
    """Phase -> kernel backend.  TPU gets the Pallas paths, CPU stays numpy.

    Only consults jax if something else already imported it: planning must
    not pay (or force) the jax import — a process that never loaded jax is
    running the numpy engine by definition.

    Keys pinned here are the ones the executor actually consults:
    "desummarize" picks between the numpy expansion and the fused
    `kernels/expand_fused.py` wrapper; "summarize" picks the generation
    engine — numpy (the dynamic-shape oracle) or the device-resident
    `engine_jax.generate_gfjs_jax` frontier.  On CPU both stay numpy: the
    kernels would only run interpreted there, and numpy's dynamic shapes
    beat bucket-padded interpret execution (DESIGN.md §14 quantifies when
    the planner should prefer numpy even on device).
    """
    import sys
    jx = sys.modules.get("jax")
    on_tpu = False
    if jx is not None:
        try:
            on_tpu = jx.default_backend() == "tpu"
        except Exception:  # pragma: no cover - partially initialized jax
            on_tpu = False
    dev = "jax" if on_tpu else "numpy"
    return {"summarize": dev, "desummarize": dev}


def propose_decomposition(
        model: CostModel, logical: LogicalPlan, order: Sequence[str]
) -> Tuple[Tuple[BagStep, ...], List, float]:
    """Hypertree-decomposed hybrid candidate for ``order`` (cyclic only).

    Covers the table occurrences with cliques of the order's induced
    triangulation (``core/graph.py::decompose_bags``), prices each
    multi-occurrence bag as a WCOJ step (AGM bound + skew-aware level
    simulation, ``CostModel.bag_estimate``), then simulates the remaining
    acyclic spine — ordinary GJ elimination over the bag marginals plus
    the unbagged table factors.  Returns ``(bags, spine_steps, total)``;
    ``bags`` is empty when the query is structurally acyclic (the gate
    that keeps acyclic signatures and cache keys byte-unchanged) or when
    no clique joins two or more occurrences.
    """
    graph = logical.graph
    if structurally_acyclic(graph):
        return (), [], 0.0
    raw, _tri = decompose_bags(graph, order)
    if not raw:
        return (), [], 0.0
    bag_steps: List[BagStep] = []
    bag_stats = []
    used = set()
    for scope, occs in raw:
        est = model.bag_estimate(occs, scope)
        bag_steps.append(BagStep(
            vars=tuple(scope), occurrences=tuple(occs),
            bind_order=tuple(scope),
            est_entries=est.entries, est_cost=est.cost,
            agm_entries=est.agm_entries, rho=est.rho,
            num_factors=len(occs),
            tables=tuple(sorted(est.stats.sources))))
        bag_stats.append(est.stats)
        used.update(occs)
    spine = bag_stats + [fs for i, fs in enumerate(model.initial_factors())
                         if i not in used]
    steps, spine_total = model.simulate(order, factors=spine)
    total = float(sum(b.est_cost for b in bag_steps)) + spine_total
    return tuple(bag_steps), steps, total


def plan_query(enc: EncodedQuery, *,
               elimination_order: Optional[Sequence[str]] = None,
               early_projection: bool = True,
               planner: str = "cost",
               beam_width: int = 4,
               stats: Optional[QueryStats] = None,
               generation_backend: Optional[str] = None,
               partitions: Optional[int] = None,
               partition_var: Optional[str] = None,
               partition_fold: Optional[int] = None,
               shard_executor: Optional[str] = None,
               hybrid: Optional[bool] = None,
               corrections: Optional[Dict[str, float]] = None,
               message_cache=None,
               table_versions: Optional[Dict[str, str]] = None
               ) -> Tuple[LogicalPlan, PhysicalPlan]:
    """Logical + physical plan for an encoded query.

    ``elimination_order`` forces the order (source="forced");
    ``planner="min_fill"`` restores the pre-planner behavior;
    ``planner="cost"`` runs the candidate search.
    ``generation_backend`` pins the GFJS-generation engine ("numpy" — the
    dynamic-shape oracle — or "jax", the device-resident frontier) instead
    of the environment default; per-query pinning because small or
    irregular generators favor numpy even when an accelerator is present.
    ``partitions`` > 1 pins hash-partitioned execution
    (repro/dist/partition.py): the executor splits the encoded potentials
    into that many shards on ``partition_var`` (default: the eliminated
    variable of the costliest estimated step, discounted by key skew) and
    runs the shards independently, producing a ``ShardedGFJS``.
    ``shard_executor`` picks where shard pipelines run: ``"thread"``
    (default) or ``"process"`` — the repro/dist/actions.py worker pool.
    ``partition_fold`` over-partitions into ``partitions * fold`` virtual
    shards folded back onto ``partitions`` workers (skew smoothing);
    default: auto-chosen from the degree stats (1 when balanced).
    ``hybrid`` controls hypertree-decomposed GJ/WCOJ execution on cyclic
    queries: ``None`` (default) lets the cost model choose between the
    hybrid candidate and pure GJ, ``False`` disables the candidate, and
    ``True`` forces it (raising when the query is structurally acyclic —
    there is no decomposition to force).  Acyclic queries are never
    decomposed, so their plan signatures and cache keys are unchanged.
    ``corrections`` seeds the CostModel with persisted calibration factors
    (op -> scalar; see ``CostModel.calibrate`` and the JoinService
    sidecar).  ``message_cache`` + ``table_versions`` enable residency
    pricing: steps whose subtree fingerprint is already resident in the
    message cache are priced at ~lookup cost (`CostModel.apply_residency`)
    and ties break toward orders that maximize reusable steps — so a warm
    cache steers the search toward the shared prefix.  Monolithic plans
    only; partitioned builds cannot consume cached messages.
    """
    if generation_backend not in (None, "numpy", "jax"):
        raise ValueError(
            f"unknown generation backend {generation_backend!r}")
    partitions = 1 if partitions is None else int(partitions)
    if partitions < 1:
        raise ValueError(f"partitions must be >= 1, got {partitions}")
    if partitions == 1 and partition_var is not None:
        raise ValueError(
            f"partition_var={partition_var!r} requires partitions > 1 "
            "(a monolithic plan would silently ignore it)")
    if shard_executor not in (None, "thread", "process"):
        raise ValueError(f"unknown shard executor {shard_executor!r} "
                         "(have: thread, process)")
    if partitions == 1 and shard_executor is not None:
        raise ValueError(
            f"shard_executor={shard_executor!r} requires partitions > 1 "
            "(a monolithic plan would silently ignore it)")
    if partition_fold is not None:
        partition_fold = int(partition_fold)
        if partition_fold < 1:
            raise ValueError(
                f"partition_fold must be >= 1, got {partition_fold}")
        if partitions == 1 and partition_fold != 1:
            raise ValueError(
                f"partition_fold={partition_fold} requires partitions > 1 "
                "(a monolithic plan would silently ignore it)")
    if hybrid not in (None, True, False):
        raise ValueError(f"hybrid must be None, True, or False, got {hybrid!r}")
    if hybrid is True and partitions > 1:
        raise ValueError(
            "hybrid=True is unsupported with partitions > 1 (bag potentials "
            "are built monolithically; partition the pure-GJ plan instead)")
    t0 = time.perf_counter()
    from repro.obs.trace import span as _span
    with _span("plan:search", cat="plan", planner=planner):
        return _plan_query_inner(
            enc, t0, elimination_order=elimination_order,
            early_projection=early_projection, planner=planner,
            beam_width=beam_width, stats=stats,
            generation_backend=generation_backend,
            partitions=partitions, partition_var=partition_var,
            partition_fold=partition_fold, shard_executor=shard_executor,
            hybrid=hybrid, corrections=corrections,
            message_cache=message_cache, table_versions=table_versions)


def _plan_query_inner(enc: EncodedQuery, t0: float, *,
                      elimination_order, early_projection, planner,
                      beam_width, stats, generation_backend,
                      partitions, partition_var,
                      partition_fold=None, shard_executor=None,
                      hybrid=None, corrections=None,
                      message_cache=None, table_versions=None
                      ) -> Tuple[LogicalPlan, PhysicalPlan]:
    logical = build_logical_plan(enc, early_projection=early_projection,
                                 stats=stats)
    model = CostModel(logical.stats, corrections=corrections)
    graph, query = logical.graph, logical.query
    first = list(logical.projected_out)

    # residency pricing: which already-resident messages would each
    # candidate order reuse?  Fingerprints depend only on (order, versions,
    # encoding), so this is a pure plan-time computation.
    resident = None
    if (message_cache is not None and table_versions is not None
            and partitions == 1):
        keys = message_cache.resident_keys()
        resident = keys if keys else None

    def _residency(order: Sequence[str]) -> frozenset:
        if resident is None:
            return frozenset()
        from repro.plan.ir import step_fingerprints
        fps, _ = step_fingerprints(
            enc, tuple(order), logical.output_vars, table_versions)
        return frozenset(v for v, fp in fps.items() if fp in resident)

    candidates: List[OrderCandidate] = []
    # order -> (repriced steps, adjusted total, #cached steps)
    sims: Dict[Tuple[str, ...], Tuple[Tuple, float, int]] = {}

    def score(source: str, order: Sequence[str]) -> OrderCandidate:
        order = tuple(order)
        if order not in sims:
            raw_steps, _ = model.simulate(order)
            cached = _residency(order)
            sims[order] = (*model.apply_residency(raw_steps, cached),
                           len(cached))
        return OrderCandidate(source, order, sims[order][1])

    if elimination_order is not None:
        chosen = score("forced", tuple(elimination_order))
        candidates.append(chosen)
    else:
        tri = min_fill_order(graph, first=first)
        candidates.append(score("min_fill", tri.order))
        if planner == "cost" and len(graph.variables) > 1:
            candidates.append(score(
                "greedy", greedy_order(model, graph.variables, first)))
            for order in beam_orders(model, graph.variables, first,
                                     beam_width=beam_width)[:1]:
                candidates.append(score("beam", order))
        # dedupe identical orders, keep first source naming it
        seen: Dict[Tuple[str, ...], OrderCandidate] = {}
        for c in candidates:
            seen.setdefault(c.order, c)
        candidates = list(seen.values())
        # ties break first toward MORE reusable (cached) steps, then toward
        # the paper's structural heuristic
        chosen = min(candidates,
                     key=lambda c: (c.cost, -sims[c.order][2],
                                    c.source != "min_fill"))

    steps, total, _ = sims[chosen.order]
    steps = list(steps)
    source = chosen.source

    # hypertree-decomposed hybrid candidate: WCOJ bag steps over the
    # cyclic core, GJ elimination over the bag marginals for the spine.
    # Gated to monolithic plans (bag potentials are built whole) and to
    # structurally cyclic queries (propose_decomposition returns no bags
    # otherwise, keeping acyclic signatures byte-unchanged).
    bags: Tuple[BagStep, ...] = ()
    if hybrid is not False and partitions == 1:
        cand_bags, cand_steps, cand_total = propose_decomposition(
            model, logical, chosen.order)
        if cand_bags:
            candidates = list(candidates) + [
                OrderCandidate("hybrid", chosen.order, cand_total)]
            if hybrid is True or cand_total < total:
                bags, steps, total = cand_bags, cand_steps, cand_total
                source = "hybrid"
        elif hybrid is True:
            raise ValueError(
                f"hybrid=True requires a structurally cyclic query; "
                f"{query.name!r} admits no multiway bag (a pure-GJ plan "
                "is already hypertree-optimal on acyclic queries)")

    # distinct-key estimate only (a lower bound on materialized rows —
    # bucket/fac multiplicities are unknown at plan time); the executor
    # re-checks the exact join_size before materializing, so "inmem" here
    # is a hint, never a commitment to an in-memory blow-up
    est_rows = max((s.message_entries for s in steps), default=0.0)
    backends = _select_backends()
    if generation_backend is not None:
        backends["summarize"] = generation_backend
    if partitions > 1:
        # jax-free import: dist.partition keeps its device imports lazy
        from repro.dist.partition import (choose_partition_fold,
                                          choose_partition_var)
        if partition_var is None:
            partition_var = choose_partition_var(
                steps, chosen.order, stats=logical.stats,
                partitions=partitions)
        elif partition_var not in graph.variables:
            raise ValueError(
                f"partition variable {partition_var!r} is not a query "
                f"variable (have: {sorted(graph.variables)})")
        if partition_fold is None:
            partition_fold = choose_partition_fold(
                logical.stats, partition_var, partitions)
    physical = PhysicalPlan(
        query_name=query.name,
        order=chosen.order,
        early_projection=early_projection,
        backends=backends,
        materialize="stream" if est_rows > STREAM_THRESHOLD else "inmem",
        source=source,
        est_cost=total,
        steps=tuple(steps),
        alternatives=tuple(sorted(candidates, key=lambda c: c.cost)),
        planner="forced" if elimination_order is not None else planner,
        search_seconds=time.perf_counter() - t0,
        partitions=partitions,
        partition_var=partition_var,
        partition_fold=partition_fold if partition_fold else 1,
        shard_executor=shard_executor if shard_executor else "thread",
        bags=bags,
    )
    return logical, physical
