"""Join-plan IR: logical → physical planning and plan-driven execution.

    enc = encode_query(catalog, query)
    logical, physical = plan_query(enc)          # cost-based order search
    print(physical.explain())
    ex = Executor(catalog, query, plan=physical) # or let Executor plan
    gfjs = ex.run()

`repro.core.api.GraphicalJoin` is a thin facade over this package.
"""

from repro.plan.cost import CostModel, StepEstimate
from repro.plan.executor import Executor
from repro.plan.ir import LogicalPlan, OrderCandidate, PhysicalPlan
from repro.plan.search import (beam_orders, build_logical_plan, greedy_order,
                               plan_query)
from repro.plan.stats import FactorStats, QueryStats

__all__ = [
    "CostModel", "StepEstimate", "Executor", "LogicalPlan", "OrderCandidate",
    "PhysicalPlan", "beam_orders", "build_logical_plan", "greedy_order",
    "plan_query", "FactorStats", "QueryStats",
]
