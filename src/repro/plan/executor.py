"""Executor — runs a PhysicalPlan phase by phase.

The Figure 4 pipeline, with every choice read off the plan instead of being
hardwired: elimination order, early-projection split, desummarize backend
(numpy `np.repeat` vs the `expand_gather` Pallas wrapper from
`repro/kernels`), streaming vs in-memory materialization.  Per-phase wall
times land in ``timings`` (same keys `GraphicalJoin` always exposed, plus
``"plan"``), and ``explain()`` renders the plan annotated with whatever has
actually been measured so far.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.core.elimination import Generator, build_generator
from repro.core.gfjs import (GFJS, ShardedGFJS, desummarize,
                             desummarize_range, generate_gfjs,
                             stream_desummarize)
from repro.obs.metrics import REGISTRY, MetricsRegistry, TimingsView
from repro.obs.trace import (Tracer, ambient_tracer, span as obs_span,
                             span_in)
from repro.plan.ir import LogicalPlan, PhysicalPlan
from repro.plan.search import plan_query
from repro.plan.stats import QueryStats
from repro.relational.encoding import EncodedQuery, encode_query
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog


class Executor:
    """Drive one query through encode → plan → generator → summarize."""

    def __init__(self, catalog: Catalog, query: JoinQuery, *,
                 elimination_order: Optional[Sequence[str]] = None,
                 early_projection: bool = True,
                 planner: str = "cost",
                 plan: Optional[PhysicalPlan] = None,
                 record_trace: bool = False,
                 generation_backend: Optional[str] = None,
                 partitions: Optional[int] = None,
                 partition_var: Optional[str] = None,
                 partition_fold: Optional[int] = None,
                 shard_executor: Optional[str] = None,
                 shard_timeout: Optional[float] = None,
                 hybrid: Optional[bool] = None,
                 message_cache=None,
                 corrections: Optional[Dict[str, float]] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None) -> None:
        self.catalog = catalog
        self.query = query
        # observability: spans land on ``tracer`` (or whatever tracer is
        # ambient at call time — benchmarks activate one around a section);
        # phase timings mirror into ``metrics`` histograms via TimingsView
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else REGISTRY
        self.elimination_order = elimination_order
        self.early_projection = early_projection
        self.planner = planner
        self.record_trace = record_trace
        # pins plan.backends["summarize"]: "numpy" (dynamic-shape oracle) or
        # "jax" (device-resident generate_gfjs_jax); None = environment pick
        self.generation_backend = generation_backend
        # hash-partitioned execution (repro/dist/partition.py): > 1 makes
        # summarize() produce a ShardedGFJS; the trace/incremental path is
        # unsupported there (refresh falls back to rebuild), so combining
        # them is refused up front — a silent no-trace run would surface
        # only as a misleading capture_state error much later
        self.partitions = partitions
        self.partition_var = partition_var
        # process-parallel shards (repro/dist/actions.py): "process" sends
        # shard builds to the spawn-based worker pool; fold over-partitions
        # for skew smoothing; shard_timeout (seconds) bounds each action
        # before the degrade-to-thread retry — a runtime knob, not plan
        # identity, so it lives here and not on the PhysicalPlan
        self.partition_fold = partition_fold
        self.shard_executor = shard_executor
        self.shard_timeout = shard_timeout
        # hypertree-decomposed hybrid GJ/WCOJ execution (DESIGN §19):
        # None = let the cost model pick, True = force bags on a cyclic
        # query, False = pure GJ.  Bag potentials merge several table
        # occurrences, which the splice-based incremental refresher cannot
        # replay, so record_trace forces the pure-GJ plan: an implicit
        # (cost-picked) hybrid silently degrades to pure GJ, an explicit
        # hybrid=True conflict is refused up front
        self.hybrid = hybrid
        # cross-query message reuse (repro/summary/msgcache.py): probed per
        # elimination step under the plan-time subtree fingerprints.  Only
        # monolithic, traceless, bagless builds participate — the other
        # shapes refuse reuse (DESIGN §20) and simply bypass the cache.
        self.message_cache = message_cache
        # calibration factors loaded from a prior session (JoinService's
        # sidecar); used to price the plan search and rendered as
        # ``calib(loaded)=`` until this run measures its own drift
        self.corrections = dict(corrections) if corrections else None
        if record_trace and hybrid is True:
            raise ValueError(
                "record_trace is unsupported with hybrid=True: bag "
                "potentials merge table occurrences, breaking the "
                "per-occurrence wiring incremental refresh replays")
        if record_trace and plan is not None and plan.bags:
            raise ValueError(
                "record_trace is unsupported for a pre-compiled plan with "
                "bag steps (see hybrid=True)")
        if record_trace and (
                (partitions is not None and partitions > 1)
                or (plan is not None and plan.partitions > 1)):
            raise ValueError(
                "record_trace is unsupported under a partitioned plan: "
                "splice-based incremental refresh does not understand "
                "shard structure (partitioned summaries rebuild on append)")
        self.timings: Dict[str, float] = TimingsView(self.metrics)
        self.enc: Optional[EncodedQuery] = None
        self.logical: Optional[LogicalPlan] = None
        self.plan: Optional[PhysicalPlan] = plan
        self._forced_plan = plan is not None
        self.generator: Optional[Generator] = None
        # partitioned runs have no monolithic generator to memoize, so the
        # merged summary itself is cached (cleared with the other phase
        # products on build_model re-entry) — join_size()/aggregate()/
        # explain() after run() must not pay the k-shard build again
        self._sharded: Optional[ShardedGFJS] = None
        # per-level (src, cidx) gather indices from the last summarize —
        # captured under record_trace for incremental refresh splicing
        self.expansion_cache = None
        self.refresh_report: Dict[str, float] = {}
        # content versions of the tables actually encoded by build_model
        self.source_versions: Optional[Dict[str, str]] = None
        # plan feedback: measured per-step product sizes and wall times
        # from the last generator build.  Partitioned runs keep the FULL
        # per-shard picture: ``step_actuals`` sums over shards (shards
        # partition the monolithic product exactly), ``step_seconds`` is
        # the per-step max (critical path of a device-parallel deploy),
        # ``step_seconds_sum`` the total work, and ``shard_report`` the
        # per-shard matrix + walls + skew + stragglers that
        # explain(analyze=True) renders
        self.step_actuals: Dict[str, float] = {}
        self.step_seconds: Dict[str, float] = {}
        self.step_seconds_sum: Dict[str, float] = {}
        self.shard_report: Optional[Dict[str, Any]] = None
        # hybrid plans: measured bag products / wall times, keyed by bag
        # index in plan.bags (same feedback role as step_actuals)
        self.bag_actuals: Dict[int, float] = {}
        self.bag_seconds: Dict[int, float] = {}
        # variables served from the message cache by the last generator
        # build (explain() renders cached=hit for them)
        self.cached_steps: Tuple[str, ...] = ()

    # -- observability plumbing --------------------------------------------
    def _phase(self, name: str, **args: Any):
        """A ``phase:<name>`` span on this executor's tracer, the ambient
        tracer, or the shared no-op — in that order."""
        if self.tracer is not None:
            return self.tracer.span(f"phase:{name}", cat="phase", **args)
        return obs_span(f"phase:{name}", cat="phase", **args)

    # -- phases ------------------------------------------------------------
    def build_model(self) -> "Executor":
        """Qualitative + quantitative learning (encode; potentials lazily).

        Re-entry resets every downstream phase product — a re-encoded query
        must never reuse a generator or plan built on the previous encoding.

        The base tables are snapshotted once up front (Table objects are
        immutable) and ``source_versions`` records exactly what was
        encoded: a concurrent append replacing a catalog entry mid-build
        can therefore never skew the provenance an incremental refresh
        later chains its deltas from.
        """
        self._reset_downstream()
        with self._phase("build_model"):
            t0 = time.perf_counter()
            snapshot = {qt.table: self.catalog[qt.table]
                        for qt in self.query.tables}
            self.enc = encode_query(Catalog(dict(snapshot)), self.query)
            self.source_versions = {n: t.version()
                                    for n, t in snapshot.items()}
            self.timings["build_model"] = time.perf_counter() - t0
        return self

    def _reset_downstream(self) -> None:
        self.enc = None
        self.logical = None
        self.generator = None
        self._sharded = None
        self.expansion_cache = None
        self.step_actuals = {}
        self.step_seconds = {}
        self.step_seconds_sum = {}
        self.shard_report = None
        self.bag_actuals = {}
        self.bag_seconds = {}
        self.cached_steps = ()
        if not self._forced_plan:
            self.plan = None
        self.timings = TimingsView(self.metrics)

    def build_plan(self) -> PhysicalPlan:
        """Logical plan + order search + physical pinning (cached)."""
        if self.enc is None:
            self.build_model()
        if self.plan is not None and self.logical is not None:
            return self.plan
        with self._phase("plan", planner=self.planner):
            return self._build_plan_inner()

    def _build_plan_inner(self) -> PhysicalPlan:
        t0 = time.perf_counter()
        if self.plan is not None:
            # pre-compiled plan: every choice is already pinned, so skip
            # the statistics pass (degree-vector bincounts) and the search
            # entirely — build only the potentials the generator needs and
            # hand them to the shared logical-plan constructor.  Under a
            # partitioned plan even those are skipped: each shard derives
            # its own potentials from the shard slice, so monolithic
            # factors would be built and never read.
            from repro.core.potentials import Factor
            from repro.plan.search import build_logical_plan
            sizes = self.enc.domain_sizes()
            factors = [] if self.plan.partitions > 1 else \
                [Factor.from_columns(cols, sizes)
                 for cols in self.enc.encoded_tables]
            self.logical = build_logical_plan(
                self.enc, early_projection=self.plan.early_projection,
                stats=QueryStats(sizes, factors, []))
        else:
            self.logical, self.plan = plan_query(
                self.enc,
                elimination_order=self.elimination_order,
                early_projection=self.early_projection,
                planner=self.planner,
                generation_backend=self.generation_backend,
                partitions=self.partitions,
                partition_var=self.partition_var,
                partition_fold=self.partition_fold,
                shard_executor=self.shard_executor,
                # trace capability wins over a cost-picked hybrid (an
                # explicit hybrid=True conflict was refused in __init__)
                hybrid=False if self.record_trace else self.hybrid,
                corrections=self.corrections,
                # residency pricing: only builds that can actually consume
                # cached messages may let residency steer the order choice
                message_cache=(None if self.record_trace
                               else self.message_cache),
                table_versions=self.source_versions)
        self.timings["plan"] = time.perf_counter() - t0
        return self.plan

    def build_generator(self) -> "Executor":
        plan = self.build_plan()
        with self._phase("build_generator"):
            t0 = time.perf_counter()
            msg_fps = msg_sources = None
            if (self.message_cache is not None and not self.record_trace
                    and not plan.bags and plan.partitions == 1):
                from repro.plan.ir import step_fingerprints
                msg_fps, msg_sources = step_fingerprints(
                    self.enc, plan.order, self.enc.query.output_variables,
                    self.source_versions)
            self.generator = build_generator(
                self.enc,
                elimination_order=list(plan.order),
                early_projection=plan.early_projection,
                # a partitioned pre-compiled plan carries no monolithic stats
                # factors; None lets build_generator derive its own
                factors=list(self.logical.stats.factors) or None,
                record_trace=self.record_trace,
                step_estimates={s.var: s.product_entries for s in plan.steps},
                bags=plan.bags or None,
                bag_estimates={j: b.est_entries
                               for j, b in enumerate(plan.bags)},
                message_cache=self.message_cache if msg_fps else None,
                step_fingerprints=msg_fps,
                step_sources=msg_sources,
            )
            self.step_actuals = {v: float(n) for v, n
                                 in self.generator.step_products.items()}
            self.step_seconds = dict(self.generator.step_seconds)
            self.step_seconds_sum = dict(self.generator.step_seconds)
            self.bag_actuals = {j: float(n) for j, n
                                in self.generator.bag_products.items()}
            self.bag_seconds = dict(self.generator.bag_seconds)
            self.cached_steps = tuple(self.generator.cached_steps)
            self.timings["build_generator"] = time.perf_counter() - t0
        return self

    def summarize(self) -> Union[GFJS, ShardedGFJS]:
        plan = self.build_plan()
        if plan.partitions > 1:
            return self._summarize_partitioned(plan)
        if self.generator is None:
            self.build_generator()
        backend = (self.plan.backends.get("summarize", "numpy")
                   if self.plan is not None else "numpy")
        with self._phase("summarize", backend=backend):
            t0 = time.perf_counter()
            if self.record_trace:
                # trace capture needs the host (src, cidx) gather indices
                # that splice-based incremental refresh replays — numpy only
                self.expansion_cache = []
                gfjs = generate_gfjs(self.generator, self.enc.domains,
                                     self.expansion_cache)
            elif backend == "jax":
                from repro.core.engine_jax import generate_gfjs_jax
                gfjs = generate_gfjs_jax(self.generator, self.enc.domains)
            else:
                gfjs = generate_gfjs(self.generator, self.enc.domains)
            self.timings["summarize"] = time.perf_counter() - t0
        return gfjs

    def _summarize_partitioned(self, plan: PhysicalPlan) -> ShardedGFJS:
        """Hash-partitioned build: independent shard pipelines, merged view.

        Each shard gets its own generator + GFJS over the shard's slice of
        the partitioned potentials (replicated potentials are shared by
        reference); shards run concurrently — with the jax generation
        backend each shard's device work overlaps, on numpy the win is the
        sharded (smaller) per-step products.  ``record_trace`` is ignored:
        the splice-based incremental refresher does not understand shard
        structure, so partitioned summaries fall back to rebuild on
        appends (the service handles that transparently).

        Per-step actuals are *summed* over shards (the shards partition
        the monolithic product exactly).  Per-step seconds keep the FULL
        per-shard matrix (``shard_report["step_seconds"]``), exposed two
        ways: ``step_seconds`` is the per-step max (the critical path of a
        device-parallel deployment), ``step_seconds_sum`` the total work.
        Shard spans are opened from worker threads with the summarize
        phase span handed across explicitly (ambient context never
        crosses the pool boundary).

        ``plan.partition_fold`` > 1 cuts ``partitions * fold`` *virtual*
        shards: the pool still runs ``partitions`` workers, and free
        workers pulling queued shards is the fold that smooths hash skew
        (DESIGN §17).  ``plan.shard_executor == "process"`` dispatches the
        virtual shards to the repro/dist/actions.py spawn pool instead of
        the thread pool — except under the jax backend, where device work
        already overlaps across threads and a second process would mean a
        second XLA runtime.  Worker span records are grafted under the
        summarize phase span and worker metrics merged into this
        executor's registry, so explain(analyze=True)/shard_report keep
        the same shape on every path.
        """
        if plan.bags:
            # plan_query refuses hybrid + partitions; this catches
            # hand-built plans arriving through the pre-compiled path
            raise ValueError(
                "hypertree bag steps are unsupported under a partitioned "
                "plan: bag potentials are built monolithically")
        if self._sharded is not None:
            return self._sharded
        from repro.dist.partition import PartitionScheme, partition_encoded
        nshards = plan.partitions * max(1, plan.partition_fold)
        with self._phase("partition", partitions=plan.partitions,
                         partition_var=plan.partition_var,
                         fold=plan.partition_fold):
            t0 = time.perf_counter()
            scheme = PartitionScheme(plan.partition_var, nshards)
            shard_encs = partition_encoded(self.enc, scheme)
            self.timings["partition"] = time.perf_counter() - t0

        backend = plan.backends.get("summarize", "numpy")
        order = list(plan.order)
        # expected per-shard product: the shards partition the monolithic
        # product exactly, so 1/nshards of the planner estimate per step
        shard_est = {s.var: s.product_entries / nshards
                     for s in plan.steps}
        use_process = plan.shard_executor == "process" and backend != "jax"

        with self._phase("summarize", backend=backend,
                         partitions=plan.partitions,
                         executor=plan.shard_executor) as parent_sp:
            tracer = self.tracer if self.tracer is not None \
                else ambient_tracer()
            t1 = time.perf_counter()
            if use_process:
                shards, shard_walls, shard_matrix, shard_spans, \
                    shard_products, retries = self._run_shards_process(
                        plan, shard_encs, order, shard_est, parent_sp,
                        tracer)
            else:
                shards, shard_walls, shard_matrix, shard_spans, \
                    shard_products, retries = self._run_shards_thread(
                        plan, shard_encs, order, shard_est, backend,
                        parent_sp, tracer)

            self.step_actuals = {}
            self.step_seconds = {}
            self.step_seconds_sum = {}
            for products, seconds in zip(shard_products, shard_matrix):
                for v, n in products.items():
                    self.step_actuals[v] = \
                        self.step_actuals.get(v, 0.0) + float(n)
                for v, dt in seconds.items():
                    self.step_seconds[v] = \
                        max(self.step_seconds.get(v, 0.0), dt)
                    self.step_seconds_sum[v] = \
                        self.step_seconds_sum.get(v, 0.0) + dt
            sharded = ShardedGFJS(
                shards=shards,
                column_order=list(shards[0].column_order),
                join_size=int(sum(s.join_size for s in shards)),
                domains=self.enc.domains,
                partition_var=scheme.var,
                salt=scheme.salt,
            )
            self.timings["summarize"] = time.perf_counter() - t1
            self.shard_report = self._make_shard_report(
                sharded, shard_walls, shard_matrix, shard_spans,
                workers=plan.partitions,
                executor="process" if use_process else "thread",
                retries=retries)
        self._sharded = sharded
        return sharded

    def _run_shards_thread(self, plan, shard_encs, order, shard_est,
                           backend, parent_sp, tracer):
        """The GIL-sharing pool: ``partitions`` worker threads pull the
        (possibly over-partitioned) shard queue."""

        def run_shard(item):
            i, enc_s = item
            t_s = time.perf_counter()
            with span_in(tracer, parent_sp, f"shard:{i}", cat="shard",
                         shard=i) as sp:
                gen = build_generator(
                    enc_s, elimination_order=order,
                    early_projection=plan.early_projection,
                    step_estimates=shard_est)
                if backend == "jax":
                    from repro.core.engine_jax import generate_gfjs_jax
                    gfjs = generate_gfjs_jax(gen, enc_s.domains)
                else:
                    gfjs = generate_gfjs(gen, enc_s.domains)
                sp.set(rows=gfjs.join_size)
            return gen, gfjs, time.perf_counter() - t_s, sp

        with ThreadPoolExecutor(max_workers=plan.partitions) as pool:
            results = list(pool.map(run_shard, enumerate(shard_encs)))
        return ([gfjs for _, gfjs, _, _ in results],
                [w for _, _, w, _ in results],
                [dict(g.step_seconds) for g, _, _, _ in results],
                [sp for _, _, _, sp in results],
                [dict(g.step_products) for g, _, _, _ in results],
                0)

    def _run_shards_process(self, plan, shard_encs, order, shard_est,
                            parent_sp, tracer):
        """Dispatch shard builds to the repro/dist/actions.py spawn pool.

        One :class:`ShardBuildAction` per virtual shard; the shared
        persistent pool runs ``plan.partitions`` worker processes.  Each
        reply's span records are grafted under the summarize phase span —
        rebased so the worker's root lands at its observed completion time
        (worker and coordinator ``perf_counter`` epochs are otherwise
        incomparable) — and its metrics snapshot is merged, so the
        analyze/report surface matches the thread path shape-for-shape.
        A failed or timed-out worker already came back via the inline
        thread retry inside the pool (degrade, don't kill the query).
        """
        from repro.dist.actions import (ShardBuildAction,
                                        shared_shard_executor)
        from repro.obs.trace import NULL_SPAN
        actions = [
            ShardBuildAction(shard=i, enc=enc_s, order=tuple(order),
                             early_projection=plan.early_projection,
                             backend="numpy", step_estimates=shard_est)
            for i, enc_s in enumerate(shard_encs)]
        pool = shared_shard_executor(plan.partitions)
        outcomes = pool.run(actions, timeout=self.shard_timeout)

        shards, walls, matrix, spans, products = [], [], [], [], []
        retries = 0
        for out in outcomes:
            res = out.result
            retries += 1 if out.retried else 0
            shards.append(res.gfjs)
            walls.append(res.build_seconds)
            matrix.append(dict(res.step_seconds))
            products.append(dict(res.step_products))
            if res.metrics:
                self.metrics.merge(res.metrics)
            root = NULL_SPAN
            if tracer is not None and res.spans:
                # the worker's root span is its last-closed record; rebase
                # so it ends at the observed completion instant (graft
                # ignores a non-Span parent, so NULL_SPAN is safe)
                offset = out.t_done - float(res.spans[-1]["t1"])
                grafted = tracer.graft(res.spans, parent=parent_sp,
                                       offset=offset)
                root = grafted[-1]
                root.set(retried=out.retried)
            spans.append(root)
        return shards, walls, matrix, spans, products, retries

    def _make_shard_report(self, sharded: ShardedGFJS,
                           walls: List[float],
                           matrix: List[Dict[str, float]],
                           spans: List[Any], *,
                           workers: Optional[int] = None,
                           executor: str = "thread",
                           retries: int = 0) -> Dict[str, Any]:
        """Per-shard breakdown + skew + stragglers (satellite of the old
        lossy max-reduction): this is what explain(analyze=True) renders
        and what dist_bench derives its skew numbers from.

        Skew is computed over per-*worker* loads: the (possibly
        over-partitioned) virtual-shard sizes/walls are folded onto
        ``workers`` bins first (repro/dist/partition.py::fold_loads — the
        same LPT model the planner used to pick the fold), so fold=1
        degenerates to the old per-shard skew and fold>1 reports the
        balance the pool actually achieves, not the raw hash spread.
        """
        from repro.dist.partition import fold_loads
        from repro.ft.straggler import flag_shard_stragglers
        workers = len(sharded.shards) if workers is None else workers
        sizes = [int(s.join_size) for s in sharded.shards]
        w_sizes = fold_loads(sizes, workers)
        w_walls = fold_loads(walls, workers)
        mean_size = float(w_sizes.mean()) if len(w_sizes) else 0.0
        mean_wall = float(w_walls.mean()) if len(w_walls) else 0.0
        skew = float(w_sizes.max()) / mean_size if mean_size > 0 else 1.0
        time_skew = float(w_walls.max()) / mean_wall if mean_wall > 0 else 1.0
        stragglers = flag_shard_stragglers(walls)
        straggler_ids = {s.shard for s in stragglers}
        for i, sp in enumerate(spans):
            sp.set(wall_seconds=walls[i], straggler=i in straggler_ids)
        self.metrics.gauge("dist.shard_skew", unit="x").set(skew)
        self.metrics.gauge("dist.time_skew", unit="x").set(time_skew)
        if stragglers:
            self.metrics.counter("dist.stragglers").inc(len(stragglers))
        if retries:
            self.metrics.counter("dist.shard_degraded").inc(retries)
        for w in walls:
            self.metrics.histogram("dist.shard_seconds", unit="s").observe(w)
        return {
            "sizes": sizes,
            "seconds": list(walls),
            "step_seconds": matrix,
            "skew": skew,
            "time_skew": time_skew,
            "stragglers": stragglers,
            "executor": executor,
            "workers": workers,
            "retries": retries,
        }

    def run(self) -> Union[GFJS, ShardedGFJS]:
        return self.summarize()

    # -- incremental refresh ----------------------------------------------
    def capture_state(self, gfjs: GFJS, versions=None):
        """Snapshot this run for later delta refreshes (record_trace only)."""
        from repro.summary.incremental import capture_state
        return capture_state(self, gfjs, versions=versions)

    def refresh(self, state, deltas) -> "IncrementalState":
        """The ``refresh`` phase: apply appends to a captured state.

        Re-encodes only the appended blocks, re-runs only the dirty
        elimination steps, and splices the result into the retained
        summary structure.  Wall time lands in ``timings["refresh"]`` so
        benchmarks can put rebuild and refresh side by side; the refreshed
        generator is adopted so ``desummarize``/``explain`` keep working.
        """
        from repro.summary.incremental import refresh_state
        if not isinstance(deltas, (list, tuple)):
            deltas = [deltas]
        with self._phase("refresh"):
            t0 = time.perf_counter()
            new_state, report = refresh_state(state, deltas)
            self.timings["refresh"] = time.perf_counter() - t0
        self.generator = new_state.generator
        self.expansion_cache = new_state.expansion_cache
        self.source_versions = dict(new_state.table_versions)
        if self.enc is not None:
            # domains advance with the refresh so summarize()/desummarize
            # decode through the grown dictionaries; the encoded base
            # columns are NOT re-read (the refresher never rescans them) —
            # re-enter build_model to re-derive them if needed
            self.enc = EncodedQuery(self.enc.query, new_state.domains,
                                    self.enc.encoded_tables)
        self.refresh_report = report
        return new_state

    # -- plan-directed materialization ------------------------------------
    def desummarize(self, gfjs: Union[GFJS, ShardedGFJS], *,
                    decode: bool = True) -> Dict[str, np.ndarray]:
        """Full expansion on the plan's backend.

        Sharded summaries expand shard by shard (each through the pinned
        backend) and concatenate in shard order.
        """
        backend = (self.plan.backends.get("desummarize", "numpy")
                   if self.plan is not None else "numpy")
        with self._phase("desummarize", backend=backend,
                         rows=gfjs.join_size):
            t0 = time.perf_counter()
            if backend == "jax" and isinstance(gfjs, ShardedGFJS):
                parts = [_desummarize_jax(s, decode=decode)
                         for s in gfjs.shards]
                out = {v: np.concatenate([p[v] for p in parts])
                       for v in gfjs.column_order}
            elif backend == "jax":
                out = _desummarize_jax(gfjs, decode=decode)
            else:
                out = desummarize(gfjs, decode=decode)  # dispatches on shape
            self.timings["desummarize"] = time.perf_counter() - t0
        return out

    def materialize(self, gfjs: Union[GFJS, ShardedGFJS], *,
                    decode: bool = True,
                    chunk_rows: int = 1 << 20
                    ) -> Union[Dict[str, np.ndarray],
                               Iterator[Dict[str, np.ndarray]]]:
        """In-memory dict or a row-chunk iterator.

        The plan's pinned choice is a *hint* from distinct-key estimates;
        the actual join size (frequency-weighted, known exactly once the
        summary exists) makes the final call — a duplication-heavy join
        can be orders of magnitude larger than its run count, and it must
        stream regardless of what the planner guessed.
        """
        from repro.plan.search import STREAM_THRESHOLD
        plan_streams = self.plan is not None and \
            self.plan.materialize == "stream"
        if plan_streams or gfjs.join_size > STREAM_THRESHOLD:
            return stream_desummarize(gfjs, chunk_rows, decode=decode)
        return self.desummarize(gfjs, decode=decode)

    # -- observability -----------------------------------------------------
    def calibration(self) -> Dict[str, float]:
        """Per-op correction factors from the last build's est-vs-actual
        drift (geometric mean of actual/est, per CostModel.drift_factor).

        ``{"eliminate": ..., "bag": ...}`` — keys appear only once the
        matching step kind has actually run.  Feed the dict into
        ``CostModel(stats, corrections=...)`` (or ``CostModel.calibrate``)
        to price future plans with measured reality, and into
        ``explain()``'s calibration section (rendered automatically)."""
        from repro.plan.cost import CostModel
        plan = self.plan
        if plan is None:
            return {}
        out: Dict[str, float] = {}
        if self.step_actuals:
            est = {s.var: float(s.product_entries) for s in plan.steps}
            out["eliminate"] = CostModel.drift_factor(est, self.step_actuals)
        if self.bag_actuals:
            est = {j: float(b.est_entries) for j, b in enumerate(plan.bags)}
            out["bag"] = CostModel.drift_factor(est, self.bag_actuals)
        return out

    def explain(self, *, analyze: bool = False) -> str:
        """Render the plan; ``analyze=True`` adds everything measured —
        per-step seconds (max and summed over shards), per-bag WCOJ
        products and drift, calibration factors, the per-shard breakdown
        (never the lossy max-reduction), and stragglers."""
        plan = self.build_plan()
        calibration = self.calibration() or None
        calibration_source = "measured"
        if calibration is None and self.corrections:
            # nothing measured yet this run: render the factors a prior
            # session persisted (JoinService's calibration sidecar)
            calibration = dict(self.corrections)
            calibration_source = "loaded"
        cached = self.cached_steps or None
        if not analyze:
            return plan.explain(timings=self.timings,
                                actuals=self.step_actuals,
                                bag_actuals=self.bag_actuals,
                                calibration=calibration,
                                calibration_source=calibration_source,
                                cached_steps=cached)
        return plan.explain(timings=self.timings, actuals=self.step_actuals,
                            step_seconds=self.step_seconds,
                            step_seconds_sum=self.step_seconds_sum,
                            shard_report=self.shard_report,
                            bag_actuals=self.bag_actuals,
                            bag_seconds=self.bag_seconds,
                            calibration=calibration,
                            calibration_source=calibration_source,
                            cached_steps=cached)


_I32_MAX = (1 << 31) - 1


def _desummarize_jax(gfjs: GFJS, *, decode: bool = True
                     ) -> Dict[str, np.ndarray]:
    """RLE expansion through the fused per-level kernel path.

    Delegates to `engine_jax.desummarize_jax` — one `expand_gather_many`
    launch per level with memoized launch metadata; levels with codes past
    the int32 range fall back to numpy inside it.  A join size past the
    int32 kernel range expands fully on numpy instead of raising: the
    plan's backend choice is a hint, never a hard capability claim.
    """
    if gfjs.join_size > _I32_MAX:
        return desummarize(gfjs, decode=decode)
    from repro.core.engine_jax import desummarize_jax
    return desummarize_jax(gfjs, decode=decode)
