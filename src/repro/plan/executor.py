"""Executor — runs a PhysicalPlan phase by phase.

The Figure 4 pipeline, with every choice read off the plan instead of being
hardwired: elimination order, early-projection split, desummarize backend
(numpy `np.repeat` vs the `expand_gather` Pallas wrapper from
`repro/kernels`), streaming vs in-memory materialization.  Per-phase wall
times land in ``timings`` (same keys `GraphicalJoin` always exposed, plus
``"plan"``), and ``explain()`` renders the plan annotated with whatever has
actually been measured so far.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, Optional, Sequence, Union

import numpy as np

from repro.core.elimination import Generator, build_generator
from repro.core.gfjs import (GFJS, desummarize, desummarize_range,
                             generate_gfjs, stream_desummarize)
from repro.plan.ir import LogicalPlan, PhysicalPlan
from repro.plan.search import plan_query
from repro.plan.stats import QueryStats
from repro.relational.encoding import EncodedQuery, encode_query
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog


class Executor:
    """Drive one query through encode → plan → generator → summarize."""

    def __init__(self, catalog: Catalog, query: JoinQuery, *,
                 elimination_order: Optional[Sequence[str]] = None,
                 early_projection: bool = True,
                 planner: str = "cost",
                 plan: Optional[PhysicalPlan] = None,
                 record_trace: bool = False,
                 generation_backend: Optional[str] = None) -> None:
        self.catalog = catalog
        self.query = query
        self.elimination_order = elimination_order
        self.early_projection = early_projection
        self.planner = planner
        self.record_trace = record_trace
        # pins plan.backends["summarize"]: "numpy" (dynamic-shape oracle) or
        # "jax" (device-resident generate_gfjs_jax); None = environment pick
        self.generation_backend = generation_backend
        self.timings: Dict[str, float] = {}
        self.enc: Optional[EncodedQuery] = None
        self.logical: Optional[LogicalPlan] = None
        self.plan: Optional[PhysicalPlan] = plan
        self._forced_plan = plan is not None
        self.generator: Optional[Generator] = None
        # per-level (src, cidx) gather indices from the last summarize —
        # captured under record_trace for incremental refresh splicing
        self.expansion_cache = None
        self.refresh_report: Dict[str, float] = {}
        # content versions of the tables actually encoded by build_model
        self.source_versions: Optional[Dict[str, str]] = None

    # -- phases ------------------------------------------------------------
    def build_model(self) -> "Executor":
        """Qualitative + quantitative learning (encode; potentials lazily).

        Re-entry resets every downstream phase product — a re-encoded query
        must never reuse a generator or plan built on the previous encoding.

        The base tables are snapshotted once up front (Table objects are
        immutable) and ``source_versions`` records exactly what was
        encoded: a concurrent append replacing a catalog entry mid-build
        can therefore never skew the provenance an incremental refresh
        later chains its deltas from.
        """
        self._reset_downstream()
        t0 = time.perf_counter()
        snapshot = {qt.table: self.catalog[qt.table]
                    for qt in self.query.tables}
        self.enc = encode_query(Catalog(dict(snapshot)), self.query)
        self.source_versions = {n: t.version() for n, t in snapshot.items()}
        self.timings = {"build_model": time.perf_counter() - t0}
        return self

    def _reset_downstream(self) -> None:
        self.enc = None
        self.logical = None
        self.generator = None
        self.expansion_cache = None
        if not self._forced_plan:
            self.plan = None
        self.timings = {}

    def build_plan(self) -> PhysicalPlan:
        """Logical plan + order search + physical pinning (cached)."""
        if self.enc is None:
            self.build_model()
        if self.plan is not None and self.logical is not None:
            return self.plan
        t0 = time.perf_counter()
        if self.plan is not None:
            # pre-compiled plan: every choice is already pinned, so skip
            # the statistics pass (degree-vector bincounts) and the search
            # entirely — build only the potentials the generator needs and
            # hand them to the shared logical-plan constructor
            from repro.core.potentials import Factor
            from repro.plan.search import build_logical_plan
            sizes = self.enc.domain_sizes()
            factors = [Factor.from_columns(cols, sizes)
                       for cols in self.enc.encoded_tables]
            self.logical = build_logical_plan(
                self.enc, early_projection=self.plan.early_projection,
                stats=QueryStats(sizes, factors, []))
        else:
            self.logical, self.plan = plan_query(
                self.enc,
                elimination_order=self.elimination_order,
                early_projection=self.early_projection,
                planner=self.planner,
                generation_backend=self.generation_backend)
        self.timings["plan"] = time.perf_counter() - t0
        return self.plan

    def build_generator(self) -> "Executor":
        plan = self.build_plan()
        t0 = time.perf_counter()
        self.generator = build_generator(
            self.enc,
            elimination_order=list(plan.order),
            early_projection=plan.early_projection,
            factors=list(self.logical.stats.factors),
            record_trace=self.record_trace,
        )
        self.timings["build_generator"] = time.perf_counter() - t0
        return self

    def summarize(self) -> GFJS:
        if self.generator is None:
            self.build_generator()
        t0 = time.perf_counter()
        backend = (self.plan.backends.get("summarize", "numpy")
                   if self.plan is not None else "numpy")
        if self.record_trace:
            # trace capture needs the host (src, cidx) gather indices that
            # splice-based incremental refresh replays — numpy only
            self.expansion_cache = []
            gfjs = generate_gfjs(self.generator, self.enc.domains,
                                 self.expansion_cache)
        elif backend == "jax":
            from repro.core.engine_jax import generate_gfjs_jax
            gfjs = generate_gfjs_jax(self.generator, self.enc.domains)
        else:
            gfjs = generate_gfjs(self.generator, self.enc.domains)
        self.timings["summarize"] = time.perf_counter() - t0
        return gfjs

    def run(self) -> GFJS:
        return self.summarize()

    # -- incremental refresh ----------------------------------------------
    def capture_state(self, gfjs: GFJS, versions=None):
        """Snapshot this run for later delta refreshes (record_trace only)."""
        from repro.summary.incremental import capture_state
        return capture_state(self, gfjs, versions=versions)

    def refresh(self, state, deltas) -> "IncrementalState":
        """The ``refresh`` phase: apply appends to a captured state.

        Re-encodes only the appended blocks, re-runs only the dirty
        elimination steps, and splices the result into the retained
        summary structure.  Wall time lands in ``timings["refresh"]`` so
        benchmarks can put rebuild and refresh side by side; the refreshed
        generator is adopted so ``desummarize``/``explain`` keep working.
        """
        from repro.summary.incremental import refresh_state
        if not isinstance(deltas, (list, tuple)):
            deltas = [deltas]
        t0 = time.perf_counter()
        new_state, report = refresh_state(state, deltas)
        self.timings["refresh"] = time.perf_counter() - t0
        self.generator = new_state.generator
        self.expansion_cache = new_state.expansion_cache
        self.source_versions = dict(new_state.table_versions)
        if self.enc is not None:
            # domains advance with the refresh so summarize()/desummarize
            # decode through the grown dictionaries; the encoded base
            # columns are NOT re-read (the refresher never rescans them) —
            # re-enter build_model to re-derive them if needed
            self.enc = EncodedQuery(self.enc.query, new_state.domains,
                                    self.enc.encoded_tables)
        self.refresh_report = report
        return new_state

    # -- plan-directed materialization ------------------------------------
    def desummarize(self, gfjs: GFJS, *, decode: bool = True
                    ) -> Dict[str, np.ndarray]:
        """Full expansion on the plan's backend."""
        t0 = time.perf_counter()
        backend = (self.plan.backends.get("desummarize", "numpy")
                   if self.plan is not None else "numpy")
        if backend == "jax":
            out = _desummarize_jax(gfjs, decode=decode)
        else:
            out = desummarize(gfjs, decode=decode)
        self.timings["desummarize"] = time.perf_counter() - t0
        return out

    def materialize(self, gfjs: GFJS, *, decode: bool = True,
                    chunk_rows: int = 1 << 20
                    ) -> Union[Dict[str, np.ndarray],
                               Iterator[Dict[str, np.ndarray]]]:
        """In-memory dict or a row-chunk iterator.

        The plan's pinned choice is a *hint* from distinct-key estimates;
        the actual join size (frequency-weighted, known exactly once the
        summary exists) makes the final call — a duplication-heavy join
        can be orders of magnitude larger than its run count, and it must
        stream regardless of what the planner guessed.
        """
        from repro.plan.search import STREAM_THRESHOLD
        plan_streams = self.plan is not None and \
            self.plan.materialize == "stream"
        if plan_streams or gfjs.join_size > STREAM_THRESHOLD:
            return stream_desummarize(gfjs, chunk_rows, decode=decode)
        return self.desummarize(gfjs, decode=decode)

    # -- observability -----------------------------------------------------
    def explain(self) -> str:
        plan = self.build_plan()
        return plan.explain(timings=self.timings)


_I32_MAX = (1 << 31) - 1


def _desummarize_jax(gfjs: GFJS, *, decode: bool = True
                     ) -> Dict[str, np.ndarray]:
    """RLE expansion through the fused per-level kernel path.

    Delegates to `engine_jax.desummarize_jax` — one `expand_gather_many`
    launch per level with memoized launch metadata; levels with codes past
    the int32 range fall back to numpy inside it.  A join size past the
    int32 kernel range expands fully on numpy instead of raising: the
    plan's backend choice is a hint, never a hard capability claim.
    """
    if gfjs.join_size > _I32_MAX:
        return desummarize(gfjs, decode=decode)
    from repro.core.engine_jax import desummarize_jax
    return desummarize_jax(gfjs, decode=decode)
