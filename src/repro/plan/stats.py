"""Planner statistics — what the encoder already knows, organized for costing.

The cost model never touches base-table rows: everything it needs falls out
of the quantitative-learning factors the pipeline builds anyway (one GROUP BY
per table occurrence, `Factor.from_columns`):

* per-variable **domain sizes** (from the dictionary encoder);
* per-factor **cardinalities** (distinct key rows = factor entries);
* per-(factor, variable) **degree vectors** — `bincount` of the variable's
  codes over its domain.  The dot product of two degree vectors is the
  *exact* entry count of the pairwise factor product on that variable, which
  is what makes the planner skew-aware ("Skew Strikes Back": AGM-style
  bounds that ignore the degree distribution miss exactly the blow-ups GJ
  cares about).

Degree vectors are only materialized for domains up to ``DEGREE_CAP`` codes;
above that the model falls back to (entries, distinct) scalar estimates —
the classic System-R uniformity assumption, now a guarded fallback instead
of the only option.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.potentials import Factor
from repro.relational.encoding import EncodedQuery

DEGREE_CAP = 1 << 22  # max domain size for which we keep a degree vector


@dataclass
class FactorStats:
    """Cheap statistics of one potential (real or simulated intermediate)."""

    vars: Tuple[str, ...]
    entries: float                       # distinct key rows (estimated)
    distinct: Dict[str, float]           # per-var distinct value count
    degrees: Dict[str, np.ndarray]       # per-var degree vector (optional)
    # base tables folded into this (possibly simulated) factor.  Messages
    # accumulate the sources of everything they consumed, so a step's
    # sources are exactly the tables whose appends dirty it — the plan-level
    # dependency map behind PhysicalPlan.dirty_steps().
    sources: FrozenSet[str] = frozenset()

    def has_degrees(self, v: str) -> bool:
        return v in self.degrees

    @staticmethod
    def of(factor: Factor, sizes: Dict[str, int],
           sources: FrozenSet[str] = frozenset()) -> "FactorStats":
        distinct: Dict[str, float] = {}
        degrees: Dict[str, np.ndarray] = {}
        for v in factor.vars:
            col = factor.col(v)
            size = int(sizes.get(v, 0))
            if 0 < size <= DEGREE_CAP:
                deg = np.bincount(col, minlength=size).astype(np.float64) \
                    if len(col) else np.zeros(size, np.float64)
                degrees[v] = deg
                distinct[v] = float(np.count_nonzero(deg))
            else:
                distinct[v] = float(len(np.unique(col)))
        return FactorStats(tuple(factor.vars), float(factor.num_entries),
                           distinct, degrees, sources)


@dataclass
class QueryStats:
    """All planner inputs for one encoded query."""

    sizes: Dict[str, int]                # per-variable domain size
    factors: List[Factor]                # the real potentials (reused later)
    factor_stats: List[FactorStats]

    @staticmethod
    def of(enc: EncodedQuery,
           factors: Optional[Sequence[Factor]] = None) -> "QueryStats":
        sizes = enc.domain_sizes()
        if factors is None:
            factors = [Factor.from_columns(cols, sizes)
                       for cols in enc.encoded_tables]
        fstats = [FactorStats.of(f, sizes, frozenset({qt.table}))
                  for f, qt in zip(factors, enc.query.tables)]
        return QueryStats(sizes, list(factors), fstats)
