"""The join-plan IR: LogicalPlan (what to join) → PhysicalPlan (how).

A :class:`LogicalPlan` is the planner's view of a query: the primal graph,
the projection split (the paper's O' / O), and the statistics bundle.  A
:class:`PhysicalPlan` pins every choice the executor needs — elimination
order, early-projection split, kernel backends, materialization strategy —
plus the cost estimates that justified them, and renders all of it through
``explain()``.

PhysicalPlan identity (``signature()``) covers exactly the fields that
change the produced GFJS or how it is computed; `JoinQuery.fingerprint`
mixes it into the cache key so `SummaryCache`/`JoinService` distinguish
summaries built under different plans.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import QueryGraph
from repro.plan.cost import StepEstimate
from repro.plan.stats import QueryStats
from repro.relational.query import JoinQuery


# -- subtree fingerprints (cross-query message identity) ---------------------
#
# The message an elimination step emits is fully determined by the step's
# *source-potential closure*: the multiset of table occurrences feeding it
# (structure x content version), the dictionary-code spaces of the variables
# involved, the eliminated variable, the separator sequence, and whether a
# psi is kept.  Hashing exactly those ingredients gives a fingerprint under
# which identical subtrees in *different* queries collide by construction,
# and any `Table.append` invalidates by key (the version changes).

def domain_content_ids(enc) -> Dict[str, str]:
    """var -> content hash of its dictionary-encoding domain.

    Dictionary codes are domain-relative: `encode_query` builds each
    variable's domain as the sorted unique union over *all* of its
    occurrences, so a message's integer codes are only meaningful against
    that exact value array.  Hashing the domain content (not the
    contributor set) is both necessary and sufficient — and deliberately
    permissive: a dimension-key variable whose domain is the same value
    set under two different fact tables still matches.
    """
    ids: Dict[str, str] = {}
    for v, dom in enc.domains.items():
        h = hashlib.sha256()
        vals = np.ascontiguousarray(dom.values)
        if vals.dtype.kind == "O":   # object columns: hash the repr stream
            h.update(repr(vals.tolist()).encode())
        else:
            h.update(str(vals.dtype).encode())
            h.update(vals.tobytes())
        ids[v] = h.hexdigest()[:24]
    return ids


def _fp_hash(obj) -> str:
    return hashlib.sha256(
        json.dumps(obj, separators=(",", ":")).encode()).hexdigest()[:40]


def step_fingerprints(
    enc, order: Sequence[str], out_vars: Sequence[str],
    versions: Dict[str, str],
) -> Tuple[Dict[str, str], Dict[str, Tuple[str, ...]]]:
    """Canonical subtree fingerprint per elimination step of ``order``.

    Simulates exactly the working-set bookkeeping of
    `core.elimination.build_generator` (which factors contain a variable is
    structural — data never changes the wiring): each table occurrence
    hashes to (table, content version, sorted (column -> variable-label)
    pairs), and each step hashes to (order-insensitive multiset of input
    fingerprints, eliminated-variable label, separator label sequence *in
    order* — the separator order is what the consumer's factor columns
    follow — and the psi-needed flag).  Variable labels are the
    domain-content ids of :func:`domain_content_ids`, so the fingerprints
    are alias-insensitive; a label that is ambiguous within the query
    (self-joins over identical domains) falls back to including the literal
    name — conservative: loses cross-query hits, never conflates.

    Returns ``(fingerprints, sources)``: var -> fingerprint and var -> the
    base tables in the step's closure (provenance for explicit
    invalidation).  Bagged (hybrid WCOJ) plans must not call this — bag
    potentials merge occurrences outside the step wiring simulated here.
    """
    query = enc.query
    dom = domain_content_ids(enc)
    counts: Dict[str, int] = {}
    for d in dom.values():
        counts[d] = counts.get(d, 0) + 1
    labels = {v: (d if counts[d] == 1 else f"{d}|{v}")
              for v, d in dom.items()}
    out_set = set(out_vars)

    working: List[Tuple[str, frozenset, frozenset]] = []
    for qt in query.tables:
        canon = {
            "table": qt.table,
            "version": versions[qt.table],
            "cols": sorted([c, labels[u]] for c, u in qt.var_map),
        }
        working.append((_fp_hash(canon), frozenset(qt.variables),
                        frozenset((qt.table,))))

    fps: Dict[str, str] = {}
    sources: Dict[str, Tuple[str, ...]] = {}
    for v in order[:-1]:
        rel = [w for w in working if v in w[1]]
        rest = [w for w in working if v not in w[1]]
        if not rel:            # disconnected graph; the executor will raise
            return {}, {}
        scope: set = set()
        tabs: set = set()
        for _, sc, tb in rel:
            scope |= sc
            tabs |= tb
        sep = tuple(u for u in order if u != v and u in scope)
        canon = {
            "op": "eliminate",
            "var": labels[v],
            "inputs": sorted(fp for fp, _, _ in rel),
            "sep": [labels[u] for u in sep],
            "psi": v in out_set,
        }
        fp = _fp_hash(canon)
        fps[v] = fp
        sources[v] = tuple(sorted(tabs))
        working = rest + [(fp, frozenset(sep), frozenset(tabs))]
    return fps, sources


@dataclass
class LogicalPlan:
    """Query graph + projection split + planner statistics."""

    query: JoinQuery
    graph: QueryGraph
    output_vars: Tuple[str, ...]          # the paper's O (generation order src)
    projected_out: Tuple[str, ...]        # the paper's O' (eliminated silently)
    stats: QueryStats

    @property
    def variables(self) -> List[str]:
        return list(self.graph.variables)


@dataclass
class OrderCandidate:
    """One scored elimination order considered by the search."""

    source: str                           # "min_fill" | "greedy" | "beam" | ...
    order: Tuple[str, ...]
    cost: float


@dataclass
class BagStep:
    """One WCOJ multiway bag step of a hybrid GJ/WCOJ plan.

    The executor generic-joins all of the bag's table occurrences at once
    (``core/potential_join.py::multiway_product``, binding ``bind_order``
    level by level with per-level intersection on the smallest potential)
    and feeds the joint potential into ordinary GJ elimination in place of
    its member factors.  ``vars``/``bind_order`` list the bag scope in the
    plan's global elimination order; because the scope is a clique of the
    chosen order's triangulation, the downstream elimination meets exactly
    the same separators as the monolithic build and the final GFJS is
    bit-identical (DESIGN.md §19).
    """

    vars: Tuple[str, ...]          # bag scope, in global elimination order
    occurrences: Tuple[int, ...]   # table-occurrence indices joined here
    bind_order: Tuple[str, ...]    # WCOJ binding order (== vars today)
    est_entries: float = 0.0       # estimated |bag product| (drift anchor)
    est_cost: float = 0.0          # estimated work: sum of level frontiers
    agm_entries: float = 0.0       # AGM fractional-edge-cover bound
    rho: float = 0.0               # fractional edge cover number
    num_factors: int = 0
    tables: Tuple[str, ...] = ()   # base tables feeding the bag

    @property
    def cost(self) -> float:
        return self.est_cost


@dataclass
class PhysicalPlan:
    """Every executable choice, pinned."""

    query_name: str
    order: Tuple[str, ...]
    early_projection: bool
    backends: Dict[str, str]              # phase -> "numpy" | "jax"
    materialize: str                      # "inmem" | "stream"
    source: str                           # which candidate won
    est_cost: float
    steps: Tuple[StepEstimate, ...] = ()
    alternatives: Tuple[OrderCandidate, ...] = ()
    planner: str = "cost"
    search_seconds: float = 0.0
    # hash-partitioned execution (repro/dist/partition.py): split into
    # ``partitions`` shards on hash(partition_var); 1 = monolithic.
    # ``partition_fold`` over-partitions: partitions * fold virtual shards
    # folded back onto ``partitions`` workers (skew smoothing, DESIGN §17);
    # ``shard_executor`` picks where shard pipelines run ("thread" — the
    # GIL-bound pool — or "process": the repro/dist/actions.py worker pool).
    partitions: int = 1
    partition_var: Optional[str] = None
    partition_fold: int = 1
    shard_executor: str = "thread"
    # hypertree-decomposed hybrid execution: WCOJ bag steps pre-joining the
    # cyclic core, then GJ elimination over the bag marginals.  () = pure
    # GJ (every acyclic plan, and cyclic ones where the cost model found
    # no win) — folded into signature() only when non-empty, so existing
    # plans keep their historical signatures and cache keys.
    bags: Tuple[BagStep, ...] = ()

    # -- delta support -----------------------------------------------------
    def dirty_steps(self, table: str) -> Tuple[str, ...]:
        """Variables whose elimination steps an append to ``table`` dirties.

        Each :class:`StepEstimate` carries the base tables feeding it —
        directly (the step consumes one of the table's potentials) or
        transitively (it consumes a message derived from one).  The result
        is therefore the downstream closure in the message-flow DAG: the
        exact set of steps an incremental refresh must recompute; every
        other step's conditional factor and message are reusable as-is.
        """
        return tuple(s.var for s in self.steps if table in s.tables)

    def refresh_fraction(self, table: str) -> float:
        """Estimated share of elimination work an append re-runs (0..1)."""
        total = sum(s.product_entries for s in self.steps)
        if total <= 0.0:
            return 1.0
        dirty = sum(s.product_entries for s in self.steps
                    if table in s.tables)
        return dirty / total

    def admission_cost(self) -> float:
        """Cost of a cold build of this plan, for admission control.

        Sum of the per-step CostModel estimates (product entries touched
        across the elimination; DESIGN §15) divided by the partition
        count — shards run in parallel, so the per-worker critical path
        is what a serving deadline competes with.  Falls back to
        ``est_cost`` when the plan carries no step breakdown (hand-built
        plans).  ``repro.serve.server.JoinServer`` compares this against
        its ``cost_ceiling`` before admitting a cold build.
        """
        total = sum(s.cost for s in self.steps) if self.steps \
            else float(self.est_cost)
        total += sum(b.cost for b in self.bags)
        return total / max(int(self.partitions), 1)

    # -- identity ----------------------------------------------------------
    def signature(self, labels: Optional[Dict[str, str]] = None) -> str:
        """Stable hash of the execution-relevant plan fields.

        Cost estimates, alternatives, and search timings are advisory and
        deliberately excluded: two plans that run the same way hash the
        same even if their statistics were gathered at different times.

        ``labels`` (var -> canonical label, from
        `JoinQuery.canonical_labels`) renames the variables the signature
        embeds, so `fingerprint(plan=...)` can hash alias-renamed twins of
        the same plan identically; identity labels (or None) reproduce the
        historical signature byte-for-byte.
        """
        if labels:
            def lab(v):
                return labels.get(v, v)
        else:
            def lab(v):
                return v
        canon = {
            "order": [lab(v) for v in self.order],
            "early_projection": bool(self.early_projection),
            "backends": dict(sorted(self.backends.items())),
            "materialize": self.materialize,
        }
        if self.partitions > 1:
            # only folded in when actually partitioned, so monolithic plans
            # keep their historical signatures (and spilled cache entries)
            canon["partitions"] = int(self.partitions)
            canon["partition_var"] = (lab(self.partition_var)
                                      if self.partition_var else None)
            canon["partition_fold"] = int(self.partition_fold)
            canon["shard_executor"] = self.shard_executor
        if self.bags:
            # same conditionality: pure-GJ plans (all acyclic queries in
            # particular) keep their historical signatures and cache keys
            canon["bags"] = [[[lab(v) for v in b.vars], list(b.occurrences),
                              [lab(v) for v in b.bind_order]]
                             for b in self.bags]
        return hashlib.sha256(
            json.dumps(canon, separators=(",", ":")).encode()).hexdigest()[:16]

    # -- rendering ---------------------------------------------------------
    def explain(self, timings: Optional[Dict[str, float]] = None,
                actuals: Optional[Dict[str, float]] = None,
                step_seconds: Optional[Dict[str, float]] = None,
                step_seconds_sum: Optional[Dict[str, float]] = None,
                shard_report: Optional[Dict[str, object]] = None,
                bag_actuals: Optional[Dict[int, float]] = None,
                bag_seconds: Optional[Dict[int, float]] = None,
                calibration: Optional[Dict[str, float]] = None,
                calibration_source: str = "measured",
                cached_steps: Optional[Sequence[str]] = None) -> str:
        """Human-readable plan: order, per-step estimates, backends.

        Pass the executor's ``timings`` to annotate phases with measured
        wall time next to the estimates, and its ``step_actuals``
        (var -> measured product entries) to render estimate-vs-actual
        drift per step — the honest-numbers half of the plan-feedback
        loop (no re-planning yet).

        The analyze-mode kwargs (``Executor.explain(analyze=True)``
        supplies them) extend each step with measured seconds — the
        per-shard max alongside the summed work when partitioned — and
        append a per-shard section (rows, wall, straggler flags, skew)
        instead of collapsing shards into one number.

        ``bag_actuals``/``bag_seconds`` (bag index -> measured product
        size / wall) annotate the WCOJ bag section of a hybrid plan the
        same way; ``calibration`` (op -> correction scalar from
        ``CostModel.calibrate``) renders each raw estimate next to its
        calibrated value so the feedback loop's effect is visible.
        ``calibration_source="loaded"`` marks factors restored from the
        persisted sidecar (rendered ``calib(loaded)=``) rather than
        measured this run.  ``cached_steps`` (variables whose messages the
        build actually served from the message cache) renders
        ``cached=hit`` per step; steps the planner merely *priced* as
        resident (`StepEstimate.cached`) render ``cached=resident``.
        """
        calib_tag = ("calib(loaded)" if calibration_source == "loaded"
                     else "calib")
        lines = [
            f"PhysicalPlan {self.query_name!r}  "
            f"(planner={self.planner}, chosen={self.source}, "
            f"signature={self.signature()})",
            f"  elimination order : {' -> '.join(self.order)}"
            f"   (root: {self.order[-1] if self.order else '-'})",
            f"  early projection  : {'on' if self.early_projection else 'off'}",
            f"  backends          : " + ", ".join(
                f"{k}={v}" for k, v in sorted(self.backends.items())),
            f"  materialize       : {self.materialize}",
            f"  est cost          : {self.est_cost:.3g} product entries"
            f"   (search {self.search_seconds * 1e3:.2f}ms)",
        ]
        if self.partitions > 1:
            part = (f"  partitions        : {self.partitions} "
                    f"by hash({self.partition_var})")
            if self.partition_fold > 1:
                part += (f" x{self.partition_fold} fold "
                         f"({self.partitions * self.partition_fold} virtual)")
            part += f"  executor={self.shard_executor}"
            lines.insert(5, part)
        if self.bags:
            lines.append("  bags (WCOJ multiway steps):")
            for j, b in enumerate(self.bags):
                line = (
                    f"    bag[{','.join(b.vars)}] factors={b.num_factors}"
                    f"  est_entries={b.est_entries:.3g}"
                    f"  agm={b.agm_entries:.3g} (rho*={b.rho:.2f})")
                if calibration and "bag" in calibration:
                    calib = b.est_entries * calibration["bag"]
                    line += f"  {calib_tag}={calib:.3g}"
                if b.tables:
                    line += f"  tables=({','.join(b.tables)})"
                if bag_actuals and j in bag_actuals:
                    act = float(bag_actuals[j])
                    drift = (act / b.est_entries
                             if b.est_entries > 0.0 else float("inf"))
                    line += f"  actual={act:.3g} ({drift:.2f}x est)"
                if bag_seconds and j in bag_seconds:
                    line += f"  time={bag_seconds[j] * 1e3:.2f}ms"
                lines.append(line)
        if self.steps:
            lines.append("  steps:")
            for s in self.steps:
                sep = ",".join(s.separator) or "()"
                line = (
                    f"    eliminate {s.var:<12s} factors={s.num_factors}"
                    f"  est_product={s.product_entries:.3g}"
                    f"  sep=({sep})  est_message={s.message_entries:.3g}")
                if calibration and "eliminate" in calibration:
                    calib = s.product_entries * calibration["eliminate"]
                    line += f"  {calib_tag}={calib:.3g}"
                if cached_steps is not None and s.var in cached_steps:
                    line += "  cached=hit"
                elif getattr(s, "cached", False):
                    line += "  cached=resident"
                if s.tables:
                    line += f"  tables=({','.join(s.tables)})"
                if actuals and s.var in actuals:
                    act = float(actuals[s.var])
                    drift = (act / s.product_entries
                             if s.product_entries > 0.0 else float("inf"))
                    line += f"  actual={act:.3g} ({drift:.2f}x est)"
                if step_seconds and s.var in step_seconds:
                    line += f"  time={step_seconds[s.var] * 1e3:.2f}ms"
                    if (step_seconds_sum
                            and s.var in step_seconds_sum
                            and step_seconds_sum[s.var]
                            != step_seconds[s.var]):
                        line += (f" (max; sum "
                                 f"{step_seconds_sum[s.var] * 1e3:.2f}ms)")
                lines.append(line)
        if shard_report:
            sizes = shard_report.get("sizes", [])
            seconds = shard_report.get("seconds", [])
            stragglers = {getattr(s, "shard", None)
                          for s in shard_report.get("stragglers", [])}
            lines.append("  shards:")
            for i, (rows, sec) in enumerate(zip(sizes, seconds)):
                mark = "  STRAGGLER" if i in stragglers else ""
                lines.append(f"    shard {i:<3d} rows={rows:<12d} "
                             f"wall={sec * 1e3:10.2f}ms{mark}")
            lines.append(
                f"    skew: rows={shard_report.get('skew', 1.0):.2f}x  "
                f"time={shard_report.get('time_skew', 1.0):.2f}x")
            if shard_report.get("executor"):
                line = (f"    executor: {shard_report['executor']} "
                        f"workers={shard_report.get('workers', '?')}")
                if shard_report.get("retries"):
                    line += (f"  degraded={shard_report['retries']} "
                             "(retried on threads)")
                lines.append(line)
        if self.alternatives:
            lines.append("  candidates:")
            for c in self.alternatives:
                mark = "*" if (c.source == self.source
                               and tuple(c.order) == tuple(self.order)) else " "
                lines.append(
                    f"   {mark}{c.source:<10s} cost={c.cost:<12.4g} "
                    f"[{', '.join(c.order)}]")
        if calibration:
            src = " [loaded from sidecar]" \
                if calibration_source == "loaded" else ""
            lines.append(
                f"  calibration (op -> geometric-mean actual/est){src}:")
            for k, v in sorted(calibration.items()):
                lines.append(f"    {k:<16s} x{v:.3f}")
        if timings:
            lines.append("  measured:")
            for k, v in timings.items():
                lines.append(f"    {k:<16s} {v * 1e3:10.2f}ms")
        return "\n".join(lines)
