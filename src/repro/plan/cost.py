"""Cost model: score an elimination order by simulating Algorithm 2 on stats.

For every eliminated variable the real driver multiplies the factors that
contain it (`multiway_product`) and sums the variable out.  The dominant
cost of a step is the entry count of that product — both the expansion work
and the memory of the conditional factor stored into the generator.  The
model replays the same factor bookkeeping on :class:`FactorStats` instead of
data:

* joining two stat-factors that share a variable with degree vectors on
  both sides uses the **exact** pairwise product size (dot product of the
  degree vectors) — this is what sees skew;
* additional shared variables apply the standard independence correction
  ``1 / max(distinct_l, distinct_r)``;
* summing a variable out caps the message size at the product of the
  remaining variables' distinct counts (a separator-size / width bound in
  the hypertree-duality sense: the separator is the clique the message
  lives on).

``simulate`` returns the per-step estimates and their sum — the plan cost.
Estimates are heuristic; correctness never depends on them (every
admissible order yields the same GFJS; see tests/test_plan.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.plan.stats import FactorStats, QueryStats

_HUGE = 1e30


@dataclass
class StepEstimate:
    """Planner's view of one elimination step."""

    var: str
    product_entries: float          # estimated |multiway_product(rel)|
    separator: Tuple[str, ...]      # remaining vars of the product
    message_entries: float          # estimated message size after summing out
    num_factors: int                # how many factors contained the var
    tables: Tuple[str, ...] = ()    # base tables feeding the step (transitive)

    @property
    def cost(self) -> float:
        return self.product_entries


def _join_stats(a: FactorStats, b: FactorStats) -> FactorStats:
    """Estimated stats of the factor product a ⋈ b.

    Deliberately conservative: the estimate is the *minimum single-variable
    bound* — for each shared variable the dot product of the degree vectors
    (the exact product size if that were the only join variable), taking
    the tightest one.  Further shared variables only shrink the true
    result, but applying independence corrections for them systematically
    underestimates correlated intermediates (messages in a cyclic query are
    highly correlated), and an optimistic planner is a dangerous planner:
    one missed blow-up costs more than many slightly-loose bounds.
    """
    shared = [v for v in a.vars if v in b.vars]
    out_vars = a.vars + tuple(v for v in b.vars if v not in a.vars)

    if not shared:
        entries = min(a.entries * b.entries, _HUGE)
    else:
        bounds = [float(a.degrees[v] @ b.degrees[v]) for v in shared
                  if a.has_degrees(v) and b.has_degrees(v)]
        # containment: if one side's variables are a subset of the other's,
        # every result row extends exactly one row of the superset side
        # (factor keys are unique), so the superset's cardinality bounds
        # the product — this is what keeps same-separator message products
        # (cyclic queries) from looking like cartesian blow-ups.
        if set(a.vars) <= set(b.vars):
            bounds.append(b.entries)
        if set(b.vars) <= set(a.vars):
            bounds.append(a.entries)
        if not bounds:
            # scalar fallback: one correction by the most selective variable
            sel = max(max(a.distinct.get(v, 1.0), b.distinct.get(v, 1.0), 1.0)
                      for v in shared)
            bounds = [a.entries * b.entries / sel]
        entries = min(min(bounds), _HUGE)

    distinct: Dict[str, float] = {}
    degrees: Dict[str, np.ndarray] = {}
    for v in out_vars:
        cands = [s.distinct[v] for s in (a, b) if v in s.distinct]
        distinct[v] = min(min(cands), max(entries, 1.0))
        if v in shared and a.has_degrees(v) and b.has_degrees(v):
            degrees[v] = a.degrees[v] * b.degrees[v]
        elif a.has_degrees(v):
            degrees[v] = a.degrees[v] * (entries / max(a.entries, 1.0))
        elif b.has_degrees(v):
            degrees[v] = b.degrees[v] * (entries / max(b.entries, 1.0))
    return FactorStats(out_vars, entries, distinct, degrees,
                       a.sources | b.sources)


def _sum_out(joint: FactorStats, var: str) -> FactorStats:
    """Estimated stats of the message after marginalizing ``var`` out."""
    keep = tuple(v for v in joint.vars if v != var)
    cap = 1.0
    for v in keep:
        cap = min(cap * max(joint.distinct.get(v, 1.0), 1.0), _HUGE)
    entries = min(joint.entries, cap) if keep else 1.0
    scale = entries / max(joint.entries, 1.0)
    distinct = {v: min(joint.distinct[v], max(entries, 1.0)) for v in keep}
    degrees = {v: joint.degrees[v] * scale
               for v in keep if v in joint.degrees}
    return FactorStats(keep, entries, distinct, degrees, joint.sources)


class CostModel:
    """Scores elimination orders on a query's :class:`QueryStats`."""

    def __init__(self, stats: QueryStats) -> None:
        self.stats = stats

    def initial_factors(self) -> List[FactorStats]:
        return list(self.stats.factor_stats)

    def eliminate(self, factors: List[FactorStats], var: str
                  ) -> Tuple[StepEstimate, List[FactorStats]]:
        """One simulated elimination step: returns (estimate, new factors)."""
        rel = [f for f in factors if var in f.vars]
        rest = [f for f in factors if var not in f.vars]
        if not rel:
            est = StepEstimate(var, 0.0, (), 0.0, 0)
            return est, rest
        joint = rel[0]
        for f in rel[1:]:
            joint = _join_stats(joint, f)
        msg = _sum_out(joint, var)
        est = StepEstimate(var, joint.entries, msg.vars, msg.entries, len(rel),
                           tuple(sorted(joint.sources)))
        return est, rest + [msg]

    def step_cost(self, factors: List[FactorStats], var: str) -> float:
        """Cost of eliminating ``var`` next, without committing the step."""
        return self.eliminate(factors, var)[0].cost

    def simulate(self, order: Sequence[str]) -> Tuple[List[StepEstimate], float]:
        """Replay a full order; returns per-step estimates and total cost.

        The last variable of the order is the generator root — it is never
        eliminated, so it contributes no step.
        """
        factors = self.initial_factors()
        steps: List[StepEstimate] = []
        for v in list(order)[:-1]:
            est, factors = self.eliminate(factors, v)
            steps.append(est)
        return steps, float(sum(s.cost for s in steps))
