"""Cost model: score an elimination order by simulating Algorithm 2 on stats.

For every eliminated variable the real driver multiplies the factors that
contain it (`multiway_product`) and sums the variable out.  The dominant
cost of a step is the entry count of that product — both the expansion work
and the memory of the conditional factor stored into the generator.  The
model replays the same factor bookkeeping on :class:`FactorStats` instead of
data:

* joining two stat-factors that share a variable with degree vectors on
  both sides uses the **exact** pairwise product size (dot product of the
  degree vectors) — this is what sees skew;
* additional shared variables apply the standard independence correction
  ``1 / max(distinct_l, distinct_r)``;
* summing a variable out caps the message size at the product of the
  remaining variables' distinct counts (a separator-size / width bound in
  the hypertree-duality sense: the separator is the clique the message
  lives on).

``simulate`` returns the per-step estimates and their sum — the plan cost.
Estimates are heuristic; correctness never depends on them (every
admissible order yields the same GFJS; see tests/test_plan.py).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, replace
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

import numpy as np

from repro.plan.stats import FactorStats, QueryStats

_HUGE = 1e30
# vertex-enumeration budget for the exact fractional-edge-cover LP; past
# this many basis candidates the greedy integral cover takes over
_LP_COMBO_CAP = 5000
# what a message-cache hit costs in product-entry units: a key probe plus a
# positional rename, independent of how expensive the skipped product was
CACHED_STEP_COST = 1.0


@dataclass
class StepEstimate:
    """Planner's view of one elimination step."""

    var: str
    product_entries: float          # estimated |multiway_product(rel)|
    separator: Tuple[str, ...]      # remaining vars of the product
    message_entries: float          # estimated message size after summing out
    num_factors: int                # how many factors contained the var
    tables: Tuple[str, ...] = ()    # base tables feeding the step (transitive)
    cached: bool = False            # message resident in the cache at plan time

    @property
    def cost(self) -> float:
        return CACHED_STEP_COST if self.cached else self.product_entries


def _join_stats(a: FactorStats, b: FactorStats) -> FactorStats:
    """Estimated stats of the factor product a ⋈ b.

    Deliberately conservative: the estimate is the *minimum single-variable
    bound* — for each shared variable the dot product of the degree vectors
    (the exact product size if that were the only join variable), taking
    the tightest one.  Further shared variables only shrink the true
    result, but applying independence corrections for them systematically
    underestimates correlated intermediates (messages in a cyclic query are
    highly correlated), and an optimistic planner is a dangerous planner:
    one missed blow-up costs more than many slightly-loose bounds.
    """
    shared = [v for v in a.vars if v in b.vars]
    out_vars = a.vars + tuple(v for v in b.vars if v not in a.vars)

    if not shared:
        entries = min(a.entries * b.entries, _HUGE)
    else:
        bounds = [float(a.degrees[v] @ b.degrees[v]) for v in shared
                  if a.has_degrees(v) and b.has_degrees(v)]
        # containment: if one side's variables are a subset of the other's,
        # every result row extends exactly one row of the superset side
        # (factor keys are unique), so the superset's cardinality bounds
        # the product — this is what keeps same-separator message products
        # (cyclic queries) from looking like cartesian blow-ups.
        if set(a.vars) <= set(b.vars):
            bounds.append(b.entries)
        if set(b.vars) <= set(a.vars):
            bounds.append(a.entries)
        if not bounds:
            # scalar fallback: one correction by the most selective variable
            sel = max(max(a.distinct.get(v, 1.0), b.distinct.get(v, 1.0), 1.0)
                      for v in shared)
            bounds = [a.entries * b.entries / sel]
        entries = min(min(bounds), _HUGE)

    distinct: Dict[str, float] = {}
    degrees: Dict[str, np.ndarray] = {}
    for v in out_vars:
        cands = [s.distinct[v] for s in (a, b) if v in s.distinct]
        distinct[v] = min(min(cands), max(entries, 1.0))
        if v in shared and a.has_degrees(v) and b.has_degrees(v):
            degrees[v] = a.degrees[v] * b.degrees[v]
        elif a.has_degrees(v):
            degrees[v] = a.degrees[v] * (entries / max(a.entries, 1.0))
        elif b.has_degrees(v):
            degrees[v] = b.degrees[v] * (entries / max(b.entries, 1.0))
    return FactorStats(out_vars, entries, distinct, degrees,
                       a.sources | b.sources)


def _sum_out(joint: FactorStats, var: str) -> FactorStats:
    """Estimated stats of the message after marginalizing ``var`` out."""
    keep = tuple(v for v in joint.vars if v != var)
    cap = 1.0
    for v in keep:
        cap = min(cap * max(joint.distinct.get(v, 1.0), 1.0), _HUGE)
    entries = min(joint.entries, cap) if keep else 1.0
    scale = entries / max(joint.entries, 1.0)
    distinct = {v: min(joint.distinct[v], max(entries, 1.0)) for v in keep}
    degrees = {v: joint.degrees[v] * scale
               for v in keep if v in joint.degrees}
    return FactorStats(keep, entries, distinct, degrees, joint.sources)


def fractional_edge_cover(variables: Sequence[str],
                          scopes: Sequence[Set[str]],
                          log_sizes: Sequence[float]
                          ) -> Tuple[float, float]:
    """The AGM fractional-edge-cover LP over a bag's factors.

    minimize  sum_f x_f * log N_f
    s.t.      sum_{f : v in scope(f)} x_f >= 1   for every v in variables
              x >= 0

    Returns ``(rho, log_bound)``: the cover weight at the optimum and the
    optimal objective — ``exp(log_bound)`` is the AGM bound on the bag's
    join size (and, by restriction of the same cover, on every prefix
    frontier of a WCOJ evaluation of the bag).

    Solved exactly by basic-feasible-point enumeration (an LP optimum sits
    on a vertex: n tight constraints out of the m coverage + n
    nonnegativity rows); bags are small, so the combinatorics stay tiny —
    past ``_LP_COMBO_CAP`` candidates a greedy integral set cover takes
    over (a valid, merely looser, cover).
    """
    n = len(scopes)
    vs = [v for v in variables if any(v in s for s in scopes)]
    m = len(vs)
    if n == 0 or m == 0:
        return 0.0, 0.0
    A = np.zeros((m, n))
    for j, sc in enumerate(scopes):
        for i, v in enumerate(vs):
            if v in sc:
                A[i, j] = 1.0
    c = np.asarray([max(w, 0.0) for w in log_sizes], float)
    rows = [(A[i], 1.0) for i in range(m)]
    for j in range(n):
        e = np.zeros(n)
        e[j] = 1.0
        rows.append((e, 0.0))
    best_val, best_rho = None, 0.0
    if math.comb(m + n, n) <= _LP_COMBO_CAP:
        for combo in itertools.combinations(range(m + n), n):
            M = np.stack([rows[k][0] for k in combo])
            b = np.asarray([rows[k][1] for k in combo])
            try:
                x = np.linalg.solve(M, b)
            except np.linalg.LinAlgError:
                continue
            if (x >= -1e-9).all() and (A @ x >= 1.0 - 1e-9).all():
                val = float(c @ x)
                if best_val is None or val < best_val - 1e-12:
                    best_val, best_rho = val, float(x.sum())
    if best_val is None:
        # greedy integral cover: most uncovered vars per unit log-size
        uncovered = set(vs)
        val, rho = 0.0, 0.0
        while uncovered:
            j = max(range(n),
                    key=lambda k: (len(scopes[k] & uncovered), -c[k], -k))
            if not scopes[j] & uncovered:  # pragma: no cover - cover invariant
                break
            uncovered -= scopes[j]
            val += float(c[j])
            rho += 1.0
        best_val, best_rho = val, rho
    return best_rho, best_val


@dataclass
class BagEstimate:
    """Planner's view of one WCOJ bag step (see plan/ir.py::BagStep)."""

    entries: float              # estimated |bag product| (final frontier)
    cost: float                 # estimated work: sum of per-level frontiers
    rho: float                  # fractional edge cover number of the bag
    agm_entries: float          # AGM bound exp(sum x_f log N_f)
    stats: FactorStats          # the bag product as a spine-level factor


class CostModel:
    """Scores elimination orders on a query's :class:`QueryStats`.

    ``corrections`` (op name -> scalar) are the calibration factors from
    :meth:`calibrate`: estimates for an op are multiplied by its factor,
    so a model fed past drift records prices the next plan with them.
    """

    def __init__(self, stats: QueryStats,
                 corrections: Optional[Mapping[str, float]] = None) -> None:
        self.stats = stats
        self.corrections = dict(corrections or {})

    def _corr(self, op: str) -> float:
        return float(self.corrections.get(op, 1.0))

    def initial_factors(self) -> List[FactorStats]:
        return list(self.stats.factor_stats)

    def eliminate(self, factors: List[FactorStats], var: str
                  ) -> Tuple[StepEstimate, List[FactorStats]]:
        """One simulated elimination step: returns (estimate, new factors)."""
        rel = [f for f in factors if var in f.vars]
        rest = [f for f in factors if var not in f.vars]
        if not rel:
            est = StepEstimate(var, 0.0, (), 0.0, 0)
            return est, rest
        joint = rel[0]
        for f in rel[1:]:
            joint = _join_stats(joint, f)
        msg = _sum_out(joint, var)
        est = StepEstimate(var,
                           min(joint.entries * self._corr("eliminate"), _HUGE),
                           msg.vars, msg.entries, len(rel),
                           tuple(sorted(joint.sources)))
        return est, rest + [msg]

    def step_cost(self, factors: List[FactorStats], var: str) -> float:
        """Cost of eliminating ``var`` next, without committing the step."""
        return self.eliminate(factors, var)[0].cost

    def simulate(self, order: Sequence[str],
                 factors: Optional[Sequence[FactorStats]] = None
                 ) -> Tuple[List[StepEstimate], float]:
        """Replay a full order; returns per-step estimates and total cost.

        The last variable of the order is the generator root — it is never
        eliminated, so it contributes no step.  ``factors`` replaces the
        initial working set (the hybrid planner passes bag-product stats
        plus the unbagged table factors to price the acyclic spine).
        """
        factors = self.initial_factors() if factors is None else list(factors)
        steps: List[StepEstimate] = []
        for v in list(order)[:-1]:
            est, factors = self.eliminate(factors, v)
            steps.append(est)
        return steps, float(sum(s.cost for s in steps))

    def apply_residency(self, steps: Sequence[StepEstimate],
                        cached_vars: Set[str]
                        ) -> Tuple[Tuple[StepEstimate, ...], float]:
        """Reprice steps whose message is already resident in the message
        cache: a cached step costs :data:`CACHED_STEP_COST` (a key lookup)
        no matter how expensive the skipped product would have been.
        Returns the repriced steps and the adjusted total — what the order
        search compares so it can prefer orders that maximize reusable
        prefixes against the cache's resident key set."""
        out = tuple(replace(s, cached=True) if s.var in cached_vars else s
                    for s in steps)
        return out, float(sum(s.cost for s in out))

    # -- WCOJ bag steps ----------------------------------------------------
    def bag_estimate(self, occurrences: Sequence[int],
                     bind_order: Sequence[str]) -> BagEstimate:
        """Price a WCOJ bag step joining the given table occurrences.

        Two bounds, combined take the min at every level:

        * **AGM** — ``fractional_edge_cover`` over the bag's factors.  The
          optimal cover restricted to a prefix of ``bind_order`` is
          feasible for the prefix LP with the same objective, so the full
          bound caps every intermediate frontier, not just the output.
        * **skew-aware level simulation** — fold the frontier through
          ``_join_stats`` one bind level at a time, expanding through the
          cheapest containing factor (mirroring the real
          ``multiway_product`` expander choice) and projecting away
          unbound variables; this is what sees degree skew the AGM bound
          is blind to.

        ``cost`` sums the per-level frontiers (the work the breadth-first
        WCOJ actually does); ``entries`` is the final frontier (what the
        executor's bag span measures, the drift anchor).
        """
        stats = [self.stats.factor_stats[i] for i in occurrences]
        scopes = [set(s.vars) for s in stats]
        logs = [math.log(max(s.entries, 1.0)) for s in stats]
        rho, logb = fractional_edge_cover(bind_order, scopes, logs)
        agm = min(math.exp(min(logb, math.log(_HUGE))), _HUGE)

        def _cap(f: FactorStats) -> FactorStats:
            if f.entries <= agm:
                return f
            scale = agm / max(f.entries, 1.0)
            return FactorStats(f.vars, agm,
                               {u: min(d, agm) for u, d in f.distinct.items()},
                               {u: d * scale for u, d in f.degrees.items()},
                               f.sources)

        frontier: Optional[FactorStats] = None
        bound: List[str] = []
        cost = 0.0
        for v in bind_order:
            rel = [s for s in stats if v in s.vars]
            if not rel:
                bound.append(v)
                continue
            best: Optional[FactorStats] = None
            for s in rel:
                j = s if frontier is None else _join_stats(frontier, s)
                for u in list(j.vars):
                    if u != v and u not in bound:
                        j = _sum_out(j, u)
                if best is None or j.entries < best.entries:
                    best = j
            frontier = _cap(best)
            bound.append(v)
            cost += frontier.entries
        if frontier is None:  # pragma: no cover - bags always bind a var
            frontier = FactorStats(tuple(bind_order), 0.0, {}, {}, set())
        corr = self._corr("bag")
        sources: Set[str] = set()
        for s in stats:
            sources |= s.sources
        out = FactorStats(frontier.vars, frontier.entries, dict(frontier.distinct),
                          dict(frontier.degrees), sources)
        return BagEstimate(entries=min(out.entries * corr, _HUGE),
                           cost=min(cost * corr, _HUGE),
                           rho=rho, agm_entries=agm, stats=out)

    # -- calibration (the first bite of the plan-feedback control half) ----
    @staticmethod
    def drift_factor(estimates: Mapping[str, float],
                     actuals: Mapping[str, float]) -> float:
        """Geometric-mean actual/estimate ratio over the common keys.

        The geometric mean is the right pooling for multiplicative drift:
        one 100x blow-up and one 100x overestimate cancel, and the result
        is scale-free in the step sizes.  Keys with a nonpositive side are
        skipped (an empty product carries no ratio information).
        """
        logs = [math.log(float(actuals[k]) / float(estimates[k]))
                for k in estimates
                if k in actuals
                and float(estimates[k]) > 0.0 and float(actuals[k]) > 0.0]
        if not logs:
            return 1.0
        return float(math.exp(sum(logs) / len(logs)))

    def calibrate(self, step_estimates: Mapping[str, float],
                  step_actuals: Mapping[str, float],
                  bag_estimates: Optional[Mapping[object, float]] = None,
                  bag_actuals: Optional[Mapping[object, float]] = None
                  ) -> Dict[str, float]:
        """Fold measured drift records into per-op correction factors.

        Consumes the PR-5 feedback surface (``Generator.step_products``
        vs the plan's ``StepEstimate.product_entries``, and the bag
        equivalents) and stores one scalar per op kind: ``"eliminate"``
        for spine steps, ``"bag"`` for WCOJ bag products.  Subsequent
        :meth:`eliminate`/:meth:`bag_estimate` calls on THIS model price
        with the corrections; the returned dict is what
        ``explain(analyze=True)`` renders as calibrated-vs-raw.
        """
        if step_estimates and step_actuals:
            self.corrections["eliminate"] = self.drift_factor(
                step_estimates, step_actuals)
        if bag_estimates and bag_actuals:
            self.corrections["bag"] = self.drift_factor(
                {str(k): v for k, v in bag_estimates.items()},
                {str(k): v for k, v in bag_actuals.items()})
        return dict(self.corrections)
