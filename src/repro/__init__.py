"""repro — Graphical Join (GJ) as a production JAX/TPU framework.

Subpackages are imported lazily; in particular `repro.core` enables x64 at
import (frequencies are int64) while `repro.launch.dryrun` must initialize
jax with 512 host devices before any other jax touch — so nothing here may
import jax eagerly.
"""

__version__ = "1.0.0"
