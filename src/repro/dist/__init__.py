"""Distribution layer: logical-axis sharding rules, activation-sharding
context, and GJ-specific data-parallel primitives.

Models declare *logical* axes ("embed", "heads", "ff", ...) per parameter
leaf (repro/models/layers.py); :mod:`repro.dist.sharding` maps those to mesh
``PartitionSpec``s so model code never mentions mesh axes.
:mod:`repro.dist.gj_parallel` carries the GJ-side primitives: sharded
potential counts and range-sharded desummarization (DESIGN.md §7).
"""

from repro.dist.sharding import (DEFAULT_RULES, SP_FSDP_RULES, ShardingRules,
                                 param_specs)
from repro.dist.act_sharding import constrain, use

__all__ = ["ShardingRules", "DEFAULT_RULES", "SP_FSDP_RULES", "param_specs",
           "constrain", "use"]
