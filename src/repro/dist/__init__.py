"""Distribution layer: logical-axis sharding rules, activation-sharding
context, and the hash-partitioned Graphical Join execution layer.

Models declare *logical* axes ("embed", "heads", "ff", ...) per parameter
leaf (repro/models/layers.py); :mod:`repro.dist.sharding` maps those to mesh
``PartitionSpec``s so model code never mentions mesh axes.
:mod:`repro.dist.partition` carries the GJ-side layer (DESIGN.md §15):
hash-partitioning of encoded potentials on a planned partition variable,
device-parallel partition/potential histograms over a mesh axis, and
parallel desummarization of both monolithic and sharded summaries (it
absorbed the former ``dist/gj_parallel.py``).

Submodule re-exports resolve lazily (PEP 562): ``sharding`` and
``act_sharding`` import jax at module level, and eagerly pulling them here
would force the jax import onto every consumer of the (numpy-only)
partition layer — the planner imports ``repro.dist.partition`` and must
stay jax-free (see ``plan/search.py::_select_backends``).
"""

_SHARDING = {"ShardingRules", "DEFAULT_RULES", "SP_FSDP_RULES", "param_specs"}
_ACT = {"constrain", "use"}
_PARTITION = {"PartitionScheme", "choose_partition_fold",
              "choose_partition_var", "fold_loads", "hash_partition",
              "parallel_desummarize", "partition_counts", "partition_encoded",
              "partition_histogram", "sharded_potential_counts"}
_ACTIONS = {"ShardBuildAction", "ShardBuildResult", "DispatchOutcome",
            "ProcessShardExecutor", "encode_action", "decode_action",
            "encode_result", "decode_result", "perform_action",
            "run_shard_action", "shared_shard_executor",
            "shutdown_shared_executor"}

__all__ = sorted(_SHARDING | _ACT | _PARTITION | _ACTIONS)


def __getattr__(name):
    import importlib
    if name in _SHARDING:
        return getattr(importlib.import_module("repro.dist.sharding"), name)
    if name in _ACT:
        return getattr(importlib.import_module("repro.dist.act_sharding"),
                       name)
    if name in _PARTITION:
        return getattr(importlib.import_module("repro.dist.partition"), name)
    if name in _ACTIONS:
        return getattr(importlib.import_module("repro.dist.actions"), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
