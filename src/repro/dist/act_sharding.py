"""Activation-sharding constraints as an ambient context.

Model code calls :func:`constrain` on every residual-stream activation; by
default that's the identity, so single-device tests and benchmarks pay
nothing.  The dry-run's sequence-parallel preset installs a (mesh, spec)
context via :func:`use`, turning every call into
``jax.lax.with_sharding_constraint`` — model code never names mesh axes.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_state = threading.local()


def current() -> Optional[Tuple[Mesh, PartitionSpec]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use(mesh: Mesh, spec: PartitionSpec) -> Iterator[None]:
    """Install an activation sharding constraint for the enclosed trace."""
    prev = current()
    _state.ctx = (mesh, spec)
    try:
        yield
    finally:
        _state.ctx = prev


def constrain(x: jax.Array) -> jax.Array:
    """Apply the ambient activation constraint (identity when unset)."""
    ctx = current()
    if ctx is None:
        return x
    mesh, spec = ctx
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
