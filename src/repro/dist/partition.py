"""Hash-partitioned Graphical Join execution (DESIGN.md §15).

The partition key falls out of the PGM view: pick one query variable
``v`` (by default the eliminated variable of the costliest planned step —
the bottleneck the shards should split), hash its dictionary codes, and

* restrict every base potential *containing* ``v`` to the rows whose
  ``v``-code hashes to the shard;
* replicate every potential that does not mention ``v``.

Every row of the full join result carries exactly one ``v`` value, so the
per-shard join results are disjoint and their union is the full result —
each shard runs the *same* message-passing steps independently, no
cross-shard communication until the (cheap, summary-level) merge.  This is
the classic distributed hash join generalized to the whole elimination
DAG: steps whose inputs are reachable from a ``v``-carrying potential do
``1/k``-th of the work per shard; steps independent of ``v`` are
replicated (DESIGN.md §15 discusses when that trade is worth it).

The module is importable without jax (the planner consults
:func:`choose_partition_var`); the device-parallel entry points —
:func:`partition_histogram`, :func:`sharded_potential_counts` (absorbed
from the retired ``dist/gj_parallel.py``) — import ``shard_map`` lazily
and run one program per mesh-axis device.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Sequence, Union

import numpy as np

from repro.core.gfjs import GFJS, ShardedGFJS, desummarize, desummarize_range
from repro.core.potentials import INT
from repro.obs.trace import span as _span
from repro.relational.encoding import EncodedQuery

# Knuth multiplicative constant (2^32 / phi); the hash must be identical
# in numpy and jnp uint32 arithmetic so host- and device-side partition
# decisions can never disagree.
HASH_MULT = 0x9E3779B1


def hash_partition(codes, num_partitions: int, *, salt: int = 0) -> np.ndarray:
    """Partition id in [0, num_partitions) per dictionary code (numpy).

    uint32 multiplicative hash + xor-fold: codes are dense domain indices,
    so plain modulo would map contiguous code ranges to round-robin shards
    and correlate with value order; the multiply decorrelates.
    """
    if num_partitions <= 0:
        raise ValueError("num_partitions must be positive")
    h = np.asarray(codes).astype(np.uint32)
    h = (h + np.uint32(salt & 0xFFFFFFFF)) * np.uint32(HASH_MULT)
    h ^= h >> np.uint32(16)
    return (h % np.uint32(num_partitions)).astype(INT)


def hash_partition_device(codes, num_partitions: int, *, salt: int = 0):
    """jnp twin of :func:`hash_partition` (bit-identical by construction)."""
    import jax.numpy as jnp
    if num_partitions <= 0:
        raise ValueError("num_partitions must be positive")
    h = jnp.asarray(codes).astype(jnp.uint32)
    h = (h + jnp.uint32(salt & 0xFFFFFFFF)) * jnp.uint32(HASH_MULT)
    h = h ^ (h >> jnp.uint32(16))
    return (h % jnp.uint32(num_partitions)).astype(jnp.int32)


@dataclass(frozen=True)
class PartitionScheme:
    """How a query's execution is split: hash ``var`` into ``num_partitions``."""

    var: str
    num_partitions: int
    salt: int = 0

    def shard_of(self, codes: np.ndarray) -> np.ndarray:
        return hash_partition(codes, self.num_partitions, salt=self.salt)


def _aggregate_degrees(stats, var: str):
    """Summed degree vector of ``var`` over every factor containing it.

    The hash partitions *codes*, so the unit of placement is one code's
    total row mass across the partitioned occurrences — exactly this sum.
    ``None`` when no factor kept a degree vector for ``var`` (domain past
    ``DEGREE_CAP``), in which case skew is unknowable from the stats.
    """
    total = None
    for fs in stats.factor_stats:
        deg = fs.degrees.get(var)
        if deg is None:
            continue
        total = deg.copy() if total is None else total + deg
    return total


def _top_key_share(stats, var: str) -> float:
    """Mass fraction of ``var``'s heaviest code (0.0 when unknown).

    A code is atomic under hash partitioning: whichever shard its heaviest
    code lands on carries at least this fraction of the partitioned work,
    so ``1 / top_key_share`` caps achievable speedup no matter how many
    shards are cut ("Skew Strikes Back": the degree distribution, not the
    cardinality, decides what parallelism buys).
    """
    deg = _aggregate_degrees(stats, var)
    if deg is None:
        return 0.0
    total = float(deg.sum())
    if total <= 0.0:
        return 0.0
    return float(deg.max()) / total


def choose_partition_var(steps: Sequence, order: Sequence[str],
                         stats=None, partitions: int = 1) -> str:
    """Partition key: the costliest step, discounted by key skew.

    Base rule (and the whole rule when ``stats`` is absent): the variable
    of the costliest estimated step — partitioning on a step's eliminated
    variable shards that step and everything downstream of it in the
    message-flow DAG.

    With ``stats``, each candidate's product mass is discounted by how
    much of it is *unparallelizable*: a variable whose heaviest code holds
    share ``s`` of its row mass cannot spread below ``max(s, 1/k)`` on one
    shard, so the shardable benefit is ``product_entries * (1 - cap)``.
    A huge step on a one-hot-key variable (cap -> 1) loses to a slightly
    smaller step that actually splits.  Ties (including the balanced case
    where every cap is 1/k) break toward higher raw product then earlier
    step, which degenerates to the base rule.
    """
    best = None
    best_score = None
    for pos, s in enumerate(steps):
        if stats is not None and partitions > 1:
            cap = max(_top_key_share(stats, s.var), 1.0 / partitions)
            score = (s.product_entries * (1.0 - cap), s.product_entries,
                     -pos)
        else:
            score = (s.product_entries, -pos)
        if best_score is None or score > best_score:
            best, best_score = s, score
    if best is not None:
        return best.var
    if not order:
        raise ValueError("cannot choose a partition variable: empty order")
    return order[-1]


def fold_loads(sizes: Sequence[float], workers: int) -> np.ndarray:
    """Greedy largest-first (LPT) fold of shard loads onto ``workers`` bins.

    Models what a work-stealing pool does with over-partitioned shards:
    big shards land first, small ones fill the valleys.  Used both to
    *predict* folded balance (:func:`choose_partition_fold`) and to
    *report* it (the executor's ``shard_report`` skew is computed over
    these per-worker loads, so fold=1 degenerates to per-shard skew).
    """
    workers = max(1, int(workers))
    loads = np.zeros(workers, np.float64)
    for s in sorted((float(s) for s in sizes), reverse=True):
        loads[int(np.argmin(loads))] += s
    return loads


def choose_partition_fold(stats, var: str, partitions: int, *,
                          max_fold: int = 8, target_skew: float = 1.2,
                          salt: int = 0) -> int:
    """Over-partitioning factor ``f``: cut ``partitions * f`` virtual
    shards so folding can smooth hash unluck.

    With exactly ``k`` shards, one hot code landing next to a merely warm
    one doubles that shard; with ``k*f`` virtual shards folded back onto
    ``k`` workers, the fold redistributes everything *except* the atomic
    hot codes.  Simulates the real ``hash_partition`` on ``var``'s
    aggregate degree vector and picks the smallest ``f`` whose predicted
    folded worker skew (max/mean) meets ``target_skew``; if none does
    (e.g. a single code holds half the mass), the best-predicted ``f``
    wins.  Returns 1 when no degree vector exists or shards are already
    balanced — over-partitioning is pure overhead then.
    """
    partitions = max(1, int(partitions))
    if partitions == 1:
        return 1
    deg = None if stats is None else _aggregate_degrees(stats, var)
    if deg is None or float(deg.sum()) <= 0.0:
        return 1
    codes = np.arange(len(deg))
    best_f, best_skew = 1, np.inf
    f = 1
    while f <= max_fold:
        pids = hash_partition(codes, partitions * f, salt=salt)
        shard_loads = np.bincount(pids, weights=deg,
                                  minlength=partitions * f)
        worker = fold_loads(shard_loads, partitions)
        mean = float(worker.mean())
        skew = float(worker.max()) / mean if mean > 0 else 1.0
        if skew < best_skew - 1e-12:
            best_f, best_skew = f, skew
        if skew <= target_skew:
            return f
        f *= 2
    return best_f


def partition_encoded(enc: EncodedQuery,
                      scheme: PartitionScheme) -> List[EncodedQuery]:
    """Split an encoded query into per-shard encoded queries.

    Occurrences containing the partition variable are masked to the
    shard's hash slice (a copy of the surviving rows); occurrences without
    it share the original arrays — replication is by reference, never a
    data copy.  Domains are shared globally so codes (and therefore level
    structure and decode) agree across shards.
    """
    if scheme.var not in enc.domains:
        raise ValueError(
            f"partition variable {scheme.var!r} is not a query variable "
            f"(have: {sorted(enc.domains)})")
    with _span("dist:partition_encoded", cat="dist", var=scheme.var,
               partitions=scheme.num_partitions):
        occ_pids = [scheme.shard_of(cols[scheme.var]) if scheme.var in cols
                    else None for cols in enc.encoded_tables]
        out: List[EncodedQuery] = []
        for s in range(scheme.num_partitions):
            tabs = []
            for cols, pids in zip(enc.encoded_tables, occ_pids):
                if pids is None:
                    tabs.append(cols)                # replicated by reference
                else:
                    m = pids == s
                    tabs.append({v: a[m] for v, a in cols.items()})
            out.append(EncodedQuery(enc.query, enc.domains, tabs))
        return out


def partition_counts(enc: EncodedQuery, scheme: PartitionScheme) -> np.ndarray:
    """Rows per shard across the partitioned occurrences (balance probe).

    The numpy view of :func:`partition_histogram`; benchmarks and the
    executor's observability use it to report hash balance under skew.
    """
    counts = np.zeros(scheme.num_partitions, INT)
    for cols in enc.encoded_tables:
        if scheme.var in cols:
            counts += np.bincount(scheme.shard_of(cols[scheme.var]),
                                  minlength=scheme.num_partitions)
    return counts


# ---------------------------------------------------------------------------
# Device-parallel primitives (shard_map over a mesh axis).
# ---------------------------------------------------------------------------

def partition_histogram(mesh, axis: str, codes, num_partitions: int,
                        *, salt: int = 0):
    """Per-partition row counts of a code column, device-parallel.

    Hash on device, then histogram the partition ids with the shared
    sharded GROUP-BY-count kernel.  Matches
    ``np.bincount(hash_partition(codes, k))`` exactly.
    """
    return sharded_potential_counts(
        mesh, axis, hash_partition_device(codes, num_partitions, salt=salt),
        num_partitions)


def sharded_potential_counts(mesh, axis: str, codes, num_codes: int):
    """GROUP BY count of dense codes, sharded over ``axis`` + psum.

    (Absorbed from the retired ``dist/gj_parallel.py``.)  The quantitative-
    learning histogram of one encoded column, computed device-parallel;
    padding rows get code ``num_codes`` — a dead slot sliced off at the
    end — so uneven shard sizes never perturb the histogram.
    """
    import functools
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    ndev = mesh.shape[axis]
    n = codes.shape[0]
    n_pad = -(-max(n, 1) // ndev) * ndev
    padded = jnp.full((n_pad,), num_codes, jnp.int32).at[:n].set(
        jnp.asarray(codes, jnp.int32))

    @functools.partial(shard_map, mesh=mesh, in_specs=P(axis), out_specs=P())
    def _count(local):
        hist = jnp.zeros((num_codes + 1,), jnp.int64).at[local].add(1)
        return jax.lax.psum(hist, axis)

    return _count(padded)[:num_codes]


# ---------------------------------------------------------------------------
# Parallel desummarization (host threads; numpy releases are best-effort).
# ---------------------------------------------------------------------------

def parallel_desummarize(
    summary: Union[GFJS, ShardedGFJS], num_shards: int, *,
    decode: bool = False
) -> Dict[str, np.ndarray]:
    """Desummarize via concurrent workers; results concatenate in order.

    * :class:`GFJS` — range-sharded: run boundaries are prefix sums, so
      each worker expands its own contiguous row slice
      (``desummarize_range``), the absorbed ``host_parallel_desummarize``
      path of the retired ``dist/gj_parallel.py``;
    * :class:`ShardedGFJS` — one worker per hash shard (the shards are
      already independent summaries), output in shard order, equal to
      :func:`repro.core.gfjs.desummarize` on the same object.
    """
    if isinstance(summary, ShardedGFJS):
        workers = max(1, min(num_shards, len(summary.shards)))
        with ThreadPoolExecutor(max_workers=workers) as ex:
            parts = list(ex.map(
                lambda s: desummarize(s, decode=decode), summary.shards))
        return {v: np.concatenate([p[v] for p in parts])
                for v in summary.column_order}
    total = summary.join_size
    num_shards = max(1, min(num_shards, max(total, 1)))
    step = -(-max(total, 1) // num_shards)
    ranges = [(lo, min(lo + step, total)) for lo in range(0, total, step)]
    if not ranges:
        return desummarize_range(summary, 0, 0, decode=decode)
    with ThreadPoolExecutor(max_workers=num_shards) as ex:
        parts = list(ex.map(
            lambda r: desummarize_range(summary, r[0], r[1], decode=decode),
            ranges))
    return {v: np.concatenate([p[v] for p in parts])
            for v in summary.column_order}
