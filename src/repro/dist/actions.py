"""Shard-build action protocol + process-pool shard executor (DESIGN §17).

PR 5 proved sharded elimination scales at the step level (each shard's
products are ~1/k of the monolithic ones) but the thread-pooled build in
``Executor._summarize_partitioned`` serializes the numpy pipelines on the
GIL.  This module promotes shards to real processes with an ARMI-style
action protocol: the coordinator broadcasts self-describing work units, the
workers answer with self-describing results, and nothing else crosses the
boundary.

Wire format (both directions reuse the ``core/storage.py`` codec):

* **action** (:class:`ShardBuildAction` → :func:`encode_action`): a
  ``GJSB``-magic container — JSON header (shard id, elimination order,
  plan knobs, step estimates) + the shard's serialized
  :class:`~repro.relational.encoding.EncodedQuery` slice
  (``encoded_query_to_bytes``).
* **result** (:class:`ShardBuildResult` → :func:`encode_result`): a
  ``GJSB``-magic container — JSON header (join size, per-step measured
  products/seconds, worker wall, serialized span records, metrics
  snapshot) + the shard's GFJS blob (``gfjs_to_bytes``).

Workers run the full per-shard pipeline — ``build_generator`` +
``generate_gfjs`` (or the jax frontier when the action pins it) — inside a
root ``shard:<i>`` span on a private tracer; the coordinator grafts the
returned span records under its ``phase:summarize`` span and merges the
metrics snapshot, so ``explain(analyze=True)`` and the shard report look
the same whether shards ran on threads or processes.

:class:`ProcessShardExecutor` owns a **persistent** spawn-based
``ProcessPoolExecutor`` (spawn, not fork: jax/XLA state does not survive
forking, and spawn workers import a clean interpreter).  Fault posture: a
worker that dies (``BrokenProcessPool``), times out, or raises is retried
**once inline on the coordinator thread** — the thread path is the last
resort, so a crashed shard degrades the query to partially-threaded
execution instead of killing it.  Timeouts recycle the pool (terminating
its processes) so a hung worker can never wedge the next query.
"""

from __future__ import annotations

import json
import os
import struct
import sys
import time
from concurrent.futures import TimeoutError as FutureTimeoutError
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.gfjs import GFJS
from repro.obs.metrics import REGISTRY
from repro.obs.trace import Tracer
from repro.relational.encoding import EncodedQuery

ACTION_MAGIC = b"GJSB"
ACTION_VERSION = 1
KIND_ACTION = "shard_build"
KIND_RESULT = "shard_build_result"

#: Set by the pool initializer in worker processes only.  Fault hooks
#: (test-only) and the worker-side registry reset are gated on it, so the
#: inline thread-path retry of a faulted action never re-faults (or wipes
#: the coordinator's metrics).
_IN_WORKER = False

#: Env hook for fault-injection tests: ``"kill:<shard>"`` hard-exits the
#: worker mid-build, ``"hang:<shard>:<seconds>"`` sleeps past any timeout.
#: Read only in worker processes (spawn inherits the coordinator environ).
FAULT_ENV = "REPRO_SHARD_FAULT"


@dataclass
class ShardBuildAction:
    """One self-describing unit of shard work.

    Everything the worker needs and nothing it must look up: the encoded
    shard slice plus the plan knobs that pin how to build it.  ``fault``
    is the in-band test hook (same contract as :data:`FAULT_ENV`).
    """

    shard: int
    enc: EncodedQuery
    order: Tuple[str, ...]
    early_projection: bool = True
    backend: str = "numpy"                 # GFJS generation engine
    step_estimates: Dict[str, float] = field(default_factory=dict)
    fault: Optional[str] = None


@dataclass
class ShardBuildResult:
    """A worker's reply: the shard summary + every measurement it took."""

    shard: int
    gfjs: GFJS
    join_size: int
    step_products: Dict[str, float]
    step_seconds: Dict[str, float]
    build_seconds: float                   # worker-side pipeline wall
    spans: List[Dict[str, Any]] = field(default_factory=list)
    metrics: Dict[str, Dict[str, Any]] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Wire format — header JSON + one storage-codec payload blob.
# ---------------------------------------------------------------------------

def _pack(kind: str, header: Dict[str, Any], payload: bytes) -> bytes:
    header = dict(header)
    header["kind"] = kind
    hjson = json.dumps(header).encode()
    return (ACTION_MAGIC + struct.pack("<HH", ACTION_VERSION, 0)
            + struct.pack("<Q", len(hjson)) + hjson + payload)


def _unpack(data: bytes, kind: str) -> Tuple[Dict[str, Any], bytes]:
    if data[:4] != ACTION_MAGIC:
        raise ValueError("not a shard-action container (bad magic)")
    (version, _flags) = struct.unpack("<HH", data[4:8])
    if version != ACTION_VERSION:
        raise ValueError(f"unsupported shard-action version {version}")
    (hlen,) = struct.unpack("<Q", data[8:16])
    header = json.loads(data[16:16 + hlen])
    if header.get("kind") != kind:
        raise ValueError(
            f"expected a {kind!r} container, got {header.get('kind')!r}")
    return header, data[16 + hlen:]


def encode_action(action: ShardBuildAction, *,
                  codec: Optional[str] = None) -> bytes:
    from repro.core.storage import encoded_query_to_bytes
    header = {
        "shard": int(action.shard),
        "order": list(action.order),
        "early_projection": bool(action.early_projection),
        "backend": action.backend,
        "step_estimates": {k: float(v)
                           for k, v in action.step_estimates.items()},
        "fault": action.fault,
    }
    return _pack(KIND_ACTION, header,
                 encoded_query_to_bytes(action.enc, codec=codec))


def decode_action(data: bytes) -> ShardBuildAction:
    from repro.core.storage import encoded_query_from_bytes
    header, payload = _unpack(data, KIND_ACTION)
    return ShardBuildAction(
        shard=int(header["shard"]),
        enc=encoded_query_from_bytes(payload),
        order=tuple(header["order"]),
        early_projection=bool(header["early_projection"]),
        backend=header.get("backend", "numpy"),
        step_estimates=dict(header.get("step_estimates", {})),
        fault=header.get("fault"),
    )


def encode_result(result: ShardBuildResult, *,
                  codec: Optional[str] = None) -> bytes:
    from repro.core.storage import gfjs_to_bytes
    header = {
        "shard": int(result.shard),
        "join_size": int(result.join_size),
        "step_products": {k: float(v)
                          for k, v in result.step_products.items()},
        "step_seconds": {k: float(v)
                         for k, v in result.step_seconds.items()},
        "build_seconds": float(result.build_seconds),
        "spans": result.spans,
        "metrics": result.metrics,
    }
    return _pack(KIND_RESULT, header, gfjs_to_bytes(result.gfjs, codec=codec))


def decode_result(data: bytes) -> ShardBuildResult:
    from repro.core.storage import gfjs_from_bytes
    header, payload = _unpack(data, KIND_RESULT)
    return ShardBuildResult(
        shard=int(header["shard"]),
        gfjs=gfjs_from_bytes(payload),
        join_size=int(header["join_size"]),
        step_products=dict(header["step_products"]),
        step_seconds=dict(header["step_seconds"]),
        build_seconds=float(header["build_seconds"]),
        spans=list(header.get("spans", [])),
        metrics=dict(header.get("metrics", {})),
    )


# ---------------------------------------------------------------------------
# Worker side.
# ---------------------------------------------------------------------------

def _worker_init(parent_sys_path: List[str]) -> None:
    """Runs in each spawned worker before any action.

    Adopts the coordinator's ``sys.path`` (spawn children only inherit the
    environment, not in-process path edits) and marks the process as a
    worker so fault hooks and the registry reset become live.
    """
    global _IN_WORKER
    _IN_WORKER = True
    for p in parent_sys_path:
        if p not in sys.path:
            sys.path.append(p)


def _maybe_fault(action: ShardBuildAction) -> None:
    """Honor in-band / env fault hooks — worker processes only."""
    if not _IN_WORKER:
        return
    faults = [action.fault, os.environ.get(FAULT_ENV)]
    for spec in faults:
        if not spec:
            continue
        parts = spec.split(":")
        mode = parts[0]
        target = int(parts[1]) if len(parts) > 1 and parts[1] else None
        if target is not None and target != action.shard:
            continue
        if mode == "kill":
            os._exit(13)
        if mode == "hang":
            time.sleep(float(parts[2]) if len(parts) > 2 else 3600.0)
        if mode == "raise":
            raise RuntimeError(f"injected fault on shard {action.shard}")


def perform_action(action: ShardBuildAction) -> ShardBuildResult:
    """Run the full per-shard pipeline for one action, in this process.

    Spans land on a private tracer under a root ``shard:<i>`` span and are
    returned as records; in a worker process the process-global metrics
    registry is reset first so the snapshot in the result is exactly this
    action's metrics (workers are dedicated to shard actions).  On the
    inline thread-path retry neither happens to the coordinator's state:
    metrics flow into the live registry as on the normal thread path, and
    the snapshot stays empty (nothing to merge — no double counting).
    """
    from repro.core.elimination import build_generator
    from repro.core.gfjs import generate_gfjs
    _maybe_fault(action)
    if _IN_WORKER:
        REGISTRY.reset()
    tracer = Tracer()
    t0 = time.perf_counter()
    with tracer.span(f"shard:{action.shard}", cat="shard",
                     shard=action.shard) as sp:
        gen = build_generator(
            action.enc,
            elimination_order=list(action.order),
            early_projection=action.early_projection,
            step_estimates=dict(action.step_estimates) or None,
        )
        if action.backend == "jax":
            from repro.core.engine_jax import generate_gfjs_jax
            gfjs = generate_gfjs_jax(gen, action.enc.domains)
        else:
            gfjs = generate_gfjs(gen, action.enc.domains)
        sp.set(rows=gfjs.join_size)
    build_seconds = time.perf_counter() - t0
    return ShardBuildResult(
        shard=action.shard,
        gfjs=gfjs,
        join_size=int(gfjs.join_size),
        step_products={k: float(v) for k, v in gen.step_products.items()},
        step_seconds=dict(gen.step_seconds),
        build_seconds=build_seconds,
        spans=tracer.records(),
        metrics=REGISTRY.snapshot() if _IN_WORKER else {},
    )


def run_shard_action(payload: bytes) -> bytes:
    """The pool's target: bytes in, bytes out (fully self-describing)."""
    return encode_result(perform_action(decode_action(payload)))


# ---------------------------------------------------------------------------
# Coordinator side — the persistent process pool.
# ---------------------------------------------------------------------------

@dataclass
class DispatchOutcome:
    """One action's result + how it got there."""

    result: ShardBuildResult
    t_done: float                  # coordinator perf_counter at completion
    retried: bool = False          # process attempt failed, thread saved it
    error: Optional[str] = None    # the process-side failure, if any


class ProcessShardExecutor:
    """Persistent spawn-pool that runs :class:`ShardBuildAction` batches.

    ``timeout`` (seconds, per action) bounds how long the coordinator
    waits for any single worker reply; a timed-out or crashed action is
    retried once inline (thread path) and the pool is recycled so the
    stuck process cannot absorb a worker slot forever.
    """

    def __init__(self, max_workers: int, *,
                 timeout: Optional[float] = None) -> None:
        self.max_workers = max(1, int(max_workers))
        self.timeout = timeout
        self._pool = None

    # -- pool lifecycle ----------------------------------------------------
    def _ensure_pool(self):
        if self._pool is None:
            import multiprocessing
            from concurrent.futures import ProcessPoolExecutor
            self._pool = ProcessPoolExecutor(
                max_workers=self.max_workers,
                mp_context=multiprocessing.get_context("spawn"),
                initializer=_worker_init,
                initargs=(list(sys.path),),
            )
        return self._pool

    def _recycle(self) -> None:
        """Tear the pool down hard (used after a timeout/crash): terminate
        worker processes so a hung action cannot wedge the next batch."""
        pool, self._pool = self._pool, None
        if pool is None:
            return
        try:
            for p in list(getattr(pool, "_processes", {}).values()):
                p.terminate()
        except Exception:
            pass
        try:
            pool.shutdown(wait=False, cancel_futures=True)
        except Exception:
            pass

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True, cancel_futures=True)
            self._pool = None

    # -- dispatch ----------------------------------------------------------
    def run(self, actions: Sequence[ShardBuildAction], *,
            timeout: Optional[float] = None) -> List[DispatchOutcome]:
        """Dispatch a batch; returns one outcome per action, in order.

        All actions are submitted up front — with ``k`` workers and
        ``k*f`` (over-partitioned) actions, free workers pull the next
        queued action, which is the greedy load-balancing the round-robin
        fold assignment approximates.  Failures degrade per-action: the
        failed action re-runs inline on this thread while the surviving
        futures keep their results.
        """
        timeout = self.timeout if timeout is None else timeout
        payloads = [encode_action(a) for a in actions]
        try:
            pool = self._ensure_pool()
            futures = [pool.submit(run_shard_action, p) for p in payloads]
        except Exception as exc:           # pool would not even start
            return [self._retry_inline(a, str(exc)) for a in actions]
        outcomes: List[Optional[DispatchOutcome]] = [None] * len(actions)
        broken = False
        for i, (action, fut) in enumerate(zip(actions, futures)):
            try:
                data = fut.result(timeout=timeout)
                outcomes[i] = DispatchOutcome(
                    result=decode_result(data), t_done=time.perf_counter())
            except (BrokenProcessPool, FutureTimeoutError,
                    Exception) as exc:  # noqa: B014 - deliberate catch-all
                broken = True
                outcomes[i] = self._retry_inline(action, repr(exc))
        if broken:
            # a timed-out worker is still running (or the pool is already
            # broken): recycle so the next batch starts from clean slots
            self._recycle()
        return [o for o in outcomes if o is not None]

    def _retry_inline(self, action: ShardBuildAction,
                      error: str) -> DispatchOutcome:
        """The last-resort thread path: run the action in-process.

        Goes through the wire codec anyway so inline results are
        indistinguishable from worker results (and the codec stays
        exercised even when every pool attempt fails).
        """
        REGISTRY.counter("dist.shard_retries").inc()
        data = run_shard_action(encode_action(action))
        return DispatchOutcome(result=decode_result(data),
                               t_done=time.perf_counter(),
                               retried=True, error=error)


# Process-wide shared executor: spawn startup is ~100ms+ per worker, so the
# pool persists across queries (grown, never shrunk, to the largest worker
# count requested).  Tests call :func:`shutdown_shared_executor` to force a
# fresh pool (e.g. after setting the fault env hook).
_SHARED: Optional[ProcessShardExecutor] = None


def shared_shard_executor(max_workers: int) -> ProcessShardExecutor:
    global _SHARED
    if _SHARED is None or _SHARED.max_workers < max_workers:
        if _SHARED is not None:
            _SHARED.shutdown()
        _SHARED = ProcessShardExecutor(max_workers)
    return _SHARED


def shutdown_shared_executor() -> None:
    global _SHARED
    if _SHARED is not None:
        _SHARED.shutdown()
        _SHARED = None
