"""Logical-axis -> mesh-axis sharding rules.

A :class:`ShardingRules` maps each *logical* parameter axis name (the tuples
declared through ``ParamCollector.declare``) to the mesh axes it shards
over: ``None`` (replicate), a single mesh-axis name, or a tuple of them.
``param_specs`` applies the rules to a model's logical-axes pytree, dropping
mesh axes the current mesh doesn't have and never using one mesh axis twice
in a single spec (a PartitionSpec invariant).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Mapping, Optional, Tuple, Union

from jax.sharding import Mesh, PartitionSpec as P

AxisSpec = Union[None, str, Tuple[str, ...]]


def _as_tuple(spec: AxisSpec) -> Tuple[str, ...]:
    if spec is None:
        return ()
    if isinstance(spec, str):
        return (spec,)
    return tuple(spec)


@dataclass(frozen=True)
class ShardingRules:
    """Mapping: logical axis name -> mesh axes (None/str/tuple)."""

    rules: Mapping[str, AxisSpec] = field(default_factory=dict)

    def with_overrides(self, **kw: AxisSpec) -> "ShardingRules":
        merged = dict(self.rules)
        merged.update(kw)
        return ShardingRules(merged)

    def mesh_axes(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        return _as_tuple(self.rules.get(logical))

    def spec_for(self, axes: Tuple[Optional[str], ...], mesh: Mesh) -> P:
        """PartitionSpec for one parameter's logical-axes tuple."""
        used: set = set()
        parts = []
        for logical in axes:
            cand = tuple(a for a in self.mesh_axes(logical)
                         if a in mesh.axis_names and a not in used)
            used.update(cand)
            if not cand:
                parts.append(None)
            elif len(cand) == 1:
                parts.append(cand[0])
            else:
                parts.append(cand)
        while parts and parts[-1] is None:  # trailing Nones are implicit
            parts.pop()
        return P(*parts)


# Megatron-style tensor parallelism over the 'model' axis: shard the
# per-head/per-neuron dimensions, replicate d_model (activations stay
# contracted over replicated embed).
DEFAULT_RULES = ShardingRules({
    "embed": None,
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head": None,
    "ff": ("model",),
    "moe_ff": ("model",),
    "experts": None,
    "expert_cap": None,
    "layers": None,
    "audio": None,
})

# Sequence-parallel FSDP preset (the dry-run's 'sp_fsdp' grid): params
# additionally sharded over the data axes on their embed dimension;
# activations get a (batch, seq->model) constraint via repro.dist.act_sharding.
SP_FSDP_RULES = DEFAULT_RULES.with_overrides(embed=("data",))


def param_specs(
    logical_axes: Dict[str, Tuple[Optional[str], ...]],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> Dict[str, P]:
    """PartitionSpec per parameter name from its logical axes."""
    return {name: rules.spec_for(axes, mesh)
            for name, axes in logical_axes.items()}
