"""Data-parallel GJ primitives (DESIGN.md §7).

GFJS is range-shardable: run boundaries are prefix sums, so any contiguous
row range of the join result is addressable independently.  That makes both
hot phases embarrassingly parallel:

* quantitative learning — per-shard GROUP BY counts + an all-reduce
  (:func:`sharded_potential_counts`);
* desummarization — every device/host expands only its own row slice
  (:func:`parallel_desummarize_codes`, :func:`host_parallel_desummarize`).
"""

from __future__ import annotations

import functools
from concurrent.futures import ThreadPoolExecutor
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.gfjs import GFJS, desummarize_range


def sharded_potential_counts(
    mesh: Mesh, axis: str, codes: jax.Array, num_codes: int
) -> jax.Array:
    """GROUP BY count of dense codes, sharded over ``axis`` + psum.

    Padding rows get code ``num_codes`` (a dead slot sliced off at the end),
    so uneven shard sizes never perturb the histogram.
    """
    ndev = mesh.shape[axis]
    n = codes.shape[0]
    n_pad = -(-max(n, 1) // ndev) * ndev
    padded = jnp.full((n_pad,), num_codes, jnp.int32).at[:n].set(
        jnp.asarray(codes, jnp.int32))

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=P(axis), out_specs=P())
    def _count(local: jax.Array) -> jax.Array:
        hist = jnp.zeros((num_codes + 1,), jnp.int64).at[local].add(1)
        return jax.lax.psum(hist, axis)

    return _count(padded)[:num_codes]


def parallel_desummarize_codes(
    mesh: Mesh, axis: str, values: jax.Array, bounds: jax.Array, total: int
) -> jax.Array:
    """RLE-expand (values, inclusive-prefix bounds) across a device mesh.

    Each device materializes its own row slice by binary-searching the run
    boundaries — no device ever touches another's output range.
    """
    ndev = mesh.shape[axis]
    per = -(-max(total, 1) // ndev)
    values = jnp.asarray(values, jnp.int32)
    bounds = jnp.asarray(bounds, jnp.int32)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), P()), out_specs=P(axis))
    def _expand(vals: jax.Array, bnds: jax.Array) -> jax.Array:
        shard = jax.lax.axis_index(axis)
        rows = shard * per + jnp.arange(per, dtype=jnp.int32)
        run = jnp.searchsorted(bnds, rows, side="right")
        run = jnp.minimum(run, vals.shape[0] - 1)
        return vals[run]

    return _expand(values, bounds)[:total]


def host_parallel_desummarize(
    gfjs: GFJS, num_shards: int, *, decode: bool = False
) -> Dict[str, np.ndarray]:
    """Desummarize via ``num_shards`` concurrent row-range expansions.

    The host-level analog of the mesh path: each worker runs
    ``desummarize_range`` on its own slice (numpy releases the GIL inside
    repeat/searchsorted), results concatenate in row order.
    """
    total = gfjs.join_size
    num_shards = max(1, min(num_shards, max(total, 1)))
    step = -(-max(total, 1) // num_shards)
    ranges = [(lo, min(lo + step, total)) for lo in range(0, total, step)]
    if not ranges:
        return desummarize_range(gfjs, 0, 0, decode=decode)
    with ThreadPoolExecutor(max_workers=num_shards) as ex:
        parts = list(ex.map(
            lambda r: desummarize_range(gfjs, r[0], r[1], decode=decode),
            ranges))
    return {v: np.concatenate([p[v] for p in parts])
            for v in gfjs.column_order}
