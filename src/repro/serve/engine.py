"""Batched serving engine: jitted prefill + decode with a static-shape
request batch (the production pattern: fixed [B, S_max] slots, per-slot
progress, greedy/temperature sampling).

``serve_step`` is the function the dry-run lowers for the decode shapes:
one new token per sequence against a KV cache of the shape's seq_len.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM


@dataclass
class ServeConfig:
    max_seq: int
    temperature: float = 0.0
    eos_id: int = -1              # -1 => never stop early


class ServeEngine:
    def __init__(self, lm: LM, params, cfg: ServeConfig) -> None:
        self.lm = lm
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(
            functools.partial(lm.prefill, s_max=cfg.max_seq))
        self._decode = jax.jit(lm.decode_step)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        scaled = logits[:, -1] / self.cfg.temperature
        return jax.random.categorical(key, scaled).astype(jnp.int32)

    def generate(self, batch: Dict[str, jax.Array], max_new: int,
                 *, seed: int = 0) -> np.ndarray:
        """Prefill the prompt batch then decode max_new tokens."""
        logits, caches = self._prefill(self.params, batch)
        key = jax.random.key(seed)
        out = []
        tok = self._sample(logits, key)[:, None]
        vision = batch.get("vision")
        for i in range(max_new):
            out.append(np.asarray(tok))
            key, sub = jax.random.split(key)
            if vision is not None:
                logits, caches = self._decode(self.params, tok, caches,
                                              vision=vision)
            else:
                logits, caches = self._decode(self.params, tok, caches)
            tok = self._sample(logits, sub)[:, None]
        return np.concatenate(out, axis=1)


def make_serve_step(lm: LM, *, mode: str):
    """The lowered serving entry points for the dry-run.

    mode == "prefill": (params, batch) -> logits                (encode too)
    mode == "decode":  (params, tokens, caches) -> (logits, caches)
    """
    if mode == "prefill":
        if lm.cfg.is_encoder_only or lm.cfg.family == "audio":
            def encode_step(params, batch):
                return lm.forward(params, batch)
            return encode_step

        def prefill_step(params, batch, *, s_max: int):
            return lm.prefill(params, batch, s_max=s_max)
        return prefill_step

    if mode == "decode":
        def decode_step(params, tokens, caches, **kw):
            return lm.decode_step(params, tokens, caches, **kw)
        return decode_step

    raise ValueError(mode)
