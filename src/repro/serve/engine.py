"""Batched serving engine: jitted prefill + decode with a static-shape
request batch (the production pattern: fixed [B, S_max] slots, per-slot
progress, greedy/temperature sampling).

``serve_step`` is the function the dry-run lowers for the decode shapes:
one new token per sequence against a KV cache of the shape's seq_len.

:class:`RelationalFeatureProvider` is the GJ wire-in (ROADMAP "serve
path"): per-request relational features are pulled through a
:class:`~repro.summary.service.JoinService` with a **pre-compiled**
physical plan, so the steady-state request path is a summary-cache hit plus
an O(runs) group-by — never a join, never a re-plan.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.obs.metrics import REGISTRY
from repro.obs.trace import span as _span
from repro.serve.server import SingleFlight, lookup_rows


@dataclass
class ServeConfig:
    max_seq: int
    temperature: float = 0.0
    eos_id: int = -1              # -1 => never stop early


class RelationalFeatureProvider:
    """Join-backed feature vectors for serve requests.

        svc = JoinService(catalog)
        prov = RelationalFeatureProvider(
            svc, query, key_var="U1",
            aggs={"n_rows": "count", "total": ("sum", "A2")})
        feats = prov.features(np.asarray([uid0, uid1]))   # [2, 2] float32

    The physical plan is compiled once at construction (`JoinService.
    compile`), so every request keys the summary cache on the same plan
    identity; the first `features` call computes the summary, later calls
    are cache hits.  Keys missing from the join result get zero features.

    The provider survives live table growth: every `features` call
    revalidates the memoized per-key table against the catalog's content
    versions (memoized hashes — a dict compare, no data touched).  After a
    `JoinService.append`, the next call re-pulls the frame, which the
    service satisfies through the incremental refresher under the same
    pre-compiled plan — never a cold rebuild, never a re-plan.  The memo
    rebuild is single-flight: a post-append stampede of concurrent
    `features` calls computes the new per-key table exactly once
    (`serve.feature_recomputes` counts builds, not requests).

    Pass ``server=`` (a :class:`~repro.serve.server.JoinServer`) to route
    lookups through the serving front-end instead of the memo: probes
    then batch across concurrent requests against the server's resident
    table, and cold builds go through its collapse/admission machinery.

    The provider is oblivious to summary *shape*: a service configured
    with ``partitions > 1`` hands back shard-merging frames
    (`ShardedSummaryFrame`) whose `group_by` matches the monolithic
    output exactly, so nothing here knows whether the plan was
    partitioned (appends then rebuild instead of splice-refresh — a
    provenance difference, not a value difference).
    """

    def __init__(self, service, query, *, key_var: str,
                 aggs: Dict[str, Any], plan=None, server=None) -> None:
        self.service = service
        self.query = query
        self.key_var = key_var
        self.aggs = dict(aggs)
        self.plan = plan if plan is not None else service.compile(query)
        self.server = server
        # (versions, table) as ONE atomically-assigned pair: concurrent
        # features() calls can never pair an old table with new versions
        # (which would pass revalidation forever and pin stale features)
        self._memo: Optional[Tuple[Dict[str, str],
                                   Dict[str, np.ndarray]]] = None
        # collapses the post-append rebuild stampede: racers on the same
        # versions key share one _feature_table() build
        self._flight = SingleFlight()

    def _feature_table(self) -> Dict[str, np.ndarray]:
        reply = self.service.frame(self.query, plan=self.plan)
        return reply.frame.group_by([self.key_var], **self.aggs)

    def _current_versions(self) -> Dict[str, str]:
        cat = self.service.catalog
        return {qt.table: cat[qt.table].version() for qt in self.query.tables}

    def refresh(self) -> None:
        """Drop the memoized per-key table (e.g. after `invalidate`)."""
        self._memo = None

    @property
    def num_features(self) -> int:
        return len(self.aggs)

    def features(self, keys: np.ndarray) -> np.ndarray:
        """[len(keys), num_features] float32; zeros for unknown keys."""
        with _span("serve:features", cat="serve", keys=len(keys)) as sp:
            REGISTRY.counter("serve.feature_requests").inc()
            if self.server is not None:
                sp.set(via="server")
                return self.server.lookup(self.query, self.key_var, keys,
                                          self.aggs, plan=self.plan)
            versions = self._current_versions()
            memo = self._memo
            fresh = memo is None or memo[0] != versions

            def build(_fl):
                REGISTRY.counter("serve.feature_recomputes").inc()
                return (versions, self._feature_table())

            if fresh:
                # single-flight keyed on the exact version vector: a
                # post-append stampede elects one builder, everyone else
                # shares its table instead of re-deriving it per racer
                memo, _, _ = self._flight.do(
                    tuple(sorted(versions.items())), build)
                self._memo = memo
            sp.set(memo_hit=not fresh)
            return lookup_rows(memo[1], self.key_var, list(self.aggs),
                               np.asarray(keys))


class ServeEngine:
    def __init__(self, lm: LM, params, cfg: ServeConfig, *,
                 feature_provider: Optional[RelationalFeatureProvider] = None
                 ) -> None:
        self.lm = lm
        self.params = params
        self.cfg = cfg
        self.feature_provider = feature_provider
        self._prefill = jax.jit(
            functools.partial(lm.prefill, s_max=cfg.max_seq))
        self._decode = jax.jit(lm.decode_step)

    def attach_features(self, batch: Dict[str, jax.Array],
                        keys: np.ndarray) -> Dict[str, jax.Array]:
        """Return ``batch`` + a ``"features"`` array pulled through GJ.

        No-op (returns ``batch`` unchanged) when no provider is configured;
        callers that conditionally enable relational features need no
        branching.
        """
        if self.feature_provider is None:
            return batch
        out = dict(batch)
        out["features"] = jnp.asarray(self.feature_provider.features(keys))
        return out

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        scaled = logits[:, -1] / self.cfg.temperature
        return jax.random.categorical(key, scaled).astype(jnp.int32)

    def generate(self, batch: Dict[str, jax.Array], max_new: int,
                 *, seed: int = 0) -> np.ndarray:
        """Prefill the prompt batch then decode max_new tokens."""
        logits, caches = self._prefill(self.params, batch)
        key = jax.random.key(seed)
        out = []
        tok = self._sample(logits, key)[:, None]
        vision = batch.get("vision")
        for i in range(max_new):
            out.append(np.asarray(tok))
            key, sub = jax.random.split(key)
            if vision is not None:
                logits, caches = self._decode(self.params, tok, caches,
                                              vision=vision)
            else:
                logits, caches = self._decode(self.params, tok, caches)
            tok = self._sample(logits, sub)[:, None]
        return np.concatenate(out, axis=1)


def make_serve_step(lm: LM, *, mode: str):
    """The lowered serving entry points for the dry-run.

    mode == "prefill": (params, batch) -> logits                (encode too)
    mode == "decode":  (params, tokens, caches) -> (logits, caches)
    """
    if mode == "prefill":
        if lm.cfg.is_encoder_only or lm.cfg.family == "audio":
            def encode_step(params, batch):
                return lm.forward(params, batch)
            return encode_step

        def prefill_step(params, batch, *, s_max: int):
            return lm.prefill(params, batch, s_max=s_max)
        return prefill_step

    if mode == "decode":
        def decode_step(params, tokens, caches, **kw):
            return lm.decode_step(params, tokens, caches, **kw)
        return decode_step

    raise ValueError(mode)
