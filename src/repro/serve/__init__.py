"""Serving layer: the jit'd LM engine (``engine.py``) and the
Graphical-Join serving front-end (``server.py``).

Submodule re-exports resolve lazily (PEP 562, same idiom as
``repro.dist``): ``engine`` imports jax at module level, and eagerly
pulling it here would force the jax import onto every consumer of the
(numpy-only) :class:`JoinServer` — benchmarks and the service-side tests
import the server without ever touching a device.
"""

_ENGINE = {"RelationalFeatureProvider", "ServeConfig", "ServeEngine",
           "make_serve_step"}
_SERVER = {"AdmissionRejected", "DeadlineExceeded", "JoinServer",
           "SingleFlight", "lookup_rows"}

__all__ = sorted(_ENGINE | _SERVER)


def __getattr__(name):
    import importlib
    if name in _ENGINE:
        return getattr(importlib.import_module("repro.serve.engine"), name)
    if name in _SERVER:
        return getattr(importlib.import_module("repro.serve.server"), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
