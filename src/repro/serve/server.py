"""JoinServer — the thread-safe serving front-end over :class:`JoinService`.

The paper's headline wins come from computing a GFJS summary **once** and
answering everything else in O(num_runs).  The raw service honors that for
sequential traffic, but a serving tier sees *stampedes*: N threads racing
the same cold query used to run N full Graphical-Join builds (documented in
``summary/service.py`` as "duplicate work, never a wrong answer"), and the
per-key feature path re-derived its group-by table once per racer after
every append.  At 10k+ requests/s that duplicate work IS the latency.

:class:`JoinServer` closes the gap with three mechanisms (DESIGN.md §18):

* **Request collapsing** (single-flight).  Concurrent requests for the
  same (query fingerprint × table versions × plan signature) cache key
  share one in-flight build through a per-key latch: the first arrival
  becomes the *leader* and runs ``JoinService.frame``; everyone else
  waits on the latch and receives the leader's reply re-labeled
  ``source="collapsed"``.  N racers cost 1 build + N−1 waits, never N
  builds.
* **Batched probes**.  ``lookup`` answers per-key group-by probes (the
  serve-path feature pull) against one *resident* per-key table: the
  first prober leads, optionally lingers ``batch_window`` seconds to
  collect concurrent requests, pulls the frame once (single-flighted),
  derives the group-by table once (LRU-memoized per cache key), then
  answers every collected request with ONE vectorized ``searchsorted``
  over the concatenated keys and scatters the rows back.
Beneath collapsing sits the service's elimination-*message* reuse
(DESIGN.md §20): collapsing de-duplicates builds of the SAME cache key,
while the shared :class:`~repro.summary.msgcache.MessageCache` lets the
one leader build that does run inject messages computed by *different*
queries with matching elimination subtrees — the two mechanisms compose,
and ``stats()`` on the underlying service exposes the ``msgcache_*``
counters alongside the server's own.

* **Admission control**.  A cold build (cache miss with no refreshable
  retained state) is priced by the plan layer's CostModel step estimates
  (``PhysicalPlan.admission_cost``).  Above ``cost_ceiling`` the request
  is rejected (:class:`AdmissionRejected`) or, with ``admission="queue"``,
  queued for one of ``max_expensive_builds`` build slots under the
  request's deadline.  Deadlines also bound waiters on a collapsed build
  and batched-probe followers: expiry raises :class:`DeadlineExceeded` —
  a clean timeout, never a partial frame.

Observability rides :mod:`repro.obs`: every request opens a
``server:request`` span (the leader nests a ``server:build`` child whose
id collapsed waiters carry as ``build_span_id`` — the span-level record of
the latch handoff), and the server mirrors its counters (``requests`` /
``collapsed`` / ``rejected`` / ``deadline_expired`` / ``batched``), gauges
(``inflight`` / ``queue_depth``), and per-source latency histograms into
the process registry under ``server.*``.

This module is deliberately jax-free (it sits in front of the numpy-side
service; the jit'd LM engine lives in ``serve/engine.py``).
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import REGISTRY
from repro.obs.trace import span as _ambient_span
from repro.summary.cache import cache_key_for_versions
from repro.summary.service import ServiceReply


def _table_nbytes(table: Dict[str, np.ndarray]) -> int:
    """Resident footprint of one group-by table (column array bytes)."""
    return int(sum(np.asarray(v).nbytes for v in table.values()))


class AdmissionRejected(RuntimeError):
    """Cold build priced above the server's cost ceiling (reject mode)."""


class DeadlineExceeded(TimeoutError):
    """A request's deadline expired while waiting (collapse latch, probe
    batch, or admission queue) — the caller got nothing, never a partial
    frame."""


class _Flight:
    """One in-flight build: the latch waiters park on, plus its result."""

    __slots__ = ("event", "value", "error", "waiters", "meta")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.value: Any = None
        self.error: Optional[BaseException] = None
        self.waiters = 0
        self.meta: Dict[str, Any] = {}      # leader-stashed (build span id)


class SingleFlight:
    """Collapse concurrent identical-key calls into one execution.

    ``do(key, fn)`` elects the first caller per live key as the leader:
    it runs ``fn(flight)`` and publishes the result (or the exception)
    through the flight latch; concurrent callers with the same key wait
    on the latch — bounded by ``timeout`` — and share the outcome.  The
    flight is removed before the latch fires, so a *later* call starts a
    fresh flight (by then the result is typically cached downstream).

    Returns ``(value, leader, flight)``; re-raises the leader's exception
    in every waiter.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._flights: Dict[Any, _Flight] = {}

    def inflight(self) -> int:
        with self._lock:
            return len(self._flights)

    def do(self, key: Any, fn: Callable[[_Flight], Any], *,
           timeout: Optional[float] = None) -> Tuple[Any, bool, _Flight]:
        with self._lock:
            fl = self._flights.get(key)
            leader = fl is None
            if leader:
                fl = _Flight()
                self._flights[key] = fl
            else:
                fl.waiters += 1
        if leader:
            try:
                fl.value = fn(fl)
            except BaseException as e:
                fl.error = e
                raise
            finally:
                with self._lock:
                    self._flights.pop(key, None)
                fl.event.set()
            return fl.value, True, fl
        if not fl.event.wait(timeout):
            raise DeadlineExceeded(
                f"deadline expired after {timeout:.3f}s waiting on a "
                "collapsed build")
        if fl.error is not None:
            raise fl.error
        return fl.value, False, fl


def lookup_rows(table: Dict[str, np.ndarray], key_var: str,
                agg_names: List[str], keys: np.ndarray) -> np.ndarray:
    """``[len(keys), len(agg_names)]`` float32 rows of a group-by table.

    ``table`` is ``SummaryFrame.group_by`` output (rows sorted by key), so
    one ``searchsorted`` resolves every requested key; keys missing from
    the join result get zero rows.  Shared by :meth:`JoinServer.lookup`
    and ``serve/engine.py::RelationalFeatureProvider``.
    """
    uniq = np.asarray(table[key_var])
    keys = np.asarray(keys)
    pos = np.searchsorted(uniq, keys)
    pos_c = np.clip(pos, 0, max(len(uniq) - 1, 0))
    ok = (uniq[pos_c] == keys) if len(uniq) else np.zeros(len(keys), bool)
    out = np.zeros((len(keys), len(agg_names)), np.float32)
    for j, name in enumerate(agg_names):
        col = np.asarray(table[name], np.float32)
        if len(col):
            out[:, j] = np.where(ok, col[pos_c], 0.0)
    return out


class _Slot:
    """One probe request parked in a batch."""

    __slots__ = ("keys", "event", "out", "error")

    def __init__(self, keys: np.ndarray) -> None:
        self.keys = keys
        self.event = threading.Event()
        self.out: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None


class _Batcher:
    """Per-(cache key × key_var × aggs) probe rendezvous."""

    __slots__ = ("lock", "leader", "pending")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.leader: Optional[_Slot] = None
        self.pending: List[_Slot] = []


def _aggs_signature(aggs: Dict[str, Any]) -> Tuple:
    return tuple(sorted(
        (name, spec if isinstance(spec, str) else tuple(spec))
        for name, spec in aggs.items()))


class JoinServer:
    """Thread-safe serving front-end: collapse, batch, admit.

        svc = JoinService(catalog)
        server = JoinServer(svc, cost_ceiling=1e9, default_deadline=2.0)
        reply = server.frame(query)                 # collapsed under races
        rows = server.lookup(query, "U1", user_ids,
                             {"n": "count", "s": ("sum", "A2")})

    Wraps — never replaces — the service: ``server.frame`` returns the
    same :class:`ServiceReply` the service would (waiters' replies carry
    ``source="collapsed"`` and the leader's frame/key/plan), and every
    aggregate stays bit-identical to a direct ``JoinService`` call
    (``benchmarks/serve_bench.py --smoke`` gates exactly that).

    ``deadline`` (per request, or ``default_deadline``) bounds the time a
    request may spend *waiting* — on a collapse latch, a probe batch, or
    the admission queue.  It does not abort a build the request itself
    leads: the leader chose to build, and aborting mid-elimination would
    strand every waiter behind it.
    """

    def __init__(self, service, *,
                 cost_ceiling: Optional[float] = None,
                 admission: str = "reject",
                 max_expensive_builds: int = 1,
                 default_deadline: Optional[float] = None,
                 batch_window: float = 0.0,
                 max_tables: int = 64,
                 table_byte_budget: Optional[int] = None,
                 tracer=None) -> None:
        if admission not in ("reject", "queue"):
            raise ValueError(f"admission must be 'reject' or 'queue', "
                             f"got {admission!r}")
        if max_expensive_builds < 1:
            raise ValueError("max_expensive_builds must be >= 1")
        if batch_window < 0.0:
            raise ValueError("batch_window must be >= 0")
        self.service = service
        self.cost_ceiling = cost_ceiling
        self.admission = admission
        self.default_deadline = default_deadline
        self.batch_window = float(batch_window)
        self.max_tables = int(max_tables)
        # resident group-by tables are bounded by BYTES as well as entry
        # count: a handful of wide tables can dwarf the summary cache the
        # service itself budgets, so the default ties the resident set to
        # the same ceiling (the service's SummaryCache byte budget)
        if table_byte_budget is None:
            table_byte_budget = getattr(
                getattr(service, "cache", None), "byte_budget", None)
        if table_byte_budget is not None and table_byte_budget <= 0:
            raise ValueError("table_byte_budget must be positive")
        self.table_byte_budget = (int(table_byte_budget)
                                  if table_byte_budget is not None else None)
        # explicit tracer for request spans opened on serving threads
        # (ambient context does not cross thread boundaries); None falls
        # back to the ambient tracer of the calling thread, if any
        self._tracer = tracer
        self._lock = threading.Lock()
        # the counters the issue's serving tier is judged on, as plain
        # ints (race-free under _lock) AND mirrored into REGISTRY
        self.requests = 0
        self.collapsed = 0
        self.rejected = 0
        self.deadline_expired = 0
        self.batched = 0               # probe requests served from a batch
        self.probes = 0                # probe batches executed
        self.table_recomputes = 0      # resident per-key table rebuilds
        self.inflight = 0              # builds running right now
        self.queue_depth = 0           # requests parked in the admission queue
        self._flights = SingleFlight()
        self._table_flight = SingleFlight()
        self._build_slots = threading.Semaphore(max_expensive_builds)
        self._tables: "OrderedDict[Tuple, Dict[str, np.ndarray]]" = \
            OrderedDict()
        self._table_bytes: Dict[Tuple, int] = {}
        self.resident_table_bytes = 0
        self._tables_lock = threading.Lock()
        self._batchers: Dict[Tuple, _Batcher] = {}

    # -- bookkeeping --------------------------------------------------------
    def _count(self, name: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, name, getattr(self, name) + n)
        REGISTRY.counter(f"server.{name}").inc(n)

    def _gauge(self, name: str, delta: int) -> None:
        with self._lock:
            v = getattr(self, name) + delta
            setattr(self, name, v)
        REGISTRY.gauge(f"server.{name}").set(v)

    def _span(self, name: str, **args: Any):
        if self._tracer is not None:
            return self._tracer.span(name, cat="server", **args)
        return _ambient_span(name, cat="server", **args)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "requests": self.requests,
                "collapsed": self.collapsed,
                "rejected": self.rejected,
                "deadline_expired": self.deadline_expired,
                "batched": self.batched,
                "probes": self.probes,
                "table_recomputes": self.table_recomputes,
                "inflight": self.inflight,
                "queue_depth": self.queue_depth,
                "resident_tables": len(self._tables),
                "resident_table_bytes": self.resident_table_bytes,
            }

    # -- keys ---------------------------------------------------------------
    def _key(self, query, plan) -> str:
        versions = {qt.table: self.service.catalog[qt.table].version()
                    for qt in query.tables}
        return cache_key_for_versions(query, versions, plan=plan)

    # -- request collapsing -------------------------------------------------
    def frame(self, query, *, plan=None,
              deadline: Optional[float] = None) -> ServiceReply:
        """The summary for ``query`` — one build per key, however many ask.

        Fast path (cache hit) is a straight ``service.frame``-equivalent;
        on a miss, concurrent callers collapse onto one in-flight build.
        """
        deadline = self.default_deadline if deadline is None else deadline
        t0 = time.perf_counter()
        with self._span("server:request", kind="frame",
                        query=query.name) as sp:
            if plan is None:
                plan = self.service.compile(query)
            key = self._key(query, plan)

            def build(fl: _Flight) -> ServiceReply:
                return self._build(query, plan, key, deadline, t0, fl)

            try:
                reply, leader, fl = self._flights.do(
                    key, build, timeout=self._remaining(deadline, t0))
            except DeadlineExceeded as e:
                # count once per *expiry*: a latch-wait timeout is fresh
                # here, but a leader's queue timeout was already counted in
                # _admit (and is shared — re-raised — by every waiter)
                if not getattr(e, "_counted", False):
                    e._counted = True
                    self._count("deadline_expired")
                sp.set(source="deadline_expired")
                raise
            if not leader:
                wait = time.perf_counter() - t0
                self._count("collapsed")
                reply = ServiceReply(reply.frame, "collapsed", reply.key,
                                     {"collapse_wait": wait}, reply.plan)
                sp.set(collapsed=True,
                       build_span_id=fl.meta.get("build_span_id"))
            dt = time.perf_counter() - t0
            reply.timings["server"] = dt
            sp.set(source=reply.source)
            self._count("requests")
            REGISTRY.histogram(
                f"server.latency_seconds.{reply.source}",
                unit="s").observe(dt)
            return reply

    @staticmethod
    def _remaining(deadline: Optional[float], t0: float) -> Optional[float]:
        if deadline is None:
            return None
        return max(deadline - (time.perf_counter() - t0), 0.001)

    def _build(self, query, plan, key: str, deadline: Optional[float],
               t0: float, fl: _Flight) -> ServiceReply:
        """Leader path: admit (cold only), run the service, publish."""
        cold = (self.service.cache.probe(key) == "miss"
                and not self.service.can_refresh(query, plan))
        slot = self._admit(plan, deadline, t0) if cold else False
        self._gauge("inflight", +1)
        try:
            with self._span("server:build", key=key[:16], cold=cold) as bsp:
                reply = self.service.frame(query, plan=plan)
                bsp.set(source=reply.source)
                fl.meta["build_span_id"] = bsp.span_id
            return reply
        finally:
            self._gauge("inflight", -1)
            if slot:
                self._build_slots.release()

    # -- admission control --------------------------------------------------
    def _admit(self, plan, deadline: Optional[float], t0: float) -> bool:
        """Gate a cold build on the plan's cost estimate.

        Returns True iff a build slot was taken (caller must release).
        Sub-ceiling builds — and everything when no ceiling is set — pass
        for free: refreshes, disk promotions, and cheap builds never queue
        behind an expensive one.
        """
        if self.cost_ceiling is None:
            return False
        est = plan.admission_cost()
        if est <= self.cost_ceiling:
            return False
        if self.admission == "reject":
            self._count("rejected")
            raise AdmissionRejected(
                f"estimated build cost {est:.3g} exceeds the admission "
                f"ceiling {self.cost_ceiling:.3g} "
                f"(plan {plan.query_name!r}, {plan.partitions} partition(s))")
        self._gauge("queue_depth", +1)
        try:
            ok = self._build_slots.acquire(
                timeout=self._remaining(deadline, t0))
        finally:
            self._gauge("queue_depth", -1)
        if not ok:
            self._count("deadline_expired")
            e = DeadlineExceeded(
                f"deadline expired queued for a build slot "
                f"(est cost {est:.3g} > ceiling {self.cost_ceiling:.3g})")
            e._counted = True       # don't re-count in frame()'s handler
            raise e
        return True

    # -- batched per-key probes ---------------------------------------------
    def lookup(self, query, key_var: str, keys, aggs: Dict[str, Any], *,
               plan=None, deadline: Optional[float] = None) -> np.ndarray:
        """``[len(keys), len(aggs)]`` float32 feature rows for ``keys``.

        The serve-path probe: group ``query``'s summary by ``key_var``
        under ``aggs`` (memoized per cache key — versions fold in, so an
        append mints a new table) and gather the requested keys' rows.
        Concurrent probes against the same resident table batch into one
        frame pull + one vectorized lookup; keys absent from the join get
        zeros, matching ``RelationalFeatureProvider`` semantics.
        """
        deadline = self.default_deadline if deadline is None else deadline
        t0 = time.perf_counter()
        keys = np.asarray(keys)
        agg_names = list(aggs)
        with self._span("server:request", kind="lookup",
                        query=query.name, keys=len(keys)) as sp:
            if len(keys) == 0:
                sp.set(source="empty")
                self._count("requests")
                return np.zeros((0, len(agg_names)), np.float32)
            if plan is None:
                plan = self.service.compile(query)
            bkey = (self._key(query, plan), key_var, _aggs_signature(aggs))
            b = self._batcher(bkey)
            slot = _Slot(keys)
            with b.lock:
                lead = b.leader is None
                if lead:
                    b.leader = slot
                else:
                    b.pending.append(slot)
            if lead:
                out = self._lead_probe(b, bkey, slot, query, key_var, aggs,
                                       agg_names, plan, deadline, t0)
                sp.set(source="probe")
            else:
                if not slot.event.wait(self._remaining(deadline, t0)):
                    self._count("deadline_expired")
                    sp.set(source="deadline_expired")
                    raise DeadlineExceeded(
                        f"deadline expired after {deadline:.3f}s waiting "
                        "on a probe batch")
                if slot.error is not None:
                    raise slot.error
                out = slot.out
                self._count("batched")
                sp.set(source="batched")
            self._count("requests")
            REGISTRY.histogram(
                "server.latency_seconds.probe", unit="s").observe(
                    time.perf_counter() - t0)
            return out

    def _batcher(self, bkey: Tuple) -> _Batcher:
        with self._tables_lock:
            b = self._batchers.get(bkey)
            if b is None:
                # batchers for dead keys (version churn) are tiny; prune
                # opportunistically alongside the table LRU bound
                if len(self._batchers) > 4 * self.max_tables:
                    self._batchers = {k: v for k, v in self._batchers.items()
                                      if v.leader is not None or v.pending}
                b = self._batchers.setdefault(bkey, _Batcher())
            return b

    def _lead_probe(self, b: _Batcher, bkey: Tuple, slot: _Slot, query,
                    key_var: str, aggs: Dict[str, Any],
                    agg_names: List[str], plan, deadline: Optional[float],
                    t0: float) -> np.ndarray:
        """Leader: linger, resolve the table once, answer the whole batch."""
        batch: Optional[List[_Slot]] = None
        try:
            if self.batch_window > 0.0:
                time.sleep(self.batch_window)      # collect followers
            table = self._resident_table(bkey, query, key_var, aggs, plan,
                                         deadline, t0)
            with b.lock:
                batch = [slot] + b.pending
                b.pending = []
                b.leader = None
            allk = np.concatenate([s.keys for s in batch])
            rows = lookup_rows(table, key_var, agg_names, allk)
            self._count("probes")
            REGISTRY.histogram("server.batch_size").observe(len(batch))
            REGISTRY.counter("server.probe_keys").inc(len(allk))
            off = 0
            for s in batch:
                s.out = rows[off:off + len(s.keys)]
                off += len(s.keys)
                if s is not slot:
                    s.event.set()
            return slot.out
        except BaseException as e:
            if batch is None:          # failed before the drain
                with b.lock:
                    batch = list(b.pending)
                    b.pending = []
                    b.leader = None
            for s in batch:
                if s is not slot:
                    s.error = e
                    s.event.set()
            raise

    def _resident_table(self, bkey: Tuple, query, key_var: str,
                        aggs: Dict[str, Any], plan,
                        deadline: Optional[float],
                        t0: float) -> Dict[str, np.ndarray]:
        """The memoized group-by table for ``bkey`` (single-flighted)."""
        with self._tables_lock:
            hit = self._tables.get(bkey)
            if hit is not None:
                self._tables.move_to_end(bkey)
                return hit

        def build(_fl: _Flight) -> Dict[str, np.ndarray]:
            reply = self.frame(query, plan=plan,
                               deadline=self._remaining(deadline, t0))
            table = reply.frame.group_by([key_var], **aggs)
            nbytes = _table_nbytes(table)
            with self._tables_lock:
                old = self._table_bytes.pop(bkey, 0)
                self._tables[bkey] = table
                self._table_bytes[bkey] = nbytes
                self.resident_table_bytes += nbytes - old
                self._tables.move_to_end(bkey)
                # evict LRU-first while over EITHER bound — entry count or
                # resident bytes (never past the just-inserted entry: a
                # single over-budget table still serves its own request)
                while len(self._tables) > 1 and (
                        len(self._tables) > self.max_tables
                        or (self.table_byte_budget is not None
                            and self.resident_table_bytes
                            > self.table_byte_budget)):
                    ekey, _ = self._tables.popitem(last=False)
                    self.resident_table_bytes -= \
                        self._table_bytes.pop(ekey, 0)
                resident = self.resident_table_bytes
            REGISTRY.gauge("server.resident_table_bytes",
                           unit="B").set(resident)
            self._count("table_recomputes")
            return table

        table, _, _ = self._table_flight.do(
            bkey, build, timeout=self._remaining(deadline, t0))
        return table
