"""Relational substrate: tables, catalogs, join-query descriptions, IO,
and synthetic dataset generators used by benchmarks and tests.

This layer is deliberately framework-free (pure numpy): it is the "storage
engine" under the Graphical Join core.  Dictionary encoding into dense int32
codes happens here (``repro.relational.encoding``), so that everything
downstream (the GJ core, the JAX engine, the Pallas kernels) operates on
TPU-friendly dense integer arrays.
"""

from repro.relational.table import Table, TableDelta, Catalog
from repro.relational.query import QueryTable, JoinQuery
from repro.relational.encoding import Domain, encode_query

__all__ = [
    "Table",
    "TableDelta",
    "Catalog",
    "QueryTable",
    "JoinQuery",
    "Domain",
    "encode_query",
]
