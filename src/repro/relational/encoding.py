"""Dictionary encoding of attribute domains.

Every query variable gets a :class:`Domain`: the sorted union of the raw
values that variable takes across all of its table occurrences.  Encoding a
column maps raw values to dense int codes (positions in the sorted unique
array).  Because codes are assigned in sorted raw order, *sorting by code ==
sorting by raw value*, which is what makes the GFJS produced downstream equal
to the RLE of the value-sorted join result.

This is the "strings are parsed once at ingest" hardware adaptation recorded
in DESIGN.md §6: TPUs operate on the dense code arrays, raw values are only
touched at the ingest/export boundary.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.relational.query import JoinQuery
from repro.relational.table import Catalog


@dataclass
class Domain:
    """Sorted unique raw values of one query variable."""

    variable: str
    values: np.ndarray  # sorted unique raw values

    @property
    def size(self) -> int:
        return len(self.values)

    def encode(self, raw: np.ndarray) -> np.ndarray:
        """Raw values -> int32 codes; -1 for values outside the domain."""
        pos = np.searchsorted(self.values, raw)
        pos = np.clip(pos, 0, max(self.size - 1, 0))
        ok = self.size > 0
        match = (self.values[pos] == raw) if ok else np.zeros(len(raw), bool)
        codes = np.where(match, pos, -1).astype(np.int64)
        return codes

    def decode(self, codes: np.ndarray) -> np.ndarray:
        return self.values[np.asarray(codes, dtype=np.int64)]


@dataclass
class EncodedQuery:
    """A join query with all touched columns dictionary-encoded."""

    query: JoinQuery
    domains: Dict[str, Domain]
    # per query-table-occurrence: variable -> encoded int64 code column
    encoded_tables: List[Dict[str, np.ndarray]]

    def domain_sizes(self) -> Dict[str, int]:
        return {v: d.size for v, d in self.domains.items()}


def encode_query(catalog: Catalog, query: JoinQuery) -> EncodedQuery:
    """Build per-variable domains (union across occurrences) and encode.

    One pass to collect uniques, one pass to encode: O(N log N) per column
    from the sorts, performed once per (table, query-shape) — the paper's
    'potentials may have been calculated for previous queries' amortization
    point applies here too.

    Each occurrence's Table object is snapshotted once up front: tables are
    immutable, so both passes see one consistent version even if the
    catalog entry is concurrently replaced by an append.
    """
    tables = [catalog[qt.table] for qt in query.tables]

    raw_cols: Dict[str, List[np.ndarray]] = {}
    for qt, tab in zip(query.tables, tables):
        for col, var in qt.var_map:
            raw_cols.setdefault(var, []).append(tab[col])

    domains: Dict[str, Domain] = {}
    for var, cols in raw_cols.items():
        kinds = {c.dtype.kind for c in cols}
        if len(kinds) > 1:
            raise TypeError(f"variable {var!r} joins columns of mixed kinds {kinds}")
        uniq = np.unique(np.concatenate([np.unique(c) for c in cols]))
        domains[var] = Domain(var, uniq)

    encoded_tables: List[Dict[str, np.ndarray]] = []
    for qt, tab in zip(query.tables, tables):
        enc: Dict[str, np.ndarray] = {}
        for col, var in qt.var_map:
            enc[var] = domains[var].encode(tab[col])
        encoded_tables.append(enc)

    return EncodedQuery(query, domains, encoded_tables)
