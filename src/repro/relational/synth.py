"""Synthetic datasets shaped like the paper's workloads.

The paper evaluates on JOB (many-to-many, high result redundancy), lastFM
(friend chains: high UIR), TPCH SF1 (FK joins: no UIR, low redundancy), and
a cyclic lastFM query.  Those datasets are not available offline, so each
generator here reproduces the *structural* properties the paper credits for
its results (UIR fraction, result redundancy, skew, cyclicity) with
controllable scale knobs.  Exact join sizes are printed by the benchmark
harness next to each run.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.relational.query import JoinQuery
from repro.relational.table import Catalog, Table


# ---------------------------------------------------------------------------
# Paper running example (Figure 1) — used heavily by unit tests.
# ---------------------------------------------------------------------------

def figure1() -> Tuple[Catalog, JoinQuery]:
    """The exact 3-table chain join of the paper's Figure 1."""
    t1 = Table(
        "table1",
        {
            "A": ["a0", "a0", "a0", "a1", "a1", "a2", "a3", "a3", "a3", "a3", "a3", "a3"],
            "B": ["b0", "b0", "b0", "b1", "b1", "b1", "b3", "b3", "b4", "b4", "b4", "b4"],
        },
    )
    t2 = Table(
        "table2",
        {
            "B": ["b0", "b0", "b1", "b1", "b1", "b2", "b2", "b2", "b3", "b4", "b4", "b4"],
            "C": ["c0", "c0", "c0", "c0", "c0", "c1", "c1", "c1", "c2", "c3", "c3", "c4"],
        },
    )
    t3 = Table(
        "table3",
        {
            "C": ["c1", "c1", "c1", "c1", "c2", "c2", "c2", "c2", "c3", "c3", "c4", "c4"],
            "D": ["d0", "d0", "d0", "d0", "d2", "d2", "d2", "d2", "d3", "d3", "d4", "d4"],
        },
    )
    query = JoinQuery.of(
        "figure1",
        [
            ("table1", {"A": "A", "B": "B"}),
            ("table2", {"B": "B", "C": "C"}),
            ("table3", {"C": "C", "D": "D"}),
        ],
    )
    return Catalog.of(t1, t2, t3), query


# ---------------------------------------------------------------------------
# Generic generators
# ---------------------------------------------------------------------------

def _zipf_codes(rng: np.random.Generator, n: int, domain: int, alpha: float) -> np.ndarray:
    """n samples in [0, domain) with Zipf-ish skew (alpha=0 => uniform)."""
    if alpha <= 0.0:
        return rng.integers(0, domain, size=n, dtype=np.int64)
    ranks = np.arange(1, domain + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(domain, size=n, p=p).astype(np.int64)


def chain_join(
    n_tables: int = 3,
    rows: int = 10_000,
    domain: int = 1_000,
    *,
    alpha: float = 1.1,
    drop_frac: float = 0.3,
    seed: int = 0,
    name: str = "chain",
) -> Tuple[Catalog, JoinQuery]:
    """A chain T1(V0,V1) ⋈ T2(V1,V2) ⋈ ... with many-to-many join keys.

    ``alpha`` controls value skew (redundancy in the result), ``drop_frac``
    removes a random fraction of each table's join-key domain so that
    neighbouring tables only partially overlap (this manufactures UIR:
    intermediate tuples that die later in the chain).
    """
    rng = np.random.default_rng(seed)
    cat = Catalog()
    tables = []
    for i in range(n_tables):
        lo = _zipf_codes(rng, rows, domain, alpha)
        hi = _zipf_codes(rng, rows, domain, alpha)
        if drop_frac > 0.0:
            keep_lo = rng.random(domain) >= drop_frac
            keep_hi = rng.random(domain) >= drop_frac
            mask = keep_lo[lo] & keep_hi[hi]
            lo, hi = lo[mask], hi[mask]
        t = Table(f"{name}_t{i}", {f"V{i}": lo, f"V{i+1}": hi})
        cat.add(t)
        tables.append((t.name, {f"V{i}": f"V{i}", f"V{i+1}": f"V{i+1}"}))
    return cat, JoinQuery.of(name, tables)


# ---------------------------------------------------------------------------
# Adversarially skewed cyclic patterns (triangle / clique / star-cyclic).
#
# The hub-and-spoke construction is the classic AGM lower-bound family:
# every edge table is half out-of-hub rows (a_i, 0) and half into-hub rows
# (0, b_j), so each PAIRWISE join goes quadratic through the hub while the
# cyclic output stays near-linear — exactly the gap between pure-GJ
# elimination (pairwise products) and a WCOJ bag step (per-level
# intersection, bounded by the AGM bound).  A small dense uniform slice is
# mixed in so the output is non-empty.  ``hub_frac`` is the skew knob:
# 1.0 is the full adversarial instance, 0.0 degrades to uniform edges
# (where pure GJ and the hybrid plan cost about the same).
# ---------------------------------------------------------------------------

_CYCLIC_PATTERNS: Dict[str, Tuple[Tuple[str, str], ...]] = {
    "triangle": (("A", "B"), ("B", "C"), ("C", "A")),
    "clique4": (("A", "B"), ("A", "C"), ("A", "D"),
                ("B", "C"), ("B", "D"), ("C", "D")),
    # wheel W3: star hub M over a triangle rim — star + cycle in one query
    "star_cyclic": (("M", "A"), ("M", "B"), ("M", "C"),
                    ("A", "B"), ("B", "C"), ("C", "A")),
}


def cyclic_pattern_like(
    pattern: str = "triangle",
    m: int = 1_500,
    domain: int = 5_000,
    *,
    hub_frac: float = 1.0,
    dense: int = 200,
    dense_domain: int = 40,
    seed: int = 0,
) -> Tuple[Catalog, JoinQuery]:
    """One edge table per pattern edge, hub-skewed (see module section above).

    ``pattern``: "triangle", "clique4", or "star_cyclic".  Each edge table
    has ``2 * m * hub_frac`` hub rows, ``2 * m * (1 - hub_frac)`` uniform
    rows, and ``dense`` rows uniform over the small shared ``dense_domain``
    (the slice the cyclic output actually comes from).
    """
    if pattern not in _CYCLIC_PATTERNS:
        raise ValueError(f"unknown cyclic pattern {pattern!r} "
                         f"(have {sorted(_CYCLIC_PATTERNS)})")
    if not 0.0 <= hub_frac <= 1.0:
        raise ValueError("hub_frac must be in [0, 1]")
    rng = np.random.default_rng(seed)
    n_hub = int(m * hub_frac)
    n_unif = m - n_hub
    cat = Catalog()
    tables = []
    for u, v in _CYCLIC_PATTERNS[pattern]:
        x = np.concatenate([
            rng.integers(1, domain, n_hub),          # (a_i, 0) out of hub
            np.zeros(n_hub, np.int64),               # (0, b_j) into hub
            rng.integers(1, dense_domain, dense),    # dense slice
            rng.integers(1, domain, 2 * n_unif),     # uniform remainder
        ])
        y = np.concatenate([
            np.zeros(n_hub, np.int64),
            rng.integers(1, domain, n_hub),
            rng.integers(1, dense_domain, dense),
            rng.integers(1, domain, 2 * n_unif),
        ])
        t = Table(f"{pattern}_{u}{v}", {"x": x, "y": y})
        cat.add(t)
        tables.append((t.name, {"x": u, "y": v}))
    return cat, JoinQuery.of(f"{pattern}_hub", tables)


# ---------------------------------------------------------------------------
# lastFM-like: users/friends/artists.  High UIR, chain + cyclic queries.
# ---------------------------------------------------------------------------

def lastfm_like(
    n_users: int = 2_000,
    n_artists: int = 2_000,
    artists_per_user: int = 25,
    friends_per_user: int = 6,
    *,
    alpha: float = 1.05,
    seed: int = 0,
) -> Tuple[Catalog, Dict[str, JoinQuery]]:
    """user_artists(u, a) and user_friends(u, f) with skewed popularity."""
    rng = np.random.default_rng(seed)

    ua_u = np.repeat(np.arange(n_users, dtype=np.int64), artists_per_user)
    ua_a = _zipf_codes(rng, len(ua_u), n_artists, alpha)
    ua = np.unique(np.stack([ua_u, ua_a], axis=1), axis=0)
    user_artists = Table("user_artists", {"userID": ua[:, 0], "artistID": ua[:, 1]})

    uf_u = np.repeat(np.arange(n_users, dtype=np.int64), friends_per_user)
    uf_f = _zipf_codes(rng, len(uf_u), n_users, alpha / 2)
    keep = uf_u != uf_f
    pairs = np.stack([uf_u[keep], uf_f[keep]], axis=1)
    sym = np.concatenate([pairs, pairs[:, ::-1]], axis=0)  # friendship is symmetric
    sym = np.unique(sym, axis=0)
    user_friends = Table("user_friends", {"userID": sym[:, 0], "friendID": sym[:, 1]})

    cat = Catalog.of(user_artists, user_friends)

    queries = {
        # users' friends' artists:  A1 - U1 - U2 - A2 chain (self-join of ua)
        "lastfm_A1": JoinQuery.of(
            "lastfm_A1",
            [
                ("user_artists", {"artistID": "A1", "userID": "U1"}),
                ("user_friends", {"userID": "U1", "friendID": "U2"}),
                ("user_artists", {"userID": "U2", "artistID": "A2"}),
            ],
        ),
        # friends-of-friends' artists: one more hop => more UIR
        "lastfm_A2": JoinQuery.of(
            "lastfm_A2",
            [
                ("user_artists", {"artistID": "A1", "userID": "U1"}),
                ("user_friends", {"userID": "U1", "friendID": "U2"}),
                ("user_friends", {"userID": "U2", "friendID": "U3"}),
                ("user_artists", {"userID": "U3", "artistID": "A2"}),
            ],
        ),
        # longer chain standing in for the paper's lastFM_B (largest result)
        "lastfm_B": JoinQuery.of(
            "lastfm_B",
            [
                ("user_artists", {"artistID": "A1", "userID": "U1"}),
                ("user_friends", {"userID": "U1", "friendID": "U2"}),
                ("user_artists", {"userID": "U2", "artistID": "A2"}),
                ("user_friends", {"userID": "U2", "friendID": "U3"}),
            ],
        ),
        # cyclic: 4-cycle of friendships + an artist shared by U1 and U4.
        # Same junction-tree shape as the paper's lastFM_cyc (Figure 6).
        "lastfm_cyc": JoinQuery.of(
            "lastfm_cyc",
            [
                ("user_friends", {"userID": "U1", "friendID": "U2"}),
                ("user_friends", {"userID": "U2", "friendID": "U3"}),
                ("user_friends", {"userID": "U3", "friendID": "U4"}),
                ("user_friends", {"userID": "U4", "friendID": "U1"}),
                ("user_artists", {"userID": "U1", "artistID": "Ar"}),
                ("user_artists", {"userID": "U4", "artistID": "Ar"}),
            ],
        ),
        # pure triangle (classic WCOJ stress shape)
        "lastfm_tri": JoinQuery.of(
            "lastfm_tri",
            [
                ("user_friends", {"userID": "U1", "friendID": "U2"}),
                ("user_friends", {"userID": "U2", "friendID": "U3"}),
                ("user_friends", {"userID": "U3", "friendID": "U1"}),
            ],
        ),
    }
    return cat, queries


# ---------------------------------------------------------------------------
# JOB-like: star joins on a movie key with skewed fan-outs (many-to-many,
# high result redundancy).
# ---------------------------------------------------------------------------

def job_like(
    n_movies: int = 5_000,
    keywords_per_movie: int = 8,
    companies_per_movie: int = 3,
    cast_per_movie: int = 12,
    *,
    alpha: float = 1.2,
    seed: int = 0,
) -> Tuple[Catalog, Dict[str, JoinQuery]]:
    rng = np.random.default_rng(seed)

    def fan_table(name: str, key: str, val: str, per: int, vocab: int) -> Table:
        m = _zipf_codes(rng, n_movies * per, n_movies, alpha)
        v = _zipf_codes(rng, n_movies * per, vocab, alpha / 2)
        # real JOB m:n tables repeat pairs (same person, several roles);
        # duplicate rows are what gives the flat join result its run-length
        # redundancy (paper §1.1, Figure 2)
        mult = 1 + _zipf_codes(rng, len(m), 4, 1.2)
        m, v = np.repeat(m, mult), np.repeat(v, mult)
        return Table(name, {key: m, val: v})

    title = Table(
        "title",
        {"id": np.arange(n_movies, dtype=np.int64),
         "kind_id": rng.integers(0, 7, n_movies).astype(np.int64)},
    )
    movie_keyword = fan_table("movie_keyword", "movie_id", "keyword_id",
                              keywords_per_movie, n_movies * 2)
    movie_companies = fan_table("movie_companies", "movie_id", "company_id",
                                companies_per_movie, n_movies // 4)
    cast_info = fan_table("cast_info", "movie_id", "person_id",
                          cast_per_movie, n_movies * 3)

    cat = Catalog.of(title, movie_keyword, movie_companies, cast_info)
    queries = {
        "job_A": JoinQuery.of(
            "job_A",
            [
                ("title", {"id": "M", "kind_id": "K"}),
                ("movie_keyword", {"movie_id": "M", "keyword_id": "KW"}),
                ("movie_companies", {"movie_id": "M", "company_id": "CO"}),
            ],
        ),
        "job_B": JoinQuery.of(
            "job_B",
            [
                ("title", {"id": "M", "kind_id": "K"}),
                ("movie_keyword", {"movie_id": "M", "keyword_id": "KW"}),
                ("movie_companies", {"movie_id": "M", "company_id": "CO"}),
                ("cast_info", {"movie_id": "M", "person_id": "P"}),
            ],
        ),
        "job_C": JoinQuery.of(
            "job_C",
            [
                ("movie_keyword", {"movie_id": "M", "keyword_id": "KW"}),
                ("cast_info", {"movie_id": "M", "person_id": "P"}),
            ],
        ),
        "job_D": JoinQuery.of(  # the blow-up query (two high-fanout edges + star)
            "job_D",
            [
                ("movie_keyword", {"movie_id": "M", "keyword_id": "KW"}),
                ("cast_info", {"movie_id": "M", "person_id": "P"}),
                ("movie_companies", {"movie_id": "M", "company_id": "CO"}),
            ],
        ),
    }
    return cat, queries


# ---------------------------------------------------------------------------
# TPCH-like FK joins: no UIR, tiny result redundancy — GJ's worst case.
# ---------------------------------------------------------------------------

def tpch_fk_like(
    n_customers: int = 10_000,
    orders_per_customer: int = 10,
    n_nations: int = 25,
    *,
    seed: int = 0,
) -> Tuple[Catalog, Dict[str, JoinQuery]]:
    rng = np.random.default_rng(seed)
    customer = Table(
        "customer",
        {"c_custkey": np.arange(n_customers, dtype=np.int64),
         "c_nationkey": rng.integers(0, n_nations, n_customers).astype(np.int64)},
    )
    n_orders = n_customers * orders_per_customer
    orders = Table(
        "orders",
        {"o_orderkey": np.arange(n_orders, dtype=np.int64),
         "o_custkey": rng.integers(0, n_customers, n_orders).astype(np.int64)},
    )
    nation = Table(
        "nation",
        {"n_nationkey": np.arange(n_nations, dtype=np.int64),
         "n_regionkey": rng.integers(0, 5, n_nations).astype(np.int64)},
    )
    lineitem = Table(
        "lineitem",
        {"l_orderkey": rng.integers(0, n_orders, n_orders * 4).astype(np.int64),
         "l_partkey": rng.integers(0, n_customers, n_orders * 4).astype(np.int64)},
    )
    cat = Catalog.of(customer, orders, nation, lineitem)
    queries = {
        "fk_A": JoinQuery.of(
            "fk_A",
            [
                ("orders", {"o_orderkey": "O", "o_custkey": "C"}),
                ("customer", {"c_custkey": "C", "c_nationkey": "N"}),
                ("nation", {"n_nationkey": "N", "n_regionkey": "R"}),
            ],
        ),
        "fk_B": JoinQuery.of(
            "fk_B",
            [
                ("lineitem", {"l_orderkey": "O", "l_partkey": "P"}),
                ("orders", {"o_orderkey": "O", "o_custkey": "C"}),
                ("customer", {"c_custkey": "C", "c_nationkey": "N"}),
            ],
        ),
    }
    return cat, queries


def duplicate_rows(cat: Catalog, factor: int = 2) -> Catalog:
    """Replicate every tuple `factor`x (the paper's *_dup redundancy knob)."""
    out = Catalog()
    for name, t in cat.tables.items():
        idx = np.repeat(np.arange(t.num_rows), factor)
        out.add(Table(name, {c: v[idx] for c, v in t.columns.items()}))
    return out
