"""Column-oriented in-memory tables and catalogs.

A :class:`Table` is a named set of equal-length numpy columns.  Columns may
be integer (any width; normalized to int64), float, or string (numpy unicode
or object; normalized to numpy unicode).  GJ is a *physical* join operator:
all filters are assumed to have been applied before a table reaches it.
"""

from __future__ import annotations

import csv
import hashlib
import io
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence

import numpy as np


def _normalize_column(values) -> np.ndarray:
    arr = np.asarray(values)
    if arr.dtype.kind in ("i", "u", "b"):
        return arr.astype(np.int64)
    if arr.dtype.kind == "f":
        return arr.astype(np.float64)
    if arr.dtype.kind in ("U", "S", "O"):
        return arr.astype(np.str_)
    raise TypeError(f"unsupported column dtype {arr.dtype!r}")


@dataclass
class Table:
    """A named columnar table."""

    name: str
    columns: Dict[str, np.ndarray]

    def __post_init__(self) -> None:
        self.columns = {k: _normalize_column(v) for k, v in self.columns.items()}
        lengths = {len(v) for v in self.columns.values()}
        if len(lengths) > 1:
            raise ValueError(f"ragged columns in table {self.name!r}: {lengths}")

    # -- basic accessors -------------------------------------------------
    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())))

    @property
    def column_names(self) -> List[str]:
        return list(self.columns.keys())

    def __getitem__(self, col: str) -> np.ndarray:
        return self.columns[col]

    def select(self, cols: Sequence[str]) -> "Table":
        return Table(self.name, {c: self.columns[c] for c in cols})

    def take(self, idx: np.ndarray) -> "Table":
        return Table(self.name, {c: v[idx] for c, v in self.columns.items()})

    def concat(self, other: "Table") -> "Table":
        if self.column_names != other.column_names:
            raise ValueError("column mismatch in concat")
        return Table(
            self.name,
            {c: np.concatenate([self.columns[c], other.columns[c]]) for c in self.column_names},
        )

    # -- IO ----------------------------------------------------------------
    def to_csv(self, path: str) -> int:
        """Write the table as CSV; returns bytes written (paper stores CSVs)."""
        with open(path, "w", newline="") as f:
            writer = csv.writer(f)
            writer.writerow(self.column_names)
            cols = [self.columns[c] for c in self.column_names]
            for row in zip(*cols):
                writer.writerow(row)
        return os.path.getsize(path)

    @staticmethod
    def from_csv(path: str, name: Optional[str] = None) -> "Table":
        with open(path, newline="") as f:
            reader = csv.reader(f)
            header = next(reader)
            rows = list(reader)
        cols: Dict[str, np.ndarray] = {}
        for j, col in enumerate(header):
            raw = [r[j] for r in rows]
            try:
                cols[col] = np.asarray([int(x) for x in raw], dtype=np.int64)
            except ValueError:
                cols[col] = np.asarray(raw, dtype=np.str_)
        return Table(name or os.path.splitext(os.path.basename(path))[0], cols)

    def nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.columns.values()))

    def version(self) -> str:
        """Content hash of the table (schema + data).

        The compute-and-reuse cache keys summaries on (query fingerprint,
        table versions): replacing a table in the catalog — even with one of
        the same name and shape — invalidates every summary built on it.
        Computed lazily and memoized; Table treats columns as immutable after
        construction (mutate by building a new Table, as `take`/`concat` do).
        """
        cached = self.__dict__.get("_version")
        if cached is None:
            h = hashlib.sha256(self.name.encode())
            for c in sorted(self.columns):
                v = self.columns[c]
                h.update(c.encode())
                h.update(str(v.dtype).encode())
                h.update(np.ascontiguousarray(v).tobytes())
            cached = self.__dict__["_version"] = h.hexdigest()
        return cached

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Table({self.name!r}, rows={self.num_rows}, cols={self.column_names})"


@dataclass
class Catalog:
    """A named collection of tables (the 'database')."""

    tables: Dict[str, Table] = field(default_factory=dict)

    def add(self, table: Table) -> "Catalog":
        self.tables[table.name] = table
        return self

    def __getitem__(self, name: str) -> Table:
        return self.tables[name]

    def __contains__(self, name: str) -> bool:
        return name in self.tables

    @staticmethod
    def of(*tables: Table) -> "Catalog":
        cat = Catalog()
        for t in tables:
            cat.add(t)
        return cat

    def names(self) -> List[str]:
        return list(self.tables.keys())

    def versions(self, names: Optional[Sequence[str]] = None) -> Dict[str, str]:
        """Content versions of the named tables (default: all)."""
        if names is None:
            names = self.names()
        return {n: self.tables[n].version() for n in names}
