"""Column-oriented in-memory tables and catalogs.

A :class:`Table` is a named set of equal-length numpy columns.  Columns may
be integer (any width; normalized to int64), float, or string (numpy unicode
or object; normalized to numpy unicode).  GJ is a *physical* join operator:
all filters are assumed to have been applied before a table reaches it.
"""

from __future__ import annotations

import csv
import hashlib
import io
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence

import numpy as np


def _normalize_column(values) -> np.ndarray:
    arr = np.asarray(values)
    if arr.dtype.kind in ("i", "u", "b"):
        return arr.astype(np.int64)
    if arr.dtype.kind == "f":
        return arr.astype(np.float64)
    if arr.dtype.kind in ("U", "S", "O"):
        return arr.astype(np.str_)
    raise TypeError(f"unsupported column dtype {arr.dtype!r}")


@dataclass(frozen=True)
class TableDelta:
    """An append to a base table: base version -> new version + the block.

    Deltas are the unit of incremental summary maintenance (DESIGN.md §12):
    ``block`` holds only the appended rows, ``new_table`` is the full table
    after the append, and the version pair lets consumers chain deltas
    (a refresher at ``base_version`` may apply this delta; any gap means
    the chain is broken and a full rebuild is the only safe move).
    """

    table: str
    base_version: str
    new_version: str
    block: "Table"
    new_table: Optional["Table"] = None   # absent on slimmed records

    @property
    def num_rows(self) -> int:
        return self.block.num_rows

    def slim(self) -> "TableDelta":
        """This delta without the full-table reference.

        Retention-friendly: a delta log only needs the block and the
        version pair to chain refreshes; holding ``new_table`` would pin
        one full materialized copy of the grown table per logged append.
        """
        if self.new_table is None:
            return self
        return TableDelta(self.table, self.base_version, self.new_version,
                          self.block, None)


@dataclass
class Table:
    """A named columnar table."""

    name: str
    columns: Dict[str, np.ndarray]

    def __post_init__(self) -> None:
        self.columns = {k: _normalize_column(v) for k, v in self.columns.items()}
        lengths = {len(v) for v in self.columns.values()}
        if len(lengths) > 1:
            raise ValueError(f"ragged columns in table {self.name!r}: {lengths}")

    # -- basic accessors -------------------------------------------------
    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())))

    @property
    def column_names(self) -> List[str]:
        return list(self.columns.keys())

    def __getitem__(self, col: str) -> np.ndarray:
        return self.columns[col]

    def select(self, cols: Sequence[str]) -> "Table":
        return Table(self.name, {c: self.columns[c] for c in cols})

    def take(self, idx: np.ndarray) -> "Table":
        return Table(self.name, {c: v[idx] for c, v in self.columns.items()})

    def concat(self, other: "Table") -> "Table":
        if self.column_names != other.column_names:
            raise ValueError("column mismatch in concat")
        return Table(
            self.name,
            {c: np.concatenate([self.columns[c], other.columns[c]]) for c in self.column_names},
        )

    def append(self, rows) -> TableDelta:
        """Append a row block; returns the :class:`TableDelta` describing it.

        ``rows`` is a column mapping (or another :class:`Table`) with exactly
        this table's columns and compatible dtype kinds.  The table itself is
        immutable — the delta carries the resulting ``new_table``; apply it
        through :meth:`Catalog.append` to make it visible to queries.

        The grown table's version is pre-seeded as a *chained* hash of
        (base version, block content): O(block) per append instead of a
        full-table rescan.  Still injective on content along any append
        history; the only cost is that the same content reached by a
        different construction path hashes differently — a cache miss,
        never a wrong hit.
        """
        cols = rows.columns if isinstance(rows, Table) else dict(rows)
        if set(cols) != set(self.column_names):
            raise ValueError(
                f"append block columns {sorted(cols)} != table "
                f"columns {self.column_names}")
        block = Table(self.name, {c: cols[c] for c in self.column_names})
        if block.num_rows == 0:
            # empty blocks carry no dtype information; adopt the table's
            block = Table(self.name,
                          {c: self.columns[c][:0] for c in self.column_names})
        for c in self.column_names:
            have, add = self.columns[c].dtype.kind, block.columns[c].dtype.kind
            if self.num_rows and have != add:
                raise TypeError(
                    f"append block column {c!r} has kind {add!r}, "
                    f"table has {have!r}")
        new_table = self.concat(block)
        if block.num_rows == 0:
            new_table.__dict__["_version"] = self.version()
        else:
            h = hashlib.sha256(b"delta:")
            h.update(self.version().encode())
            h.update(block.version().encode())
            new_table.__dict__["_version"] = h.hexdigest()
        return TableDelta(self.name, self.version(), new_table.version(),
                          block, new_table)

    # -- IO ----------------------------------------------------------------
    def to_csv(self, path: str) -> int:
        """Write the table as CSV; returns bytes written (paper stores CSVs)."""
        with open(path, "w", newline="") as f:
            writer = csv.writer(f)
            writer.writerow(self.column_names)
            cols = [self.columns[c] for c in self.column_names]
            for row in zip(*cols):
                writer.writerow(row)
        return os.path.getsize(path)

    @staticmethod
    def from_csv(path: str, name: Optional[str] = None) -> "Table":
        with open(path, newline="") as f:
            reader = csv.reader(f)
            header = next(reader)
            rows = list(reader)
        cols: Dict[str, np.ndarray] = {}
        for j, col in enumerate(header):
            raw = [r[j] for r in rows]
            try:
                cols[col] = np.asarray([int(x) for x in raw], dtype=np.int64)
            except ValueError:
                cols[col] = np.asarray(raw, dtype=np.str_)
        return Table(name or os.path.splitext(os.path.basename(path))[0], cols)

    def nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.columns.values()))

    def version(self) -> str:
        """Content hash of the table (schema + data).

        The compute-and-reuse cache keys summaries on (query fingerprint,
        table versions): replacing a table in the catalog — even with one of
        the same name and shape — invalidates every summary built on it.
        Computed lazily and memoized; Table treats columns as immutable after
        construction (mutate by building a new Table, as `take`/`concat` do).
        """
        cached = self.__dict__.get("_version")
        if cached is None:
            h = hashlib.sha256(self.name.encode())
            for c in sorted(self.columns):
                v = self.columns[c]
                h.update(c.encode())
                h.update(str(v.dtype).encode())
                h.update(np.ascontiguousarray(v).tobytes())
            cached = self.__dict__["_version"] = h.hexdigest()
        return cached

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Table({self.name!r}, rows={self.num_rows}, cols={self.column_names})"


@dataclass
class Catalog:
    """A named collection of tables (the 'database')."""

    tables: Dict[str, Table] = field(default_factory=dict)

    def add(self, table: Table) -> "Catalog":
        self.tables[table.name] = table
        return self

    def __getitem__(self, name: str) -> Table:
        return self.tables[name]

    def __contains__(self, name: str) -> bool:
        return name in self.tables

    @staticmethod
    def of(*tables: Table) -> "Catalog":
        cat = Catalog()
        for t in tables:
            cat.add(t)
        return cat

    def names(self) -> List[str]:
        return list(self.tables.keys())

    def versions(self, names: Optional[Sequence[str]] = None) -> Dict[str, str]:
        """Content versions of the named tables (default: all)."""
        if names is None:
            names = self.names()
        return {n: self.tables[n].version() for n in names}

    def append(self, name: str, rows) -> TableDelta:
        """Append ``rows`` to table ``name`` and install the grown table.

        Returns the :class:`TableDelta`; callers holding summaries built on
        the old version hand it to the incremental refresher instead of
        recomputing from scratch.
        """
        delta = self.tables[name].append(rows)
        self.add(delta.new_table)
        return delta
