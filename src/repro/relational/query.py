"""Join-query descriptions.

A :class:`JoinQuery` is an n-way natural equi-join over *variables*.  Each
participating table maps some of its columns onto query variables via
``var_map`` (column name -> variable name); two occurrences of the same
variable join.  Renaming through ``var_map`` supports self-joins (e.g. the
paper's lastFM_A1 joins ``user_artists`` twice under different variables)
and cyclic queries (triangles).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple


@dataclass(frozen=True)
class QueryTable:
    """One occurrence of a base table inside a join query."""

    table: str                       # base-table name in the catalog
    var_map: Tuple[Tuple[str, str], ...]  # (column, variable) pairs

    @staticmethod
    def of(table: str, var_map: Dict[str, str]) -> "QueryTable":
        return QueryTable(table, tuple(sorted(var_map.items())))

    @property
    def variables(self) -> Tuple[str, ...]:
        return tuple(v for _, v in self.var_map)

    @property
    def columns(self) -> Tuple[str, ...]:
        return tuple(c for c, _ in self.var_map)


@dataclass(frozen=True)
class JoinQuery:
    """An n-way equi-join: SELECT <output> FROM tables NATURAL-JOIN on vars."""

    name: str
    tables: Tuple[QueryTable, ...]
    output: Optional[Tuple[str, ...]] = None  # None => all variables

    @staticmethod
    def of(
        name: str,
        tables: Sequence[Tuple[str, Dict[str, str]]],
        output: Optional[Sequence[str]] = None,
    ) -> "JoinQuery":
        qts = tuple(QueryTable.of(t, vm) for t, vm in tables)
        return JoinQuery(name, qts, tuple(output) if output is not None else None)

    # -- structural helpers ----------------------------------------------
    @property
    def variables(self) -> List[str]:
        seen: List[str] = []
        for qt in self.tables:
            for v in qt.variables:
                if v not in seen:
                    seen.append(v)
        return seen

    @property
    def output_variables(self) -> List[str]:
        if self.output is None:
            return self.variables
        return list(self.output)

    def hyperedges(self) -> List[FrozenSet[str]]:
        """One hyperedge (clique) per table occurrence."""
        return [frozenset(qt.variables) for qt in self.tables]

    def join_variables(self) -> Set[str]:
        """Variables appearing in >= 2 table occurrences."""
        count: Dict[str, int] = {}
        for qt in self.tables:
            for v in set(qt.variables):
                count[v] = count.get(v, 0) + 1
        return {v for v, c in count.items() if c >= 2}

    def canonical_labels(self) -> Dict[str, str]:
        """Variable -> alias-insensitive canonical label.

        Output variables keep their literal names: they surface as GFJS /
        frame column names, so two queries that differ in *output* naming
        are not interchangeable.  Projected-out variables never appear in
        any result (psis are built only for output variables), so their
        names are pure syntax — they are relabeled by structure: a
        Weisfeiler-Lehman-style color over (contributing (table, column)
        pairs, co-occurrence neighborhoods).  Two internal variables that
        still share a color after refinement fall back to their literal
        names — a conservative choice that loses cross-alias sharing but
        can never conflate genuinely different roles (e.g. the two sides
        of a symmetric self-join).
        """
        variables = self.variables
        out_set = set(self.output) if self.output is not None else None
        if out_set is None:
            return {v: v for v in variables}
        internal = [v for v in variables if v not in out_set]
        if not internal:
            return {v: v for v in variables}

        def _h(obj) -> str:
            return hashlib.sha256(
                json.dumps(obj, separators=(",", ":")).encode()).hexdigest()[:16]

        color: Dict[str, str] = {}
        for v in variables:
            contrib = sorted(
                [qt.table, c]
                for qt in self.tables for c, u in qt.var_map if u == v)
            seed = ["out", v] if v in out_set else ["int"]
            color[v] = _h([seed, contrib])
        # refine over occurrence co-membership until internal colors are as
        # distinct as they will get (bounded by the number of internal vars)
        for _ in range(len(internal)):
            neigh: Dict[str, List] = {v: [] for v in variables}
            for qt in self.tables:
                occ = sorted([c, color[u]] for c, u in qt.var_map)
                for c, u in qt.var_map:
                    neigh[u].append([qt.table, c, occ])
            new = {v: _h([color[v], sorted(neigh[v])]) for v in variables}
            if len(set(new[v] for v in internal)) \
                    == len(set(color[v] for v in internal)):
                color = new
                break
            color = new
        counts: Dict[str, int] = {}
        for v in internal:
            counts[color[v]] = counts.get(color[v], 0) + 1
        labels = {v: v for v in variables}
        for v in internal:
            if counts[color[v]] == 1:
                labels[v] = "~" + color[v]
        return labels

    def fingerprint(self, plan=None, *, literal: bool = False) -> str:
        """Canonical content hash of the join shape (cache key half).

        Two queries that join the same table occurrences on the same
        variables with the same projection hash identically, regardless of
        the order tables were listed in, the query's display ``name``, or
        the insertion order inside each ``var_map``.  An explicit projection
        equal to all variables canonicalizes to the implicit one, and
        projected-out variables are relabeled through
        :meth:`canonical_labels`, so syntactically permuted or
        alias-renamed but semantically identical queries share whole-query
        cache keys.  ``literal=True`` skips the relabeling — for keys that
        index artifacts carrying literal variable names (e.g. the
        `JoinService` plan cache, whose plans embed the query's own
        elimination-order names and must not be served to a renamed twin).

        ``plan`` (a ``repro.plan.ir.PhysicalPlan``, or anything with a
        ``signature()`` method) folds the chosen physical plan into the
        hash: the GFJS depends on the elimination order, so summaries built
        under different plans must never share a cache entry.  ``None``
        keeps the plan-agnostic hash (pre-planner compatibility).
        """
        labels = {v: v for v in self.variables} if literal \
            else self.canonical_labels()
        occurrences = sorted(
            (qt.table, tuple(sorted((c, labels[u]) for c, u in qt.var_map)))
            for qt in self.tables)
        output = self.output
        if output is not None and sorted(output) == sorted(self.variables):
            output = None
        canon = {
            "tables": [[t, list(map(list, vm))] for t, vm in occurrences],
            "output": sorted(output) if output is not None else None,
        }
        if plan is not None:
            if any(labels[v] != v for v in labels):
                try:
                    canon["plan"] = plan.signature(labels=labels)
                except TypeError:   # duck-typed plan without label support
                    canon["plan"] = plan.signature()
            else:
                canon["plan"] = plan.signature()
        return hashlib.sha256(
            json.dumps(canon, separators=(",", ":")).encode()).hexdigest()

    def is_cyclic(self) -> bool:
        """True iff the query hypergraph is cyclic (GYO reduction fails).

        GYO: repeatedly remove 'ear' hyperedges (edges whose variables are
        all private or contained in another edge).  Acyclic iff reduction
        empties the edge set.
        """
        edges = [set(e) for e in self.hyperedges()]
        changed = True
        while changed and len(edges) > 1:
            changed = False
            for i, e in enumerate(edges):
                others: Set[str] = set()
                for j, o in enumerate(edges):
                    if j != i:
                        others |= o
                shared = e & others
                # e is an ear if its shared part is contained in one other edge
                for j, o in enumerate(edges):
                    if j != i and shared <= o:
                        edges.pop(i)
                        changed = True
                        break
                if changed:
                    break
        return len(edges) > 1
