"""Join-query descriptions.

A :class:`JoinQuery` is an n-way natural equi-join over *variables*.  Each
participating table maps some of its columns onto query variables via
``var_map`` (column name -> variable name); two occurrences of the same
variable join.  Renaming through ``var_map`` supports self-joins (e.g. the
paper's lastFM_A1 joins ``user_artists`` twice under different variables)
and cyclic queries (triangles).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple


@dataclass(frozen=True)
class QueryTable:
    """One occurrence of a base table inside a join query."""

    table: str                       # base-table name in the catalog
    var_map: Tuple[Tuple[str, str], ...]  # (column, variable) pairs

    @staticmethod
    def of(table: str, var_map: Dict[str, str]) -> "QueryTable":
        return QueryTable(table, tuple(sorted(var_map.items())))

    @property
    def variables(self) -> Tuple[str, ...]:
        return tuple(v for _, v in self.var_map)

    @property
    def columns(self) -> Tuple[str, ...]:
        return tuple(c for c, _ in self.var_map)


@dataclass(frozen=True)
class JoinQuery:
    """An n-way equi-join: SELECT <output> FROM tables NATURAL-JOIN on vars."""

    name: str
    tables: Tuple[QueryTable, ...]
    output: Optional[Tuple[str, ...]] = None  # None => all variables

    @staticmethod
    def of(
        name: str,
        tables: Sequence[Tuple[str, Dict[str, str]]],
        output: Optional[Sequence[str]] = None,
    ) -> "JoinQuery":
        qts = tuple(QueryTable.of(t, vm) for t, vm in tables)
        return JoinQuery(name, qts, tuple(output) if output is not None else None)

    # -- structural helpers ----------------------------------------------
    @property
    def variables(self) -> List[str]:
        seen: List[str] = []
        for qt in self.tables:
            for v in qt.variables:
                if v not in seen:
                    seen.append(v)
        return seen

    @property
    def output_variables(self) -> List[str]:
        if self.output is None:
            return self.variables
        return list(self.output)

    def hyperedges(self) -> List[FrozenSet[str]]:
        """One hyperedge (clique) per table occurrence."""
        return [frozenset(qt.variables) for qt in self.tables]

    def join_variables(self) -> Set[str]:
        """Variables appearing in >= 2 table occurrences."""
        count: Dict[str, int] = {}
        for qt in self.tables:
            for v in set(qt.variables):
                count[v] = count.get(v, 0) + 1
        return {v for v, c in count.items() if c >= 2}

    def fingerprint(self, plan=None) -> str:
        """Canonical content hash of the join shape (cache key half).

        Two queries that join the same table occurrences on the same
        variables with the same projection hash identically, regardless of
        the order tables were listed in, the query's display ``name``, or
        the insertion order inside each ``var_map``.  An explicit projection
        equal to all variables canonicalizes to the implicit one.

        ``plan`` (a ``repro.plan.ir.PhysicalPlan``, or anything with a
        ``signature()`` method) folds the chosen physical plan into the
        hash: the GFJS depends on the elimination order, so summaries built
        under different plans must never share a cache entry.  ``None``
        keeps the plan-agnostic hash (pre-planner compatibility).
        """
        occurrences = sorted(
            (qt.table, tuple(sorted(qt.var_map))) for qt in self.tables)
        output = self.output
        if output is not None and sorted(output) == sorted(self.variables):
            output = None
        canon = {
            "tables": [[t, list(map(list, vm))] for t, vm in occurrences],
            "output": sorted(output) if output is not None else None,
        }
        if plan is not None:
            canon["plan"] = plan.signature()
        return hashlib.sha256(
            json.dumps(canon, separators=(",", ":")).encode()).hexdigest()

    def is_cyclic(self) -> bool:
        """True iff the query hypergraph is cyclic (GYO reduction fails).

        GYO: repeatedly remove 'ear' hyperedges (edges whose variables are
        all private or contained in another edge).  Acyclic iff reduction
        empties the edge set.
        """
        edges = [set(e) for e in self.hyperedges()]
        changed = True
        while changed and len(edges) > 1:
            changed = False
            for i, e in enumerate(edges):
                others: Set[str] = set()
                for j, o in enumerate(edges):
                    if j != i:
                        others |= o
                shared = e & others
                # e is an ear if its shared part is contained in one other edge
                for j, o in enumerate(edges):
                    if j != i and shared <= o:
                        edges.pop(i)
                        changed = True
                        break
                if changed:
                    break
        return len(edges) > 1
