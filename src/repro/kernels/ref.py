"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function computes exactly what its kernel computes, with no Pallas, no
padding contracts, and no dtype tricks — these are the ground truth for the
shape/dtype sweep tests in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def expand_gather_ref(payload: jax.Array, bounds: jax.Array, total: int) -> jax.Array:
    """RLE expansion: out[t] = payload[r] where bounds[r-1] <= t < bounds[r]."""
    t = jnp.arange(total, dtype=jnp.int32)
    idx = jnp.searchsorted(bounds, t, side="right")
    idx = jnp.minimum(idx, payload.shape[0] - 1)
    return payload[idx]


def mul_segsum_ref(seg_ids: jax.Array, x: jax.Array, y: jax.Array,
                   num_segments: int) -> jax.Array:
    """out[s] = sum_{i: seg_ids[i]==s} x[i]*y[i]."""
    return jax.ops.segment_sum((x * y).astype(jnp.float32), seg_ids,
                               num_segments=num_segments)


def run_boundaries_ref(keys: jax.Array) -> jax.Array:
    """flags[i] = 1 iff i == 0 or keys[i] != keys[i-1]."""
    if keys.shape[0] == 0:
        return jnp.zeros((0,), jnp.int32)
    head = jnp.ones((1,), jnp.int32)
    rest = (keys[1:] != keys[:-1]).astype(jnp.int32)
    return jnp.concatenate([head, rest])


def dense_message_ref(phi: jax.Array, m: jax.Array) -> jax.Array:
    """Counting-semiring matmul."""
    return (phi.astype(jnp.float32) @ m.astype(jnp.float32))
