"""Pallas TPU kernels for GJ's three hot primitives (DESIGN.md §2).

Layout (per the repo convention):
  expand.py / segsum.py / boundaries.py / dense_contract.py — pallas_call +
      explicit BlockSpec VMEM tiling, one file per kernel;
  ops.py — jit'd public wrappers (padding buckets, interpret dispatch);
  ref.py — pure-jnp oracles used by the allclose sweep tests.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
