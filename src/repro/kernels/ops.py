"""Public jit'd wrappers around the Pallas kernels.

These are what the JAX GJ engine calls.  Responsibilities:

* interpret-mode dispatch: on CPU backends the kernels execute their Python
  bodies (`interpret=True`); on TPU they compile to Mosaic.
* bucketized padding: output sizes are data-dependent in GJ, so callers pass
  the exact total and we round up to the next power-of-two bucket — jit
  caches stay bounded at O(log max-size) entries (DESIGN.md §2).
* dtype guards: the TPU kernels accumulate in f32 (exact < 2**24); wrappers
  fall back to exact XLA int64 paths above that.  On this CPU container the
  fallbacks also serve as the measured engine, with kernels validated via
  interpret mode in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import boundaries as _boundaries
from repro.kernels import dense_contract as _dense
from repro.kernels import expand as _expand
from repro.kernels import segsum as _segsum

F32_EXACT = 1 << 24


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def next_bucket(n: int, floor: int = 512) -> int:
    """Next power-of-two padding bucket (>= floor)."""
    b = floor
    while b < n:
        b <<= 1
    return b


def rle_expand(payload, bounds, total: int, *, interpret: bool | None = None):
    """Expand RLE runs to a flat array of ``total`` elements."""
    interpret = default_interpret() if interpret is None else interpret
    t_pad = next_bucket(max(total, 1))
    out = _expand.expand_gather(
        jnp.asarray(payload, jnp.int32), jnp.asarray(bounds, jnp.int32),
        t_pad=t_pad, interpret=interpret)
    return out[:total]


def expand_indices(bounds, total: int, *, interpret: bool | None = None):
    """Source-run index per output position (frontier expansion's `src`)."""
    n = bounds.shape[0]
    payload = jnp.arange(n, dtype=jnp.int32)
    return rle_expand(payload, bounds, total, interpret=interpret)


def mul_segsum(seg_ids, x, y, num_segments: int, *,
               interpret: bool | None = None, exact: bool = False):
    """Per-segment sum of x*y.  ``exact=True`` forces the int64 XLA path."""
    interpret = default_interpret() if interpret is None else interpret
    if exact:
        idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
        return jax.ops.segment_sum(
            jnp.asarray(x, idt) * jnp.asarray(y, idt),
            jnp.asarray(seg_ids, jnp.int32), num_segments=num_segments)
    out = _segsum.mul_segsum(
        jnp.asarray(seg_ids, jnp.int32),
        jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
        num_segments=num_segments, interpret=interpret)
    return out


def run_boundaries(keys, *, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _boundaries.run_boundaries(jnp.asarray(keys, jnp.int32),
                                      interpret=interpret)


def dense_message(phi, m, *, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    return _dense.dense_message(jnp.asarray(phi, jnp.float32),
                                jnp.asarray(m, jnp.float32),
                                interpret=interpret)


def group_by_count(keys, *, interpret: bool | None = None):
    """GROUP BY sorted keys: (segment ids, counts, num_groups).

    Composition of the two build kernels: run_boundaries -> cumsum ->
    mul_segsum(ones, ones).
    """
    flags = run_boundaries(keys, interpret=interpret)
    seg = jnp.cumsum(flags) - 1
    num = int(flags.sum())
    ones = jnp.ones_like(seg, dtype=jnp.float32)
    counts = mul_segsum(seg, ones, ones, num, interpret=interpret)
    return seg, counts, num
