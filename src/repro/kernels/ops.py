"""Public jit'd wrappers around the Pallas kernels.

These are what the JAX GJ engine calls.  Responsibilities:

* interpret-mode dispatch: on CPU backends the kernels execute their Python
  bodies (`interpret=True`); on TPU they compile to Mosaic.
* bucketized padding: output sizes are data-dependent in GJ, so callers pass
  the exact total and we round up to the next power-of-two bucket — jit
  caches stay bounded at O(log max-size) entries (DESIGN.md §2).
* dtype guards: the TPU kernels accumulate in f32 (exact < 2**24); wrappers
  fall back to exact XLA int64 paths above that.  On this CPU container the
  fallbacks also serve as the measured engine, with kernels validated via
  interpret mode in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import boundaries as _boundaries
from repro.kernels import dense_contract as _dense
from repro.kernels import expand as _expand
from repro.kernels import expand_fused as _expand_fused
from repro.kernels import segsum as _segsum
from repro.obs.metrics import REGISTRY
from repro.obs.trace import span as _span

F32_EXACT = 1 << 24


def _launch(kernel: str, expanded_bytes: int = 0, **args):
    """Count a kernel launch (+ bytes written by expansions) and open a
    device-annotated span — `jax.profiler.TraceAnnotation` rides along so
    host spans line up with device traces.  The span is the ambient no-op
    when tracing is off; the counters always accumulate."""
    REGISTRY.counter("kernels.launches").inc()
    if expanded_bytes:
        REGISTRY.counter("kernels.bytes_expanded", unit="B").inc(
            expanded_bytes)
    return _span(f"kernel:{kernel}", cat="kernel", device=True, **args)


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def next_bucket(n: int, floor: int = 512) -> int:
    """Next power-of-two padding bucket (>= floor)."""
    b = floor
    while b < n:
        b <<= 1
    return b


def rle_expand(payload, bounds, total: int, *, interpret: bool | None = None,
               meta=None):
    """Expand RLE runs to a flat array of ``total`` elements.

    ``meta`` is an optional ``(bounds_p, start_block)`` pair from
    `expand_meta`/`gfjs_expand_meta` — the memoized-launch path for levels
    expanded repeatedly.
    """
    interpret = default_interpret() if interpret is None else interpret
    t_pad = next_bucket(max(total, 1))
    payload = jnp.asarray(payload, jnp.int32)
    with _launch("rle_expand", expanded_bytes=total * 4, total=total):
        if meta is None:
            out = _expand.expand_gather(
                payload, jnp.asarray(bounds, jnp.int32),
                t_pad=t_pad, interpret=interpret)
        else:
            bounds_p, start_block = meta
            payload_p = jnp.pad(payload,
                                (0, bounds_p.shape[0] - payload.shape[0]))
            out = _expand.expand_gather_with_meta(
                payload_p, bounds_p, start_block, t_pad=t_pad,
                interpret=interpret)
    return out[:total]


def rle_expand_many(payloads, bounds, total: int, *,
                    interpret: bool | None = None, meta=None):
    """Expand K payload rows sharing one RLE — a single fused kernel launch.

    ``payloads`` is [K, Np]; the result is [K, total].  The fused kernel
    recovers each output tile's run index once and amortizes it over all K
    payload rows (codes of every variable in a GFJS level, plus the `src` /
    CSR-offset index columns of frontier expansion) — K times fewer kernel
    launches, bounds-window reads, and run searches than the per-column path.
    """
    interpret = default_interpret() if interpret is None else interpret
    t_pad = next_bucket(max(total, 1))
    payloads = jnp.asarray(payloads, jnp.int32)
    with _launch("rle_expand_many",
                 expanded_bytes=int(payloads.shape[0]) * total * 4,
                 k=int(payloads.shape[0]), total=total):
        if meta is None:
            out = _expand_fused.expand_gather_many(
                payloads, jnp.asarray(bounds, jnp.int32),
                t_pad=t_pad, interpret=interpret)
        else:
            bounds_p, start_block = meta
            payloads_p = jnp.pad(
                payloads,
                ((0, 0), (0, bounds_p.shape[0] - payloads.shape[1])))
            out = _expand_fused.expand_gather_many_with_meta(
                payloads_p, bounds_p, start_block, t_pad=t_pad,
                interpret=interpret)
    return out[:, :total]


def expand_meta(bounds, t_pad: int):
    """`launch_meta` for arbitrary bounds: (padded bounds, tile starts)."""
    return _expand.launch_meta(jnp.asarray(bounds, jnp.int32), t_pad=t_pad)


def gfjs_expand_meta(gfjs, level: int, t_pad: int):
    """Memoized launch metadata for expanding one GFJS level.

    Cached on ``GFJS._launch`` alongside the ``_bounds`` prefix sums —
    repeated expansion of the same level (the serve path's repeated
    desummarize, benchmarks, range shards sharing a bucket) skips the
    per-invocation host `searchsorted` over all output tiles.  One entry
    per level: a different ``t_pad`` replaces the cached pair, so the memo
    stays bounded and `GFJS.aux_nbytes` can account for it.
    """
    hit = gfjs._launch.get(level)
    if hit is None or hit[0] != t_pad:
        bounds = jnp.asarray(gfjs.bounds(level), jnp.int32)
        hit = (t_pad, _expand.launch_meta(bounds, t_pad=t_pad))
        gfjs._launch[level] = hit
    return hit[1]


def expand_indices(bounds, total: int, *, interpret: bool | None = None):
    """Source-run index per output position (frontier expansion's `src`)."""
    n = bounds.shape[0]
    payload = jnp.arange(n, dtype=jnp.int32)
    return rle_expand(payload, bounds, total, interpret=interpret)


def mul_segsum(seg_ids, x, y, num_segments: int, *,
               interpret: bool | None = None, exact: bool = False):
    """Per-segment sum of x*y.  ``exact=True`` forces the int64 XLA path."""
    interpret = default_interpret() if interpret is None else interpret
    if exact:
        idt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
        return jax.ops.segment_sum(
            jnp.asarray(x, idt) * jnp.asarray(y, idt),
            jnp.asarray(seg_ids, jnp.int32), num_segments=num_segments)
    with _launch("mul_segsum", segments=num_segments):
        out = _segsum.mul_segsum(
            jnp.asarray(seg_ids, jnp.int32),
            jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
            num_segments=num_segments, interpret=interpret)
    return out


def run_boundaries(keys, *, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    with _launch("run_boundaries"):
        return _boundaries.run_boundaries(jnp.asarray(keys, jnp.int32),
                                          interpret=interpret)


def dense_message(phi, m, *, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    with _launch("dense_message"):
        return _dense.dense_message(jnp.asarray(phi, jnp.float32),
                                    jnp.asarray(m, jnp.float32),
                                    interpret=interpret)


def group_by_count(keys, *, interpret: bool | None = None):
    """GROUP BY sorted keys: (segment ids, counts, num_groups).

    Composition of the two build kernels: run_boundaries -> cumsum ->
    mul_segsum(ones, ones).
    """
    flags = run_boundaries(keys, interpret=interpret)
    seg = jnp.cumsum(flags) - 1
    num = int(flags.sum())
    ones = jnp.ones_like(seg, dtype=jnp.float32)
    counts = mul_segsum(seg, ones, ones, num, interpret=interpret)
    return seg, counts, num
