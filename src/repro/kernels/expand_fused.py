"""`expand_gather_many` — fused multi-payload RLE-expansion Pallas kernel.

Desummarization and frontier expansion both expand *several* payload columns
by the *same* run-length structure: every variable of a GFJS level shares the
level's bounds, and a generation step needs (src, CSR start, offsets) plus
every frontier column expanded by one psi's counts.  The per-column kernel
(`expand.py`) pays the 2*RB comparison-matrix run search — the dominant VPU
cost — once per column, plus one kernel launch and one pass over the bounds
window per column.

This kernel recovers each output tile's run index **once** and then gathers
K payload rows with the same one-hot pick matrix: per output element the
search costs 2*RB int ops regardless of K, and the per-payload select-and-sum
is the only K-proportional term.  HBM traffic drops too — the bounds window
is read once instead of K times, and the scalar-prefetch `start_block`
metadata is computed (and memoizable, see `GFJS._launch`) once per level
instead of once per column.

Payloads ride as one [K, Np] int32 array; blocks are [K, RB] windows so the
whole payload stack for a run window is VMEM-resident (K * RB * 4 bytes —
kilobytes for any realistic level width).  The padding contract matches
`expand_gather`: runs [num_runs..Np) must carry bounds == total (zero
length), outputs [total..T_pad) replicate whatever the saturated run index
picks — callers slice [:, :total].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.expand import OT, RB, launch_meta


def _expand_many_kernel(start_block, bounds0, bounds1, payload0, payload1,
                        out_ref):
    """One output tile: recover run indices once, gather K payload rows."""
    i = pl.program_id(0)
    k = payload0.shape[0]
    t = (jax.lax.broadcasted_iota(jnp.int32, (OT, 2 * RB), 0) + i * OT)
    j = jax.lax.broadcasted_iota(jnp.int32, (OT, 2 * RB), 1)
    bounds = jnp.concatenate([bounds0[...], bounds1[...]])          # [2*RB]
    payload = jnp.concatenate([payload0[...], payload1[...]], axis=1)  # [K,2RB]

    # the amortized part: ONE comparison-matrix run search for all K payloads
    cmp = (bounds[None, :] <= t).astype(jnp.int32)                  # [OT,2RB]
    idx = jnp.sum(cmp, axis=1, keepdims=True, dtype=jnp.int32)      # [OT,1]
    idx = jnp.minimum(idx, 2 * RB - 1)
    pick = (j == idx).astype(payload.dtype)                         # [OT,2RB]

    rows = [jnp.sum(pick * payload[q][None, :], axis=1, dtype=out_ref.dtype)
            for q in range(k)]
    out_ref[...] = jnp.stack(rows, axis=0)                          # [K,OT]


@functools.partial(jax.jit, static_argnames=("t_pad", "interpret"))
def expand_gather_many_with_meta(
    payloads: jax.Array,     # [K, pad_to] int32 — pre-padded payload stack
    bounds_p: jax.Array,     # [pad_to] int32 — padded inclusive prefix sums
    start_block: jax.Array,  # [t_pad // OT] int32 — per-tile window starts
    *,
    t_pad: int,
    interpret: bool = False,
) -> jax.Array:
    """Fused expansion against precomputed launch metadata ([K, t_pad])."""
    assert t_pad % OT == 0, "t_pad must be a multiple of the output tile"
    k, pad_to = payloads.shape
    assert pad_to == bounds_p.shape[0], "payloads must match bounds padding"
    grid = t_pad // OT
    out = pl.pallas_call(
        _expand_many_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((RB,), lambda i, sb: (sb[i],)),
                pl.BlockSpec((RB,), lambda i, sb: (sb[i] + 1,)),
                pl.BlockSpec((k, RB), lambda i, sb: (0, sb[i])),
                pl.BlockSpec((k, RB), lambda i, sb: (0, sb[i] + 1)),
            ],
            out_specs=pl.BlockSpec((k, OT), lambda i, sb: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((k, t_pad), payloads.dtype),
        interpret=interpret,
    )(start_block, bounds_p, bounds_p, payloads, payloads)
    return out


@functools.partial(jax.jit, static_argnames=("t_pad", "interpret"))
def expand_gather_many(
    payloads: jax.Array,  # [K, Np] int32 — payload rows sharing one RLE
    bounds: jax.Array,    # [Np] int32 — inclusive prefix sums of run lengths
    *,
    t_pad: int,
    interpret: bool = False,
) -> jax.Array:
    """RLE-expand K payload rows by the shared ``bounds`` in one pass."""
    bounds_p, start_block = launch_meta(bounds, t_pad=t_pad)
    pad_to = bounds_p.shape[0]
    payloads_p = jnp.pad(payloads, ((0, 0), (0, pad_to - payloads.shape[1])))
    return expand_gather_many_with_meta(
        payloads_p, bounds_p, start_block, t_pad=t_pad, interpret=interpret)
