"""`expand_gather` — the RLE-expansion Pallas TPU kernel.

This is GJ's hottest primitive: desummarization writes |Q| × width bytes and
nothing else, so the roofline is pure HBM bandwidth (DESIGN.md §2).  The
kernel maps one grid step to one *output* tile of ``OT`` elements and must
answer, for every output position t, "which run am I in?".

TPU adaptation of the CPU algorithm (which is just ``np.repeat``):

* Run boundaries are an inclusive prefix sum ``bounds`` (monotone).  An
  output tile [t0, t0+OT) overlaps at most OT+1 runs because every run has
  length >= 1.  We therefore prefetch, per tile, a window of TWO consecutive
  run-blocks of size RB=OT each (`PrefetchScalarGridSpec`): the scalar
  argument ``start_block`` (computed with one cheap jnp.searchsorted on the
  host side of the jit) tells the BlockSpec index_map where the window
  starts.  start offset <= RB-1 plus OT+1 live runs always fits in 2*RB.
* Inside the kernel the run index is recovered *without* vector gathers
  (TPU Pallas has no general VMEM gather): a comparison matrix
  ``bounds_window[j] <= t`` summed over j gives the run index, and the
  payload is picked with a select-and-sum over the same window.  That costs
  2*RB integer VPU ops per output element — ~1k ops against an 8x128x8-lane
  VPU, i.e. still comfortably below the HBM-bandwidth bound of this kernel
  (napkin: 4 B/element out at 819 GB/s vs ~1k int-ops at ~100 Tops/s).

Padding contract: runs [num_runs..Np) must have bounds == bounds[num_runs-1]
(zero-length), outputs [total..T_pad) produce payload of the last live run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Output tile and run-block sizes.  8x128 = one float32 VREG tile; OT is a
# multiple so stores are lane-aligned.
OT = 512
RB = OT


def _expand_kernel(start_block, bounds0, bounds1, payload0, payload1, out_ref):
    """One output tile: recover run indices and gather payload."""
    i = pl.program_id(0)
    # 2-D iotas (TPU Mosaic requires >=2D); rows = output pos, cols = runs
    t = (jax.lax.broadcasted_iota(jnp.int32, (OT, 2 * RB), 0) + i * OT)
    j = jax.lax.broadcasted_iota(jnp.int32, (OT, 2 * RB), 1)
    bounds = jnp.concatenate([bounds0[...], bounds1[...]])     # [2*RB]
    payload = jnp.concatenate([payload0[...], payload1[...]])  # [2*RB]

    # comparison-matrix run search: idx[k] = #j with bounds[j] <= t[k]
    cmp = (bounds[None, :] <= t).astype(jnp.int32)             # [OT, 2RB]
    # pin the accumulator dtypes: x64 mode would promote these sums to int64,
    # which the int32 output ref rejects
    idx = jnp.sum(cmp, axis=1, keepdims=True, dtype=jnp.int32)  # [OT, 1]
    idx = jnp.minimum(idx, 2 * RB - 1)

    # select-and-sum payload pick (exact for any int payload)
    pick = (j == idx).astype(payload.dtype)                    # [OT, 2RB]
    out_ref[...] = jnp.sum(pick * payload[None, :], axis=1,
                           dtype=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t_pad",))
def launch_meta(bounds: jax.Array, *, t_pad: int):
    """Per-level launch metadata: padded bounds + per-tile window starts.

    The `start_block` scalar-prefetch argument is a host-side
    ``jnp.searchsorted`` over all output tiles — cheap, but it depends only
    on (bounds, t_pad), never on the payload.  Splitting it out lets callers
    that expand the same GFJS level repeatedly memoize it (``GFJS._launch``,
    populated by `repro.kernels.ops.gfjs_expand_meta`) and lets the fused
    multi-payload kernel share one computation across K columns.
    """
    n = bounds.shape[0]
    num_blocks = max(-(-n // RB), 1)
    pad_to = num_blocks * RB + RB  # +RB so block b0+1 always exists
    total = bounds[-1] if n else jnp.int32(0)
    # pad bounds with `total` so idx saturates into the dead region
    bounds_p = jnp.full((pad_to,), total, dtype=jnp.int32).at[:n].set(bounds)

    grid = t_pad // OT
    tile_lo = jax.lax.iota(jnp.int32, grid) * OT
    start_run = jnp.searchsorted(bounds_p[:n] if n else bounds_p[:1],
                                 tile_lo, side="right").astype(jnp.int32)
    start_block = jnp.clip(start_run // RB, 0, num_blocks - 1).astype(jnp.int32)
    return bounds_p, start_block


@functools.partial(jax.jit, static_argnames=("t_pad", "interpret"))
def expand_gather_with_meta(
    payload_p: jax.Array,    # [pad_to] int32 — pre-padded payload
    bounds_p: jax.Array,     # [pad_to] int32 — padded prefix sums
    start_block: jax.Array,  # [t_pad // OT] int32
    *,
    t_pad: int,
    interpret: bool = False,
) -> jax.Array:
    """Expansion against precomputed `launch_meta` (memoized-level path)."""
    assert t_pad % OT == 0, "t_pad must be a multiple of the output tile"
    grid = t_pad // OT
    out = pl.pallas_call(
        _expand_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((RB,), lambda i, sb: (sb[i],)),
                pl.BlockSpec((RB,), lambda i, sb: (sb[i] + 1,)),
                pl.BlockSpec((RB,), lambda i, sb: (sb[i],)),
                pl.BlockSpec((RB,), lambda i, sb: (sb[i] + 1,)),
            ],
            out_specs=pl.BlockSpec((OT,), lambda i, sb: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((t_pad,), payload_p.dtype),
        interpret=interpret,
    )(start_block, bounds_p, bounds_p, payload_p, payload_p)
    return out


@functools.partial(jax.jit, static_argnames=("t_pad", "interpret"))
def expand_gather(
    payload: jax.Array,   # [Np] int32 — per-run payload (values or indices)
    bounds: jax.Array,    # [Np] int32 — inclusive prefix sums of run lengths
    *,
    t_pad: int,           # static padded output length (multiple of OT)
    interpret: bool = False,
) -> jax.Array:
    """RLE-expand ``payload`` by run lengths encoded in ``bounds``."""
    bounds_p, start_block = launch_meta(bounds, t_pad=t_pad)
    payload_p = jnp.pad(payload, (0, bounds_p.shape[0] - payload.shape[0]))
    return expand_gather_with_meta(payload_p, bounds_p, start_block,
                                   t_pad=t_pad, interpret=interpret)
