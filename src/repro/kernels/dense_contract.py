"""`dense_message` — counting-semiring blocked matmul (MXU) for dense
potentials.

When a potential's key space is small enough to densify (|parent domain| x
|child domain| below a budget), the sum-product message
``m_out[p] = sum_v Phi[p, v] * m_in[v]`` is literally a matrix product in
the counting semiring — which *is* (+, x) — so it runs on the MXU at full
throughput instead of the VPU.  The JAX engine picks dense vs COO per
factor by fill ratio (see repro/core/engine_jax.py); this kernel is the
dense path, and also serves K stacked messages at once ([V, K]).

Classic 3-loop blocked matmul: grid (P/BP, K/BK, V/BV); the V axis is the
innermost (sequential) dimension and the output block is revisited across V
steps, accumulating in VMEM — the canonical Pallas accumulation pattern.
Tiles are 128-aligned for the 128x128 MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BP, BK, BV = 256, 128, 256


def _dense_message_kernel(phi_ref, m_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        phi_ref[...], m_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_message(
    phi: jax.Array,   # [P, V] float32 dense potential (counts)
    m: jax.Array,     # [V, K] float32 incoming messages
    *,
    interpret: bool = False,
) -> jax.Array:
    """[P, K] = phi @ m on the counting semiring (f32, exact < 2**24)."""
    P, V = phi.shape
    V2, K = m.shape
    assert V == V2
    Pp, Vp, Kp = -(-P // BP) * BP, -(-V // BV) * BV, -(-K // BK) * BK
    phi_p = jnp.zeros((Pp, Vp), jnp.float32).at[:P, :V].set(phi)
    m_p = jnp.zeros((Vp, Kp), jnp.float32).at[:V, :K].set(m)

    out = pl.pallas_call(
        _dense_message_kernel,
        grid=(Pp // BP, Kp // BK, Vp // BV),
        in_specs=[
            pl.BlockSpec((BP, BV), lambda i, j, k: (i, k)),
            pl.BlockSpec((BV, BK), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BP, BK), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Pp, Kp), jnp.float32),
        interpret=interpret,
    )(phi_p, m_p)
    return out[:P, :K]
