"""`mul_segsum` — fused multiply + segment-sum Pallas kernel.

This is the sum half of GJ's sum-product operation (message passing): given
entries sorted by (dense) segment id, compute ``out[s] = sum_i x[i]*y[i]``
over each segment.  On TPU the per-tile reduction is a one-hot matrix
product — an [T, T] f32 matmul that runs on the MXU — and the cross-tile
stitch (segments spanning tile boundaries add partials into the same slot)
is a tiny scatter-add done by XLA on the [num_tiles, T] partial matrix.

Why this shape: segment ids are *dense* (0..S-1, no gaps) by construction in
GJ (they come from run-boundary cumsums), so a tile of T entries touches at
most T distinct segments and the relative id ``seg - seg_first(tile)`` fits
in [0, T).  That bound is what lets the one-hot matrix be a fixed [T, T]
MXU tile instead of an unbounded scatter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

T = 512  # entries per tile; [T, T] one-hot fits VMEM (1 MiB f32)


def _mul_segsum_kernel(seg_ref, x_ref, y_ref, first_ref, part_ref):
    """Per-tile partial segment sums, relative to the tile's first id."""
    seg = seg_ref[...]
    first = seg[0]
    rel = seg - first                                        # [T] in [0, T)
    prod = (x_ref[...] * y_ref[...]).astype(jnp.float32)
    s = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)       # out slot
    onehot = (s == rel[None, :]).astype(jnp.float32)         # [T, T]
    # MXU: [T, T] @ [T] — per-slot sums of this tile's products
    part_ref[...] = jax.lax.dot_general(
        onehot, prod[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    first_ref[0] = first


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def mul_segsum(
    seg_ids: jax.Array,   # [N] int32, sorted ascending, dense ids
    x: jax.Array,         # [N]
    y: jax.Array,         # [N]
    *,
    num_segments: int,
    interpret: bool = False,
) -> jax.Array:
    """sum_i x[i]*y[i] per segment; f32 accumulate (exact below 2**24)."""
    n = seg_ids.shape[0]
    n_pad = max(-(-n // T), 1) * T
    # pad with an out-of-range segment id so padding lands in a dead slot
    seg_p = jnp.full((n_pad,), num_segments, jnp.int32).at[:n].set(seg_ids)
    x_p = jnp.zeros((n_pad,), x.dtype).at[:n].set(x)
    y_p = jnp.zeros((n_pad,), y.dtype).at[:n].set(y)
    grid = n_pad // T

    first, parts = pl.pallas_call(
        _mul_segsum_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((T,), lambda i: (i,)),
            pl.BlockSpec((T,), lambda i: (i,)),
            pl.BlockSpec((T,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((T,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid,), jnp.int32),
            jax.ShapeDtypeStruct((grid * T,), jnp.float32),
        ],
        interpret=interpret,
    )(seg_p, x_p, y_p)

    # stitch: scatter-add each tile's T relative slots at its first id
    parts = parts.reshape(grid, T)
    out = jnp.zeros((num_segments + T,), jnp.float32)
    idx = first[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    idx = jnp.minimum(idx, num_segments + T - 1)
    out = out.at[idx.reshape(-1)].add(parts.reshape(-1))
    return out[:num_segments]
