"""`run_boundaries` — run-start detection over sorted keys (Pallas).

The build side of a GROUP BY: given lexsorted (packed) key codes, emit a 0/1
flag per position marking the first entry of each run.  ``cumsum(flags)-1``
then yields the dense segment ids consumed by `mul_segsum`, and the flag sum
is the number of groups — together these two kernels implement the paper's
"scan the table once and count exact frequencies" (quantitative learning)
entirely on-device.

Cross-tile stencil: each grid step additionally maps the *previous* block of
the same input (index_map ``max(i-1, 0)``) and compares its last lane — the
standard Pallas trick for 1-element halos without a second pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

T = 1024


def _boundaries_kernel(cur_ref, prev_ref, out_ref):
    i = pl.program_id(0)
    cur = cur_ref[...]
    shifted = jnp.concatenate([prev_ref[...][-1:], cur[:-1]])
    flags = (cur != shifted).astype(jnp.int32)
    # position 0 of the whole array is always a run start
    first = (jax.lax.broadcasted_iota(jnp.int32, (T, 1), 0)[:, 0] == 0) & (i == 0)
    out_ref[...] = jnp.where(first, 1, flags)


@functools.partial(jax.jit, static_argnames=("interpret",))
def run_boundaries(keys: jax.Array, *, interpret: bool = False) -> jax.Array:
    """flags[i] = 1 iff keys[i] starts a new run (keys sorted, 1-D int32)."""
    n = keys.shape[0]
    n_pad = max(-(-n // T), 1) * T
    # pad with the last key so padding never creates a boundary
    fill = keys[-1] if n else jnp.int32(0)
    keys_p = jnp.full((n_pad,), fill, keys.dtype).at[:n].set(keys)
    out = pl.pallas_call(
        _boundaries_kernel,
        grid=(n_pad // T,),
        in_specs=[
            pl.BlockSpec((T,), lambda i: (i,)),
            pl.BlockSpec((T,), lambda i: (jnp.maximum(i - 1, 0),)),
        ],
        out_specs=pl.BlockSpec((T,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(keys_p, keys_p)
    return out[:n]
