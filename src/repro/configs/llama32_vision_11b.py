"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attention image layers every 5th layer; the vision
frontend is a STUB (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    head_dim=128,
    max_seq=131072,
    rope_theta=500_000.0,
    activation="silu",
    vlm=VLMConfig(cross_attn_every=5, vision_dim=1280, num_image_tokens=1601),
)
