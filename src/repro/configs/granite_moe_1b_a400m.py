"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512,
vocab=49155, 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    max_seq=4096,
    activation="silu",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, experts_per_token=8, shared_experts=0,
                  d_ff_expert=512, capacity_factor=1.25),
)
