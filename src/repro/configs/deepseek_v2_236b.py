"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 vocab=102400,
MLA kv_lora=512, MoE: 2 shared + 160 routed experts, top-6.
[arXiv:2405.04434; hf]"""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,                  # dense FFN of the first layer
    vocab=102400,
    head_dim=128,
    max_seq=131072,
    rope_theta=10_000.0,
    activation="silu",
    moe=MoEConfig(num_experts=160, experts_per_token=6, shared_experts=2,
                  d_ff_expert=1536, capacity_factor=1.25,
                  first_dense_layers=1),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
)
