"""Assigned architecture configs (one module per arch) + registry.

``get_config(arch_id)`` returns the full-scale ModelConfig exactly as
assigned; ``get_smoke(arch_id)`` the reduced same-family variant used by the
CPU smoke tests.  ``SHAPES`` defines the four assigned input shapes and
``applicable_shapes`` encodes the skip rules of DESIGN.md §5.
"""

from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig, smoke_variant

ARCH_IDS = [
    "gemma3_4b",
    "qwen3_8b",
    "starcoder2_3b",
    "nemotron_4_15b",
    "zamba2_2p7b",
    "deepseek_v2_236b",
    "granite_moe_1b_a400m",
    "llama32_vision_11b",
    "hubert_xlarge",
    "xlstm_350m",
]

# assigned LM shape grid: name -> (seq_len, global_batch, kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# archs with an O(1)-state decode path run long_500k; encoder-only skips
# all decode shapes (DESIGN.md §5)
LONG_OK = {"zamba2_2p7b", "xlstm_350m"}
ENCODER_ONLY = {"hubert_xlarge"}


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return smoke_variant(get_config(arch_id))


def applicable_shapes(arch_id: str) -> List[str]:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    out = ["train_4k", "prefill_32k"]
    if arch_id not in ENCODER_ONLY:
        out.append("decode_32k")
        if arch_id in LONG_OK:
            out.append("long_500k")
    return out


def skip_reason(arch_id: str, shape: str) -> str:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    if shape in applicable_shapes(arch_id):
        return ""
    if arch_id in ENCODER_ONLY:
        return "encoder-only: no decode step"
    return "full quadratic attention: no sub-quadratic long-context path"
