"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    max_seq=4096,
    activation="gelu",
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, head_dim=64,
                  chunk=256, attn_every=6, shared_attn=True),
)
