"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504 —
encoder-only (no causal mask, no decode); the conv feature frontend is a
STUB (input_specs provides precomputed 512-d frame embeddings).
[arXiv:2106.07447; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    max_seq=32768,
    causal=False,
    activation="gelu",
    gated_mlp=False,
)
