"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,
    max_seq=131072,
    sliding_window=1024,
    local_global_pattern=5,      # 5 local layers, then 1 global
    attn_logit_softcap=50.0,
    rope_theta=1_000_000.0,
    activation="gelu",
    tie_embeddings=True,
)
