"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    head_dim=128,
    max_seq=16384,
    sliding_window=4096,
    rope_theta=999_999.0,
    activation="gelu",
    gated_mlp=False,
)
