"""xlstm-350m [ssm]: 24L d_model=1024 4H vocab=50304 — alternating
sLSTM + mLSTM blocks (d_ff=0: blocks carry their own projections).
[arXiv:2405.04517; unverified]"""

from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=256,
    max_seq=524288,
    xlstm=XLSTMConfig(slstm_every=2, mlstm_proj_factor=2.0,
                      slstm_proj_factor=1.3333, mlstm_head_dim=256),
)
