"""Graphical Join core — the paper's contribution.

Public surface:

* :class:`repro.core.api.GraphicalJoin` — end-to-end driver
* :class:`repro.core.gfjs.GFJS` — the Grouped Frequentist Join Summary
* :mod:`repro.core.baselines` — binary-plan and WCOJ competitors
"""

from repro.core.api import GraphicalJoin
from repro.core.gfjs import (GFJS, ShardedGFJS, desummarize,
                             desummarize_range, generate_gfjs, row_at,
                             stream_desummarize)
from repro.core.elimination import Generator, build_generator
from repro.core.potentials import Factor
from repro.core.storage import load_gfjs, save_gfjs, gfjs_to_csv

__all__ = [
    "GraphicalJoin", "GFJS", "ShardedGFJS", "Generator", "Factor",
    "build_generator", "generate_gfjs", "desummarize", "desummarize_range",
    "stream_desummarize", "row_at", "save_gfjs", "load_gfjs", "gfjs_to_csv",
]
