"""Potentials (factors) over dictionary-encoded attribute domains.

A :class:`Factor` is the paper's *potential function*: an exact frequency
table over a set of query variables.  The paper implements potentials as
nested hash maps; per DESIGN.md §2 we use the TPU-idiomatic equivalent — a
COO tensor (lexsorted integer key rows + value columns) manipulated with
sort / searchsorted / segment-sum primitives.

Every factor carries **two** value columns:

* ``bucket`` — the product of *original* (table-derived) potential values
  folded into this factor so far;
* ``fac``    — the product of *message* values (sums produced by variable
  elimination) folded in so far.

The paper's Algorithm 2 stores exactly this split in its conditional factors
(columns named ``bucket`` and ``fac`` in Figure 8); keeping the split all the
way through the factor algebra is what lets GFJS generation run without any
divisions (see repro/core/elimination.py).
The effective frequency of an entry is always ``bucket * fac``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

INT = np.int64


def pack_keys(keys: np.ndarray, sizes: Sequence[int]) -> np.ndarray:
    """Mixed-radix pack of key columns into a single int64 rank.

    ``keys`` is [n, k]; ``sizes`` the per-column domain sizes.  Requires
    prod(sizes) < 2**63 (checked); callers fall back to lexsort otherwise.
    """
    total = 1
    for s in sizes:
        total *= max(int(s), 1)
        if total >= (1 << 62):
            raise OverflowError("key space too large to pack")
    if keys.ndim != 2:
        raise ValueError("keys must be [n, k]")
    out = np.zeros(len(keys), dtype=INT)
    for j, s in enumerate(sizes):
        out = out * max(int(s), 1) + keys[:, j]
    return out


def _rank_rows(keys: np.ndarray, sizes: Sequence[int]) -> Tuple[np.ndarray, bool]:
    """Return a 1-D sortable rank per row; bool says whether it's a pack
    (order-preserving & collision-free) or a dense re-rank."""
    try:
        return pack_keys(keys, sizes), True
    except OverflowError:
        # dense re-rank: lexsort, then run-index the unique rows
        order = np.lexsort(keys.T[::-1])
        sk = keys[order]
        new = np.ones(len(sk), dtype=bool)
        new[1:] = np.any(sk[1:] != sk[:-1], axis=1)
        run = np.cumsum(new) - 1
        ranks = np.empty(len(sk), dtype=INT)
        ranks[order] = run
        return ranks, False


def group_ranks(
    ranks: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Stable sort-and-segment of a 1-D rank array.

    Returns ``(order, seg, starts, num_groups)``: ``order`` sorts the
    ranks stably, ``seg[i]`` is the dense group id of sorted position
    ``i`` (int32 — group counts are bounded by the row count), ``starts``
    the sorted positions where a new group begins.  The host-side twin of
    ``engine_jax.group_runs_device`` and the one GROUP BY segmentation
    idiom shared by the summary algebra (monolithic and shard-merge).
    """
    order = np.argsort(ranks, kind="stable")
    sranks = ranks[order]
    new = np.ones(len(sranks), dtype=bool)
    new[1:] = sranks[1:] != sranks[:-1]
    seg = (np.cumsum(new) - 1).astype(np.int32)
    starts = np.flatnonzero(new)
    return order, seg, starts, len(starts)


@dataclass
class Factor:
    """COO frequency tensor over ``vars`` with bucket/fac value split."""

    vars: Tuple[str, ...]
    keys: np.ndarray     # [n, k] int64 codes, one column per var
    bucket: np.ndarray   # [n] int64
    fac: np.ndarray      # [n] int64
    sizes: Tuple[int, ...]  # per-var domain sizes (for packing)

    def __post_init__(self) -> None:
        self.keys = np.asarray(self.keys, dtype=INT).reshape(len(self.bucket), len(self.vars))
        self.bucket = np.asarray(self.bucket, dtype=INT)
        self.fac = np.asarray(self.fac, dtype=INT)

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_columns(
        cols: Dict[str, np.ndarray], sizes: Dict[str, int]
    ) -> "Factor":
        """GROUP BY all columns, COUNT(*): the paper's quantitative learning.

        One scan (a lexsort + run-length count) per table: O(n log n) work,
        O(N) memory — the paper's 'scan each table once' step.
        """
        names = tuple(cols.keys())
        keys = np.stack([np.asarray(cols[v], dtype=INT) for v in names], axis=1)
        sz = tuple(int(sizes[v]) for v in names)
        if keys.shape[0] == 0:
            return Factor(names, keys, np.zeros(0, INT), np.zeros(0, INT), sz)
        ranks, _ = _rank_rows(keys, sz)
        order = np.argsort(ranks, kind="stable")
        keys = keys[order]
        sranks = ranks[order]
        new = np.ones(len(sranks), dtype=bool)
        new[1:] = sranks[1:] != sranks[:-1]
        starts = np.flatnonzero(new)
        counts = np.diff(np.append(starts, len(sranks)))
        ukeys = keys[starts]
        return Factor(names, ukeys, counts.astype(INT), np.ones(len(starts), INT), sz)

    @staticmethod
    def message(vars: Tuple[str, ...], keys: np.ndarray, value: np.ndarray,
                sizes: Tuple[int, ...]) -> "Factor":
        """A message factor: its value rides in the ``fac`` column."""
        return Factor(vars, keys, np.ones(len(value), INT), np.asarray(value, INT), sizes)

    def merge_counts(self, other: "Factor") -> "Factor":
        """Pointwise sum of two bucket-count factors over the same schema.

        The delta-refresh primitive: a base-table append's potential is the
        GROUP BY of the appended block alone, and the grown table's
        potential is ``old.merge_counts(delta)`` — O((n+d) log(n+d)) on
        factor entries, never a rescan of the base rows.  Both sides must
        be pure table potentials (``fac == 1`` everywhere).
        """
        if self.vars != other.vars or self.sizes != other.sizes:
            raise ValueError(
                f"merge_counts schema mismatch: {self.vars}/{self.sizes} "
                f"vs {other.vars}/{other.sizes}")
        if np.any(self.fac != 1) or np.any(other.fac != 1):
            raise ValueError("merge_counts only applies to table potentials")
        if other.num_entries == 0:
            return self
        if self.num_entries == 0:
            return other
        keys = np.concatenate([self.keys, other.keys], axis=0)
        bucket = np.concatenate([self.bucket, other.bucket])
        ranks, _ = _rank_rows(keys, self.sizes)
        order = np.argsort(ranks, kind="stable")
        keys, sranks, bucket = keys[order], ranks[order], bucket[order]
        new = np.ones(len(sranks), dtype=bool)
        new[1:] = sranks[1:] != sranks[:-1]
        starts = np.flatnonzero(new)
        seg = np.cumsum(new) - 1
        sums = np.zeros(len(starts), dtype=INT)
        np.add.at(sums, seg, bucket)
        return Factor(self.vars, keys[starts], sums,
                      np.ones(len(starts), INT), self.sizes)

    # -- basics --------------------------------------------------------------
    @property
    def num_entries(self) -> int:
        return len(self.bucket)

    @property
    def freq(self) -> np.ndarray:
        return self.bucket * self.fac

    def var_index(self, v: str) -> int:
        return self.vars.index(v)

    def col(self, v: str) -> np.ndarray:
        return self.keys[:, self.var_index(v)]

    def sort_by(self, by: Sequence[str]) -> "Factor":
        idx = [self.var_index(v) for v in by]
        sub = self.keys[:, idx]
        ranks, packed = _rank_rows(sub, [self.sizes[i] for i in idx])
        order = np.argsort(ranks, kind="stable")
        return Factor(self.vars, self.keys[order], self.bucket[order],
                      self.fac[order], self.sizes)

    def select_nonzero(self) -> "Factor":
        m = (self.bucket != 0) & (self.fac != 0)
        if m.all():
            return self
        return Factor(self.vars, self.keys[m], self.bucket[m], self.fac[m], self.sizes)

    # -- elimination primitives ---------------------------------------------
    def marginalize_out(self, v: str) -> "Factor":
        """Sum out ``v``: the sum half of the paper's sum-product operation.

        Result is a *message*: value = sum(bucket*fac) goes to ``fac``.
        """
        keep = [i for i, u in enumerate(self.vars) if u != v]
        kvars = tuple(self.vars[i] for i in keep)
        ksizes = tuple(self.sizes[i] for i in keep)
        if not keep:
            total = np.array([np.sum(self.bucket * self.fac)], dtype=INT)
            return Factor.message((), np.zeros((1, 0), INT), total, ())
        sub = self.keys[:, keep]
        ranks, _ = _rank_rows(sub, ksizes)
        order = np.argsort(ranks, kind="stable")
        sub, ranks = sub[order], ranks[order]
        val = (self.bucket * self.fac)[order]
        new = np.ones(len(ranks), dtype=bool)
        new[1:] = ranks[1:] != ranks[:-1]
        starts = np.flatnonzero(new)
        seg = np.cumsum(new) - 1
        sums = np.zeros(len(starts), dtype=INT)
        np.add.at(sums, seg, val)
        return Factor.message(kvars, sub[starts], sums, ksizes)

    def multiply(self, other: "Factor") -> "Factor":
        """Pairwise factor product (natural join of frequency tables).

        Buckets multiply with buckets, facs with facs — preserving the
        original/message provenance split through arbitrary products.
        """
        shared = [v for v in self.vars if v in other.vars]
        only_o = [v for v in other.vars if v not in self.vars]
        out_vars = self.vars + tuple(only_o)
        out_sizes = self.sizes + tuple(other.sizes[other.var_index(v)] for v in only_o)

        if not shared:  # Cartesian product (disconnected factors)
            n, m = self.num_entries, other.num_entries
            li = np.repeat(np.arange(n), m)
            ri = np.tile(np.arange(m), n)
            keys = np.concatenate(
                [self.keys[li]] +
                ([other.keys[ri][:, [other.var_index(v) for v in only_o]]] if only_o else []),
                axis=1)
            return Factor(out_vars, keys,
                          self.bucket[li] * other.bucket[ri],
                          self.fac[li] * other.fac[ri], out_sizes)

        si = [self.var_index(v) for v in shared]
        oi = [other.var_index(v) for v in shared]
        ssz = [self.sizes[i] for i in si]

        lrank, _ = _rank_rows_joint(self.keys[:, si], other.keys[:, oi], ssz)
        lr, rr = lrank
        lorder = np.argsort(lr, kind="stable")
        rorder = np.argsort(rr, kind="stable")
        lr_s, rr_s = lr[lorder], rr[rorder]

        # group boundaries on both sides
        lu, lstart = _runs(lr_s)
        ru, rstart = _runs(rr_s)
        lcount = np.diff(np.append(lstart, len(lr_s)))
        rcount = np.diff(np.append(rstart, len(rr_s)))

        # intersect group keys (both sides sorted unique: merge via
        # searchsorted -- profiling showed np.intersect1d's hash path
        # dominating cyclic-query elimination; see EXPERIMENTS.md #Perf)
        pos = np.searchsorted(ru, lu)
        pos_c = np.minimum(pos, max(len(ru) - 1, 0))
        match = (ru[pos_c] == lu) if len(ru) else np.zeros(len(lu), bool)
        li_g = np.flatnonzero(match)
        ri_g = pos[li_g]
        a = lcount[li_g]
        b = rcount[ri_g]
        group_out = a * b
        total = int(group_out.sum())
        # O(total) expansion via repeat (was searchsorted: EXPERIMENTS GJ-2)
        g = np.repeat(np.arange(len(group_out), dtype=INT), group_out)
        offsets = np.cumsum(group_out) - group_out
        local = np.arange(total, dtype=INT) - offsets[g]
        lrow = lorder[lstart[li_g][g] + local // b[g]]
        rrow = rorder[rstart[ri_g][g] + local % b[g]]

        cols = [self.keys[lrow]]
        if only_o:
            cols.append(other.keys[rrow][:, [other.var_index(v) for v in only_o]])
        keys = np.concatenate(cols, axis=1)
        return Factor(out_vars, keys,
                      self.bucket[lrow] * other.bucket[rrow],
                      self.fac[lrow] * other.fac[rrow], out_sizes)

    def semijoin(self, other: "Factor") -> "Factor":
        """Keep only entries whose shared-variable values appear in other."""
        shared = [v for v in self.vars if v in other.vars]
        if not shared:
            return self
        si = [self.var_index(v) for v in shared]
        oi = [other.var_index(v) for v in shared]
        ssz = [self.sizes[i] for i in si]
        (lr, rr), _ = _rank_rows_joint(self.keys[:, si], other.keys[:, oi], ssz)
        rs = np.sort(rr)
        pos = np.searchsorted(rs, lr)
        pos = np.minimum(pos, max(len(rs) - 1, 0))
        mask = (rs[pos] == lr) if len(rs) else np.zeros(len(lr), bool)
        return Factor(self.vars, self.keys[mask], self.bucket[mask],
                      self.fac[mask], self.sizes)

    def project(self, vars: Sequence[str]) -> "Factor":
        """Reorder/restrict columns (no aggregation)."""
        idx = [self.var_index(v) for v in vars]
        return Factor(tuple(vars), self.keys[:, idx], self.bucket, self.fac,
                      tuple(self.sizes[i] for i in idx))

    def total(self) -> int:
        return int(np.sum(self.bucket * self.fac))


def _runs(sorted_ranks: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Unique values and run starts of a sorted 1-D array."""
    if len(sorted_ranks) == 0:
        return sorted_ranks, np.zeros(0, dtype=INT)
    new = np.ones(len(sorted_ranks), dtype=bool)
    new[1:] = sorted_ranks[1:] != sorted_ranks[:-1]
    starts = np.flatnonzero(new)
    return sorted_ranks[starts], starts


def _rank_rows_joint(
    a: np.ndarray, b: np.ndarray, sizes: Sequence[int]
) -> Tuple[Tuple[np.ndarray, np.ndarray], bool]:
    """Consistent 1-D ranks for two key matrices over the same columns."""
    try:
        return (pack_keys(a, sizes), pack_keys(b, sizes)), True
    except OverflowError:
        both = np.concatenate([a, b], axis=0)
        order = np.lexsort(both.T[::-1])
        sk = both[order]
        new = np.ones(len(sk), dtype=bool)
        new[1:] = np.any(sk[1:] != sk[:-1], axis=1)
        run = np.cumsum(new) - 1
        ranks = np.empty(len(sk), dtype=INT)
        ranks[order] = run
        return (ranks[: len(a)], ranks[len(a):]), False
