"""Public Graphical Join API — the paper's Figure 4 pipeline as one object.

    gj = GraphicalJoin(catalog, query)
    gj.build_model()        # qualitative + quantitative learning   (O(N))
    gj.build_generator()    # Algorithm 2 (+ Algorithm 1 on cycles) (O(M^rho))
    gfjs = gj.summarize()   # Algorithms 3/4                        (O(M^rho))
    gj.store(path); gfjs = gj.load(path)          # compute-and-reuse
    result = gj.desummarize(gfjs)                 # O(|Q|)

Each phase records wall time into ``gj.timings`` — benchmark Table 6 (PGM
build share) reads from there.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.elimination import Generator, build_generator
from repro.core.gfjs import (GFJS, desummarize, desummarize_range,
                             generate_gfjs, stream_desummarize)
from repro.core.storage import load_gfjs, save_gfjs
from repro.relational.encoding import EncodedQuery, encode_query
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog


class GraphicalJoin:
    """End-to-end driver for the Graphical Join."""

    def __init__(
        self,
        catalog: Catalog,
        query: JoinQuery,
        *,
        elimination_order: Optional[Sequence[str]] = None,
        early_projection: bool = True,
    ) -> None:
        self.catalog = catalog
        self.query = query
        self.elimination_order = elimination_order
        self.early_projection = early_projection
        self.timings: Dict[str, float] = {}
        self.enc: Optional[EncodedQuery] = None
        self.generator: Optional[Generator] = None

    # -- phases ------------------------------------------------------------
    def build_model(self) -> "GraphicalJoin":
        """Qualitative (graph) + quantitative (potentials at encode time)."""
        t0 = time.perf_counter()
        self.enc = encode_query(self.catalog, self.query)
        self.timings["build_model"] = time.perf_counter() - t0
        return self

    def build_generator(self) -> "GraphicalJoin":
        if self.enc is None:
            self.build_model()
        t0 = time.perf_counter()
        self.generator = build_generator(
            self.enc,
            elimination_order=self.elimination_order,
            early_projection=self.early_projection,
        )
        self.timings["build_generator"] = time.perf_counter() - t0
        return self

    def summarize(self) -> GFJS:
        if self.generator is None:
            self.build_generator()
        t0 = time.perf_counter()
        gfjs = generate_gfjs(self.generator, self.enc.domains)
        self.timings["summarize"] = time.perf_counter() - t0
        return gfjs

    # -- convenience -------------------------------------------------------
    def join_size(self) -> int:
        """|Q| without touching the data again (sum of the root marginal)."""
        if self.generator is None:
            self.build_generator()
        return self.generator.join_size

    def run(self) -> GFJS:
        """build_model -> build_generator -> summarize."""
        return self.summarize()

    def aggregate(self, op: str, var: Optional[str] = None, *,
                  by: Optional[Sequence[str]] = None,
                  where: Optional[Dict] = None,
                  gfjs: Optional[GFJS] = None):
        """Answer an aggregate from the summary — O(runs), never O(|Q|).

            gj.aggregate("count")
            gj.aggregate("sum", "D", by=["A"], where={"B": "b1"})

        ``op``: count / sum / mean / min / max / distinct / count_distinct.
        Pass a previously computed ``gfjs`` to reuse it (the compute-and-
        reuse path); otherwise the pipeline runs (or re-runs) first.  The
        summary-side time lands in ``timings["aggregate"]``.
        """
        from repro.summary.algebra import SummaryFrame
        if gfjs is None:
            gfjs = self.run()
        t0 = time.perf_counter()
        frame = SummaryFrame.of(gfjs)
        if where:
            frame = frame.filter(where)
        if by:
            if op == "count":
                out = frame.group_by(list(by), count="count")
            else:
                if var is None:
                    raise ValueError(f"aggregate {op!r} needs a variable")
                out = frame.group_by(list(by), **{op: (op, var)})
        elif op == "count":
            out = frame.count()
        elif op in ("sum", "mean", "min", "max", "distinct", "count_distinct"):
            if var is None:
                raise ValueError(f"aggregate {op!r} needs a variable")
            out = getattr(frame, op)(var)
        else:
            raise ValueError(f"unknown aggregate op {op!r}")
        self.timings["aggregate"] = time.perf_counter() - t0
        return out

    def desummarize(self, gfjs: GFJS, *, decode: bool = True) -> Dict[str, np.ndarray]:
        t0 = time.perf_counter()
        out = desummarize(gfjs, decode=decode)
        self.timings["desummarize"] = time.perf_counter() - t0
        return out

    def desummarize_range(self, gfjs: GFJS, lo: int, hi: int, *, decode: bool = True):
        return desummarize_range(gfjs, lo, hi, decode=decode)

    def stream(self, gfjs: GFJS, chunk_rows: int = 1 << 20, *, decode: bool = True):
        return stream_desummarize(gfjs, chunk_rows, decode=decode)

    def store(self, gfjs: GFJS, path: str) -> int:
        t0 = time.perf_counter()
        n = save_gfjs(gfjs, path)
        self.timings["store"] = time.perf_counter() - t0
        return n

    @staticmethod
    def load(path: str) -> GFJS:
        return load_gfjs(path)
