"""Public Graphical Join API — a thin facade over plan + execute.

    gj = GraphicalJoin(catalog, query)
    gj.build_model()        # qualitative + quantitative learning   (O(N))
    plan = gj.plan()        # cost-based elimination-order search
    gj.build_generator()    # Algorithm 2 (+ Algorithm 1 on cycles) (O(M^rho))
    gfjs = gj.summarize()   # Algorithms 3/4                        (O(M^rho))
    print(gj.explain())     # order, per-step estimates, backends, timings
    gj.store(path); gfjs = gj.load(path)          # compute-and-reuse
    result = gj.desummarize(gfjs)                 # O(|Q|)

The pipeline itself lives in :mod:`repro.plan`: ``plan_query`` searches
elimination orders with a statistics-driven cost model (min-fill is one
candidate among several) and pins the physical choices; ``Executor`` runs
the phases.  This class keeps the paper-shaped surface — and the
``gj.timings`` / ``gj.enc`` / ``gj.generator`` attributes the tests and
benchmarks read — stable across that refactor.

Each phase records wall time into ``gj.timings`` — benchmark Table 6 (PGM
build share) reads from there.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.elimination import Generator
from repro.core.gfjs import GFJS, desummarize_range, stream_desummarize
from repro.core.storage import load_gfjs, save_gfjs
from repro.relational.encoding import EncodedQuery
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog

# NOTE: repro.plan is imported lazily inside __init__ — the plan package
# consumes repro.core.{graph,potentials,elimination}, so a module-level
# import here would close an import cycle through repro.core.__init__.


class GraphicalJoin:
    """End-to-end driver for the Graphical Join.

    ``elimination_order`` forces a specific order (bypassing the search);
    ``planner`` selects the search mode ("cost" — the default candidate
    search, or "min_fill" — the paper's lone heuristic); ``plan`` injects a
    pre-compiled :class:`PhysicalPlan` (the `JoinService` serve path);
    ``record_trace`` keeps the elimination trace + expansion indices so
    `capture_state`/`refresh` can maintain the summary incrementally on
    base-table appends (repro/summary/incremental.py); ``generation_backend``
    pins GFJS generation to "numpy" (dynamic-shape oracle) or "jax" (the
    device-resident frontier of `engine_jax.generate_gfjs_jax`);
    ``partitions`` > 1 runs hash-partitioned (repro/dist/partition.py):
    ``run()`` returns a :class:`~repro.core.gfjs.ShardedGFJS` whose shards
    were built independently (``partition_var`` overrides the planner's
    partition-key choice; incremental refresh is unsupported and falls
    back to rebuild); ``shard_executor`` picks where shard pipelines run
    ("thread" — default — or "process": the repro/dist/actions.py spawn
    pool), ``partition_fold`` over-partitions for skew smoothing, and
    ``shard_timeout`` (seconds) bounds each process-shard action before
    the degrade-to-thread retry; ``hybrid`` controls hypertree-decomposed
    hybrid GJ/WCOJ execution on cyclic queries (None — default — lets the
    cost model choose between pure GJ and the bagged plan, True forces
    bags and raises on acyclic queries or with ``record_trace``, False
    forces pure GJ; acyclic plans are never bagged and keep their exact
    historical signatures); ``tracer`` / ``metrics`` plug a
    :class:`repro.obs.Tracer` / :class:`repro.obs.MetricsRegistry` into
    every phase (off by default — see repro/obs and ``explain(analyze=True)``);
    ``message_cache`` plugs a :class:`repro.summary.msgcache.MessageCache`
    into planning (residency-aware step pricing) and elimination (cached
    messages are injected, skipping product+marginalization — refused for
    ``record_trace``, bagged, or partitioned builds); ``corrections`` seeds
    the cost model with persisted per-step calibration ratios (the
    `JoinService` calibration sidecar).
    """

    def __init__(
        self,
        catalog: Catalog,
        query: JoinQuery,
        *,
        elimination_order: Optional[Sequence[str]] = None,
        early_projection: bool = True,
        planner: str = "cost",
        plan: Optional["PhysicalPlan"] = None,
        record_trace: bool = False,
        generation_backend: Optional[str] = None,
        partitions: Optional[int] = None,
        partition_var: Optional[str] = None,
        partition_fold: Optional[int] = None,
        shard_executor: Optional[str] = None,
        shard_timeout: Optional[float] = None,
        hybrid: Optional[bool] = None,
        tracer=None,
        metrics=None,
        message_cache=None,
        corrections: Optional[Dict[str, float]] = None,
    ) -> None:
        from repro.plan.executor import Executor
        self.catalog = catalog
        self.query = query
        self._executor = Executor(
            catalog, query,
            elimination_order=elimination_order,
            early_projection=early_projection,
            planner=planner,
            plan=plan,
            record_trace=record_trace,
            generation_backend=generation_backend,
            partitions=partitions,
            partition_var=partition_var,
            partition_fold=partition_fold,
            shard_executor=shard_executor,
            shard_timeout=shard_timeout,
            hybrid=hybrid,
            tracer=tracer,
            metrics=metrics,
            message_cache=message_cache,
            corrections=corrections,
        )

    # -- executor state, exposed under the historical names ----------------
    @property
    def timings(self) -> Dict[str, float]:
        return self._executor.timings

    @property
    def enc(self) -> Optional[EncodedQuery]:
        return self._executor.enc

    @property
    def generator(self) -> Optional[Generator]:
        return self._executor.generator

    # configuration reads/writes pass through to the executor so that
    # post-construction mutation (the historical pattern
    # ``gj.elimination_order = [...]; gj.build_generator()``) stays live —
    # a pending plan is discarded so the next phase re-plans
    @property
    def elimination_order(self) -> Optional[Sequence[str]]:
        return self._executor.elimination_order

    @elimination_order.setter
    def elimination_order(self, value: Optional[Sequence[str]]) -> None:
        self._executor.elimination_order = value
        self._invalidate_plan()

    @property
    def early_projection(self) -> bool:
        return self._executor.early_projection

    @early_projection.setter
    def early_projection(self, value: bool) -> None:
        self._executor.early_projection = value
        self._invalidate_plan()

    def _invalidate_plan(self) -> None:
        ex = self._executor
        if not ex._forced_plan:
            ex.plan = None
            ex.logical = None
            ex.generator = None
            ex._sharded = None

    # -- phases ------------------------------------------------------------
    def build_model(self) -> "GraphicalJoin":
        """Qualitative (graph) + quantitative (potentials at encode time).

        Calling this again re-encodes and clears every downstream product
        (plan, generator, timings): a re-planned query never silently
        reuses a generator built on stale encodings.
        """
        self._executor.build_model()
        return self

    def plan(self) -> "PhysicalPlan":
        """The physical plan (computed on first use, then pinned)."""
        return self._executor.build_plan()

    def build_generator(self) -> "GraphicalJoin":
        self._executor.build_generator()
        return self

    def summarize(self) -> GFJS:
        return self._executor.summarize()

    # -- convenience -------------------------------------------------------
    def join_size(self) -> int:
        """|Q| without touching the data again (sum of the root marginal).

        Under a partitioned plan there is no monolithic generator to read
        (and building one would re-run the exact elimination partitioning
        exists to split), so the answer comes from the sharded pipeline —
        the sum of per-shard root marginals.
        """
        if self._executor.build_plan().partitions > 1:
            return self._executor.summarize().join_size
        if self.generator is None:
            self.build_generator()
        return self.generator.join_size

    def run(self) -> GFJS:
        """build_model -> plan -> build_generator -> summarize."""
        return self.summarize()

    # -- incremental maintenance ------------------------------------------
    def capture_state(self, gfjs: GFJS, versions=None):
        """Snapshot for later delta refreshes (requires record_trace=True)."""
        return self._executor.capture_state(gfjs, versions=versions)

    def refresh(self, state, deltas):
        """Apply table appends to a captured state (the ``refresh`` phase).

            gj = GraphicalJoin(cat, query, record_trace=True)
            gfjs = gj.run(); state = gj.capture_state(gfjs)
            delta = cat.append("user_friends", rows)
            state = gj.refresh(state, delta)     # state.gfjs is the new summary

        Only the appended block is encoded and only the dirty elimination
        steps re-run; ``timings["refresh"]`` holds the wall time.
        """
        return self._executor.refresh(state, deltas)

    def explain(self, *, analyze: bool = False) -> str:
        """Render the plan, annotated with any timings measured so far.

        ``analyze=True`` is the full post-mortem: per-step measured
        seconds (max and sum over shards), the per-shard breakdown, and
        straggler flags — everything the run actually observed.
        """
        return self._executor.explain(analyze=analyze)

    def aggregate(self, op: str, var: Optional[str] = None, *,
                  by: Optional[Sequence[str]] = None,
                  where: Optional[Dict] = None,
                  gfjs: Optional[GFJS] = None):
        """Answer an aggregate from the summary — O(runs), never O(|Q|).

            gj.aggregate("count")
            gj.aggregate("sum", "D", by=["A"], where={"B": "b1"})

        ``op``: count / sum / mean / min / max / distinct / count_distinct.
        Pass a previously computed ``gfjs`` to reuse it (the compute-and-
        reuse path); otherwise the pipeline runs (or re-runs) first.  The
        summary-side time lands in ``timings["aggregate"]``.
        """
        from repro.summary.algebra import SummaryFrame
        if gfjs is None:
            gfjs = self.run()
        t0 = time.perf_counter()
        frame = SummaryFrame.of(gfjs)
        if where:
            frame = frame.filter(where)
        if by:
            if op == "count":
                out = frame.group_by(list(by), count="count")
            else:
                if var is None:
                    raise ValueError(f"aggregate {op!r} needs a variable")
                out = frame.group_by(list(by), **{op: (op, var)})
        elif op == "count":
            out = frame.count()
        elif op in ("sum", "mean", "min", "max", "distinct", "count_distinct"):
            if var is None:
                raise ValueError(f"aggregate {op!r} needs a variable")
            out = getattr(frame, op)(var)
        else:
            raise ValueError(f"unknown aggregate op {op!r}")
        self.timings["aggregate"] = time.perf_counter() - t0
        return out

    def desummarize(self, gfjs: GFJS, *, decode: bool = True) -> Dict[str, np.ndarray]:
        return self._executor.desummarize(gfjs, decode=decode)

    def desummarize_range(self, gfjs: GFJS, lo: int, hi: int, *, decode: bool = True):
        return desummarize_range(gfjs, lo, hi, decode=decode)

    def stream(self, gfjs: GFJS, chunk_rows: int = 1 << 20, *, decode: bool = True):
        return stream_desummarize(gfjs, chunk_rows, decode=decode)

    def store(self, gfjs: GFJS, path: str) -> int:
        t0 = time.perf_counter()
        n = save_gfjs(gfjs, path)
        self.timings["store"] = time.perf_counter() - t0
        return n

    @staticmethod
    def load(path: str) -> GFJS:
        return load_gfjs(path)
