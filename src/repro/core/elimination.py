"""Algorithm 2 — building the GFJS *generator* via tweaked variable
elimination.

For every eliminated variable ``v`` the driver:

1. collects the factors containing ``v`` and multiplies them worst-case
   optimally (Algorithm 1 / ``multiway_product``) into ``phi_alpha``, keeping
   the bucket (original potentials) / fac (incoming messages) value split;
2. *conditionalizes* ``phi_alpha`` on v's parents — the separator, i.e. the
   remaining variables of ``phi_alpha`` — and stores the conditional factor
   ``psi(v | parents)`` (with its bucket and fac columns) into the generator,
   CSR-grouped by parent key for O(log) lookup at generation time;
3. sums ``v`` out to produce the message to the parents (frequencies of the
   sub-tree hanging below the separator).

Entries with zero frequency never exist (products only keep matching keys),
which is the paper's UIR-pruning argument: generation will never walk a path
that dies later, hence GJ is a WOJA.

Early projection (paper §3.7): variables not in the projection list are
eliminated first (O' before O) and step 2 is skipped for them ("the node is
deleted; the factor for its parent is still calculated").
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import QueryGraph, Triangulation, min_fill_order
from repro.core.potentials import INT, Factor, _rank_rows
from repro.core.potential_join import multiway_product
from repro.obs.trace import span as _span
from repro.relational.encoding import EncodedQuery


@dataclass
class Psi:
    """Conditional factor psi(child | parents), CSR-grouped by parent key."""

    child: str
    parents: Tuple[str, ...]
    parent_keys: np.ndarray    # [g, p] unique parent combos, lex-sorted
    start: np.ndarray          # [g] CSR start into child arrays
    count: np.ndarray          # [g]
    child_codes: np.ndarray    # [m]
    bucket: np.ndarray         # [m]
    fac: np.ndarray            # [m]
    parent_sizes: Tuple[int, ...]
    child_size: int

    @property
    def num_groups(self) -> int:
        return len(self.start)

    @property
    def num_entries(self) -> int:
        return len(self.child_codes)

    def nbytes(self) -> int:
        return int(self.parent_keys.nbytes + self.start.nbytes + self.count.nbytes
                   + self.child_codes.nbytes + self.bucket.nbytes + self.fac.nbytes)


@dataclass
class StepTrace:
    """Provenance + products of one elimination step (incremental refresh).

    ``rel_tables`` are indices into the per-occurrence table factors,
    ``rel_msgs`` the variables of earlier steps whose messages fed this
    product.  Both are *structural*: which factors contain a variable
    depends only on the query graph and the order, never on the data, so
    the same wiring can be replayed against updated factors.
    """

    var: str
    rel_tables: Tuple[int, ...]
    rel_msgs: Tuple[str, ...]
    parents: Tuple[str, ...]
    message: Factor
    psi: Optional[Psi]           # None for projected-out (O') variables


@dataclass
class EliminationTrace:
    """Everything a delta refresh needs to re-run only dirty steps."""

    steps: List[StepTrace]
    root_tables: Tuple[int, ...]       # table factors surviving to the root
    root_msgs: Tuple[str, ...]         # messages surviving to the root
    factors: List[Factor]              # per table occurrence, build order

    def nbytes(self) -> int:
        n = sum(f.keys.nbytes + f.bucket.nbytes + f.fac.nbytes
                for f in self.factors)
        for s in self.steps:
            n += int(s.message.keys.nbytes + s.message.bucket.nbytes
                     + s.message.fac.nbytes)
        return int(n)


@dataclass
class Generator:
    """The GFJS generator: root marginal + conditional factors by level.

    ``levels[d]`` holds the psis whose children sit at depth d+1 of the
    generator DAG (root = depth 0).  Children within one level are expanded
    jointly (Cartesian product semantics of the paper's Algorithm 4).
    """

    root: str
    root_codes: np.ndarray
    root_freq: np.ndarray
    levels: List[List[Psi]]
    elimination_order: List[str]
    column_order: List[str]      # root + level children, generation order
    join_size: int
    stats: Dict[str, float] = field(default_factory=dict)
    trace: Optional[EliminationTrace] = None   # set by record_trace builds
    # plan feedback: measured per-step elimination products and wall times
    # (var -> |multiway_product|, var -> seconds); the executor surfaces
    # these next to the planner's estimates in PhysicalPlan.explain()
    step_products: Dict[str, int] = field(default_factory=dict)
    step_seconds: Dict[str, float] = field(default_factory=dict)
    # variables whose psi/message were injected from the message cache
    # (their products were never computed; explain() renders cached=hit)
    cached_steps: Tuple[str, ...] = ()
    # hybrid plans: measured WCOJ bag products and wall times, keyed by
    # bag index in the plan's ``bags`` tuple (empty for pure-GJ builds)
    bag_products: Dict[int, int] = field(default_factory=dict)
    bag_seconds: Dict[int, float] = field(default_factory=dict)

    def nbytes(self) -> int:
        n = int(self.root_codes.nbytes + self.root_freq.nbytes)
        for lvl in self.levels:
            n += sum(p.nbytes() for p in lvl)
        return n


def _make_psi(phi: Factor, child: str, parents: Tuple[str, ...]) -> Psi:
    """Sort phi by (parents..., child) and CSR-group by parents."""
    f = phi.project(tuple(parents) + (child,))
    f = f.sort_by(list(parents) + [child])
    p = len(parents)
    pk = f.keys[:, :p]
    if f.num_entries == 0:
        return Psi(child, parents, pk[:0], np.zeros(0, INT), np.zeros(0, INT),
                   f.keys[:0, p], f.bucket[:0], f.fac[:0],
                   tuple(f.sizes[:p]), int(f.sizes[p]) if len(f.sizes) > p else 0)
    if p == 0:
        starts = np.zeros(1, INT)
        counts = np.array([f.num_entries], INT)
        upk = pk[:1]
    else:
        new = np.ones(f.num_entries, dtype=bool)
        new[1:] = np.any(pk[1:] != pk[:-1], axis=1)
        starts = np.flatnonzero(new).astype(INT)
        counts = np.diff(np.append(starts, f.num_entries)).astype(INT)
        upk = pk[starts]
    return Psi(child, parents, upk, starts, counts,
               f.keys[:, p].copy(), f.bucket.copy(), f.fac.copy(),
               tuple(f.sizes[:p]), int(f.sizes[p]))


def eliminate_step(
    rel: List[Factor], v: str, order: Sequence[str], out_vars: Sequence[str],
    observe: Optional[Dict[str, float]] = None,
) -> Tuple[Optional[Psi], Tuple[str, ...], Factor]:
    """One Algorithm-2 step: product, conditionalize, sum out.

    Returns ``(psi, parents, message)``; ``psi`` is None for projected-out
    variables.  Shared between the full build and the incremental refresher
    (which replays exactly this computation for dirty steps).

    ``observe`` (a dict, when given) receives ``product_entries`` — the
    measured size of the step's multiway product, the quantity the cost
    model estimates when scoring orders.
    """
    # Bind v FIRST in the frontier: every rel factor contains v, so each
    # later variable joins through it and prefix frontiers stay within
    # the pairwise-product bounds anchored at v.  Binding v last lets a
    # star of factors around v go cartesian over the satellite
    # variables before v prunes them (observed 100x+ slowdowns on
    # cyclic queries).  Output column order is (v, parents...) either
    # way downstream consumers re-sort.
    phi_alpha = multiway_product(
        rel, var_order=[v] + [u for u in order if u != v])
    if observe is not None:
        observe["product_entries"] = float(phi_alpha.num_entries)
    parents = tuple(u for u in phi_alpha.vars if u != v)
    psi = _make_psi(phi_alpha, v, parents) if v in out_vars else None
    msg = phi_alpha.marginalize_out(v)
    return psi, parents, msg


def root_marginal(factors: List[Factor], root: str) -> Factor:
    """Product of the factors surviving to the root (all over ``root``)."""
    for f in factors:
        if tuple(f.vars) != (root,):  # pragma: no cover - invariant
            raise AssertionError(f"leftover factor over {f.vars} at root")
    phi_root = factors[0]
    for f in factors[1:]:
        phi_root = phi_root.multiply(f)
    return phi_root.sort_by([root])


def assemble_generator(
    order: Sequence[str],
    psis: Dict[str, Psi],
    parents_of: Dict[str, Tuple[str, ...]],
    phi_root: Factor,
    stats: Dict[str, float],
    trace: Optional[EliminationTrace] = None,
    step_products: Optional[Dict[str, int]] = None,
    step_seconds: Optional[Dict[str, float]] = None,
) -> Generator:
    """Depth-level the psis under the root marginal into a Generator.

    Pure assembly (no data work): the refresher calls this with a mix of
    reused and recomputed psis to rebuild the generator after a delta.
    """
    root = order[-1]
    join_size = int(np.sum(phi_root.bucket * phi_root.fac))

    # depth levels of the generator DAG
    depth: Dict[str, int] = {root: 0}
    for v in reversed(list(order[:-1])):
        if v in psis:
            ps = parents_of[v]
            depth[v] = 1 + max((depth[p] for p in ps), default=0)
    max_depth = max(depth.values(), default=0)
    levels: List[List[Psi]] = [[] for _ in range(max_depth)]
    order_index = {v: i for i, v in enumerate(order)}
    for v in sorted(psis, key=lambda u: (depth[u], order_index[u])):
        levels[depth[v] - 1].append(psis[v])

    column_order = [root] + [p.child for lvl in levels for p in lvl]

    return Generator(
        root=root,
        root_codes=phi_root.keys[:, 0].copy(),
        root_freq=(phi_root.bucket * phi_root.fac).astype(INT),
        levels=levels,
        elimination_order=list(order),
        column_order=column_order,
        join_size=join_size,
        stats=stats,
        trace=trace,
        step_products=dict(step_products or {}),
        step_seconds=dict(step_seconds or {}),
    )


def build_generator(
    enc: EncodedQuery,
    *,
    elimination_order: Optional[Sequence[str]] = None,
    early_projection: bool = True,
    factors: Optional[List[Factor]] = None,
    record_trace: bool = False,
    step_estimates: Optional[Dict[str, float]] = None,
    bags: Optional[Sequence] = None,
    bag_estimates: Optional[Dict[int, float]] = None,
    message_cache=None,
    step_fingerprints: Optional[Dict[str, str]] = None,
    step_sources: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> Generator:
    """Run Algorithm 2 over the (possibly cyclic) query graph.

    ``factors``: pre-built quantitative-learning potentials (one per table
    occurrence, in ``enc.encoded_tables`` order).  The planner builds them
    for its statistics; passing them here avoids a second GROUP BY pass.

    ``record_trace`` keeps per-step provenance and messages on the returned
    generator (``Generator.trace``) so a later base-table append can re-run
    only the dirty steps (repro/summary/incremental.py).

    ``step_estimates`` (var -> planner product-entry estimate) annotates
    each step's trace span with est-vs-actual drift — the raw signal the
    CostModel feedback loop consumes.  Purely observational.

    ``bags`` (hypertree-decomposed hybrid plans): WCOJ multiway bag steps
    (``plan.ir.BagStep``) covering the cyclic core.  Each bag's table
    occurrences are generic-joined into one joint potential *before*
    elimination starts; the elimination loop then runs over bag potentials
    plus the unbagged table factors.  Because every bag scope is a clique
    of the chosen order's triangulation, the per-variable separators — and
    hence the GFJS — are bit-identical to the pure-GJ build.
    ``bag_estimates`` (bag index -> planner entry estimate) annotates the
    bag spans with est-vs-actual drift, like ``step_estimates``.

    ``message_cache`` (repro/summary/msgcache.py::MessageCache) with
    ``step_fingerprints`` (var -> subtree fingerprint, from
    ``plan.ir.step_fingerprints``) enables cross-query message reuse:
    before each step the cache is probed under single-flight — a hit
    injects the cached psi/message (positionally renamed to this build's
    separator) and skips the product + marginalization entirely; a miss
    computes, then puts (``step_sources`` names the base tables per step
    for explicit invalidation).  Reuse is refused for ``record_trace``
    builds (the trace owns its messages' provenance for incremental
    refresh) and for bagged plans (bag potentials merge occurrences
    outside the fingerprint's step wiring) — the cache is simply bypassed.
    Every probe emits a ``msg:<fingerprint>`` span annotated with the
    hit/miss outcome (validated by ``repro.obs.check``).
    """
    query = enc.query
    sizes = enc.domain_sizes()

    graph = QueryGraph.from_query(query)
    if not graph.is_connected():
        raise ValueError(
            f"query {query.name!r} has a disconnected join graph (cross product)")

    out_vars = list(query.output_variables)
    if not out_vars:
        raise ValueError("projection list must be non-empty")
    non_out = [v for v in graph.variables if v not in out_vars] if early_projection else []

    tri: Triangulation = min_fill_order(
        graph, first=non_out,
        forced_order=elimination_order,
    )
    order = tri.order

    # quantitative learning: one GROUP BY per table occurrence (unless the
    # planner already built the potentials for its statistics)
    if factors is None:
        factors = [Factor.from_columns(enc_cols, sizes)
                   for enc_cols in enc.encoded_tables]
    else:
        factors = list(factors)

    if order[-1] not in out_vars:  # root must be an output var (O' precedes O)
        raise AssertionError("root is a projected-out variable")

    psis: Dict[str, Psi] = {}
    parents_of: Dict[str, Tuple[str, ...]] = {}
    trace_steps: List[StepTrace] = []
    step_products: Dict[str, int] = {}
    step_seconds: Dict[str, float] = {}
    bag_products: Dict[int, int] = {}
    bag_seconds: Dict[int, float] = {}

    # the working set carries provenance tags: ("table", occurrence index)
    # for quantitative-learning factors, ("msg", var) for messages — which
    # is exactly the wiring an incremental refresh replays
    working: List[Tuple[str, object, Factor]] = [
        ("table", i, f) for i, f in enumerate(factors)]

    if bags:
        if record_trace:
            raise ValueError(
                "record_trace is unsupported for hypertree-decomposed (bagged) "
                "plans: bag potentials merge several table occurrences, which "
                "breaks the per-occurrence wiring incremental refresh replays; "
                "build with hybrid=False to record a trace")
        seen: set = set()
        for bag in bags:
            for i in bag.occurrences:
                if not 0 <= i < len(factors):
                    raise ValueError(
                        f"bag occurrence index {i} out of range "
                        f"(query has {len(factors)} table occurrences)")
                if i in seen:
                    raise ValueError(
                        f"table occurrence {i} appears in more than one bag")
                seen.add(i)
        working = [t for t in working if t[1] not in seen]
        for j, bag in enumerate(bags):
            label = ",".join(bag.vars)
            with _span(f"eliminate:bag[{label}]", cat="step", bag=j) as sp:
                t_bag = time.perf_counter()
                phi = multiway_product(
                    [factors[i] for i in bag.occurrences],
                    var_order=list(bag.bind_order))
                bag_seconds[j] = time.perf_counter() - t_bag
                bag_products[j] = int(phi.num_entries)
                sp.set(product=bag_products[j], seconds=bag_seconds[j])
                est = None
                if bag_estimates is not None and j in bag_estimates:
                    est = float(bag_estimates[j])
                elif getattr(bag, "est_entries", 0.0):
                    est = float(bag.est_entries)
                if est is not None:
                    sp.set(est=est,
                           drift=(bag_products[j] / est if est > 0.0
                                  else float("inf")))
            working.append(("bag", j, phi))

    # cross-query message reuse: refused under record_trace (the trace owns
    # its messages' provenance) and for bagged plans (bag potentials merge
    # occurrences outside the fingerprint's step wiring)
    use_cache = (message_cache is not None and step_fingerprints
                 and not record_trace and not bags)
    cached_steps: List[str] = []

    for v in order[:-1]:
        rel = [t for t in working if v in t[2].vars]
        rest = [t for t in working if v not in t[2].vars]
        if not rel:  # pragma: no cover - connected graph invariant
            raise AssertionError(f"no factor contains variable {v}")
        fp = step_fingerprints.get(v) if use_cache else None
        flight = None
        if fp is not None:
            with _span(f"msg:{fp[:16]}", cat="msgcache", var=v) as msp:
                t_step = time.perf_counter()
                entry, flight = message_cache.lookup_or_begin(fp)
                msp.set(hit=entry is not None)
                if entry is not None:
                    scope: set = set()
                    for _, _, f in rel:
                        scope.update(f.vars)
                    parents = tuple(
                        u for u in order if u != v and u in scope)
                    psi, msg = message_cache.adopt(entry, v, parents)
                    step_seconds[v] = time.perf_counter() - t_step
            if entry is not None:
                parents_of[v] = parents
                if psi is not None:
                    psis[v] = psi
                cached_steps.append(v)
                working = rest + [("msg", v, msg)]
                continue
        try:
            with _span(f"eliminate:{v}", cat="step", var=v) as sp:
                t_step = time.perf_counter()
                obs: Dict[str, float] = {}
                psi, parents, msg = eliminate_step(
                    [f for _, _, f in rel], v, order, out_vars, observe=obs)
                step_seconds[v] = time.perf_counter() - t_step
                step_products[v] = int(obs.get("product_entries", 0))
                sp.set(product=step_products[v], seconds=step_seconds[v])
                if step_estimates is not None and v in step_estimates:
                    est = float(step_estimates[v])
                    sp.set(est=est,
                           drift=(step_products[v] / est if est > 0.0
                                  else float("inf")))
        except BaseException:
            if fp is not None:
                message_cache.abandon(fp, flight)
            raise
        if fp is not None:
            message_cache.publish(
                fp, flight, psi, msg,
                tables=(step_sources or {}).get(v, ()))
        parents_of[v] = parents
        if psi is not None:
            psis[v] = psi
        if record_trace:
            trace_steps.append(StepTrace(
                var=v,
                rel_tables=tuple(r for k, r, _ in rel if k == "table"),
                rel_msgs=tuple(r for k, r, _ in rel if k == "msg"),
                parents=parents,
                message=msg,
                psi=psi,
            ))
        working = rest + [("msg", v, msg)]

    # root: product of the remaining factors (all over the root only)
    phi_root = root_marginal([f for _, _, f in working], order[-1])

    trace = None
    if record_trace:
        trace = EliminationTrace(
            steps=trace_steps,
            root_tables=tuple(r for k, r, _ in working if k == "table"),
            root_msgs=tuple(r for k, r, _ in working if k == "msg"),
            factors=list(factors),
        )

    gen = assemble_generator(
        order, psis, parents_of, phi_root,
        stats={
            "num_fill_edges": float(len(tri.fill_edges)),
            "num_maxcliques": float(len(tri.maxcliques)),
            "largest_maxclique": float(max((len(c) for c in tri.maxcliques), default=0)),
            "num_bags": float(len(bags) if bags else 0),
        },
        trace=trace,
        step_products=step_products,
        step_seconds=step_seconds,
    )
    gen.bag_products = bag_products
    gen.bag_seconds = bag_seconds
    gen.cached_steps = tuple(cached_steps)
    return gen
