"""Algorithms 3/4 — GFJS generation — plus the GFJS structure itself.

The paper generates the summary tuple-recursively (rec_GFJS).  We run the
level-synchronous equivalent: a *frontier* table holds every generated
prefix (one row per distinct value combination of the variables produced so
far) together with its running bucket product ``p_bucket``.  Expanding one
conditional factor ``psi`` maps each frontier row to its CSR group and emits
``count`` child rows — an exclusive-scan + expand-gather, the same primitive
as RLE desummarization (and the Pallas kernel `expand_gather` on TPU).

Per Algorithm 4 the RLE frequency emitted at a level is
``p_bucket * (prod buckets of the level) * (prod facs of the level)`` and the
frontier continues with ``p_bucket * (prod buckets)``; several psis in one
level combine by Cartesian product (their buckets and facs both multiply).

Because psi entries are sorted by (parent key, child value) and expansion is
order-preserving, every level is emitted in lexicographic prefix order —
which is exactly what makes the per-level RLE columns mutually aligned and
equal to the RLE of the fully sorted join result (Definition 1).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.elimination import Generator, Psi
from repro.core.potentials import INT, _rank_rows_joint
from repro.obs.metrics import REGISTRY
from repro.obs.trace import span as _span
from repro.relational.encoding import Domain


@dataclass
class LevelSummary:
    """One GFJS level: RLE runs for the variables introduced at this level."""

    vars: Tuple[str, ...]
    key_cols: Dict[str, np.ndarray]   # var -> codes per run
    freq: np.ndarray                  # run lengths; sums to join_size

    @property
    def num_runs(self) -> int:
        return len(self.freq)

    def nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.key_cols.values()) + self.freq.nbytes)


@dataclass
class GFJS:
    """Grouped Frequentist Join Summary (Definition 1)."""

    levels: List[LevelSummary]
    column_order: List[str]
    join_size: int
    domains: Dict[str, Domain]
    _bounds: Dict[int, np.ndarray] = field(default_factory=dict, repr=False)
    # kernel launch metadata memoized alongside the prefix sums: level ->
    # (t_pad, (padded bounds, per-tile start blocks)) — one entry per level
    # (a new t_pad replaces it), populated lazily by
    # repro.kernels.ops.gfjs_expand_meta (this module stays jax-free)
    _launch: Dict[int, tuple] = field(default_factory=dict, repr=False)

    @property
    def num_columns(self) -> int:
        return len(self.column_order)

    def nbytes(self) -> int:
        return int(sum(l.nbytes() for l in self.levels))

    def num_runs(self) -> int:
        return int(sum(l.num_runs for l in self.levels))

    def bounds(self, level: int) -> np.ndarray:
        """Cached inclusive prefix sums of a level's run lengths.

        Lockless: concurrent callers may both compute and one insert wins
        (the arrays are identical).  Return the local value, never re-read
        the dict — a concurrent eviction between insert and read would
        KeyError otherwise.
        """
        b = self._bounds.get(level)
        if b is None:
            b = np.cumsum(self.levels[level].freq)
            self._bounds[level] = b
        return b

    def aux_nbytes(self) -> int:
        """Bytes held by the lazily-built expansion caches.

        ``_bounds`` prefix sums plus ``_launch`` kernel metadata — bounded
        (one entry per level each) but invisible to :meth:`nbytes`, which
        stays the *serialized* summary size (the paper's Table-4 metric).
        """
        # other threads holding this GFJS insert into these dicts lockless
        # (via bounds()/gfjs_expand_meta), so snapshot the KEYS first and
        # re-fetch each entry with .get(): a key list is detached from the
        # dict the instant it is built, whereas iterating values()/items()
        # views — even wrapped in list() — keys off dict internals that a
        # concurrent insert may resize.  An entry replaced mid-walk yields
        # its new value; one racing in/out is simply skipped — either way
        # the measurement stays a valid point-in-time bound, never a
        # "dict changed size during iteration"
        n = 0
        for lvl in list(self._bounds):
            b = self._bounds.get(lvl)
            if b is not None:
                n += int(b.nbytes)
        for lvl in list(self._launch):
            entry = self._launch.get(lvl)
            if entry is None:
                continue
            _, meta = entry
            n += sum(int(getattr(a, "nbytes", 0)) for a in meta)
        return int(n)

    def resident_nbytes(self) -> int:
        """In-memory footprint: summary arrays + expansion caches (what a
        byte-budgeted cache should charge for a resident entry)."""
        return self.nbytes() + self.aux_nbytes()


@dataclass
class ShardedGFJS:
    """A hash-partitioned GFJS: one independent summary per shard.

    The join result is partitioned by ``hash(code(partition_var)) %
    num_partitions`` (repro/dist/partition.py): every base potential
    containing the partition variable is restricted to the shard's hash
    slice and every other potential is replicated, so each shard's GFJS
    summarizes exactly the join rows whose partition-variable value hashes
    to it.  The shards are disjoint and their union is the full result —
    row counts and distributive aggregates are sums over shards, and
    nothing here ever materializes a concatenated summary.

    All shards run under the same physical plan, so ``column_order`` and
    the per-level variable structure are identical across shards (factor
    schemas — not data — determine both); the merge logic in
    repro/summary/algebra.py relies on that.
    """

    shards: List[GFJS]
    column_order: List[str]
    join_size: int
    domains: Dict[str, Domain]
    partition_var: str
    salt: int = 0

    @property
    def num_partitions(self) -> int:
        return len(self.shards)

    @property
    def num_columns(self) -> int:
        return len(self.column_order)

    def shard_sizes(self) -> List[int]:
        return [s.join_size for s in self.shards]

    def nbytes(self) -> int:
        return int(sum(s.nbytes() for s in self.shards))

    def num_runs(self) -> int:
        return int(sum(s.num_runs() for s in self.shards))

    def aux_nbytes(self) -> int:
        return int(sum(s.aux_nbytes() for s in self.shards))

    def resident_nbytes(self) -> int:
        return self.nbytes() + self.aux_nbytes()


def _lookup_groups(
    frontier_keys: np.ndarray, psi: Psi
) -> np.ndarray:
    """Group index in psi for each frontier row (-1 if absent)."""
    if len(psi.parents) == 0:
        return np.zeros(len(frontier_keys), INT)
    if len(psi.parent_keys) == 0:
        # empty psi: no parent group exists, so every frontier row misses;
        # never index pr[pos] on the zero-length array
        return np.full(len(frontier_keys), -1, INT)
    (fr, pr), _ = _rank_rows_joint(frontier_keys, psi.parent_keys,
                                   list(psi.parent_sizes))
    # psi.parent_keys rows are lex-sorted, and both rankings are
    # lex-order-consistent, so pr is sorted ascending.
    pos = np.searchsorted(pr, fr)
    pos = np.clip(pos, 0, len(pr) - 1)
    ok = pr[pos] == fr
    return np.where(ok, pos, -1).astype(INT)


def _expand(
    counts: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """src row index + within-group offset for an expansion by ``counts``.

    O(total) via repeat (the TPU path uses the `expand_gather` Pallas kernel,
    which re-derives src with a blocked binary search instead — see
    repro/kernels/expand_gather.py for why that's the right trade on TPU).
    """
    counts = np.asarray(counts, dtype=INT)
    offsets = np.cumsum(counts) - counts          # exclusive scan
    total = int(offsets[-1] + counts[-1]) if len(counts) else 0
    src = np.repeat(np.arange(len(counts), dtype=INT), counts)
    within = np.arange(total, dtype=INT) - offsets[src]
    return src, within


def expand_level(
    cols: Dict[str, np.ndarray], p_bucket: np.ndarray, level: Sequence[Psi]
) -> Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray, Tuple[str, ...],
           List[Tuple[np.ndarray, np.ndarray]]]:
    """Expand one generator level over the current frontier.

    Returns ``(cols, p_bucket, freq, new_vars, cache)`` where ``cache``
    holds one ``(src, cidx)`` index pair per psi: ``src`` maps each output
    frontier row to its source row in the previous frontier state, ``cidx``
    to the psi entry it consumed.  When a base-table append changes psi
    *values* but not psi *structure*, replaying these gathers re-propagates
    the run weights without redoing any group lookup or expansion — the
    splice fast path of repro/summary/incremental.py.
    """
    fac_acc = np.ones(len(p_bucket), INT)
    new_vars: List[str] = []
    cache: List[Tuple[np.ndarray, np.ndarray]] = []
    for psi in level:
        pk = (np.stack([cols[p] for p in psi.parents], axis=1)
              if psi.parents else np.zeros((len(p_bucket), 0), INT))
        g = _lookup_groups(pk, psi)
        counts = np.zeros(len(g), INT)
        hit = g >= 0
        counts[hit] = psi.count[g[hit]]
        src, within = _expand(counts)
        cidx = psi.start[g[src]] + within
        cols = {v: a[src] for v, a in cols.items()}
        cols[psi.child] = psi.child_codes[cidx]
        p_bucket = p_bucket[src] * psi.bucket[cidx]
        fac_acc = fac_acc[src] * psi.fac[cidx]
        new_vars.append(psi.child)
        cache.append((src, cidx))
    return cols, p_bucket, p_bucket * fac_acc, tuple(new_vars), cache


def generate_gfjs(
    gen: Generator, domains: Dict[str, Domain],
    expansion_cache: Optional[List[List[Tuple[np.ndarray, np.ndarray]]]] = None,
) -> GFJS:
    """Run Algorithms 3/4 (level-synchronous) over the generator.

    ``expansion_cache`` (when a list is passed) collects the per-level
    ``(src, cidx)`` gather indices from :func:`expand_level` — the raw
    material of incremental weight re-propagation.
    """
    levels_out: List[LevelSummary] = [
        LevelSummary((gen.root,), {gen.root: gen.root_codes}, gen.root_freq)
    ]
    # frontier state
    cols: Dict[str, np.ndarray] = {gen.root: gen.root_codes}
    p_bucket = np.ones(len(gen.root_codes), INT)

    runs_hist = REGISTRY.histogram("gfjs.runs_per_level", unit="runs")
    runs_hist.observe(len(gen.root_codes))
    for depth, level in enumerate(gen.levels):
        with _span(f"gfjs:level:{depth}", cat="gen", backend="numpy",
                   depth=depth) as sp:
            cols, p_bucket, freq, new_vars, cache = expand_level(
                cols, p_bucket, level)
            sp.set(runs=len(freq), vars=",".join(new_vars))
        runs_hist.observe(len(freq))
        levels_out.append(LevelSummary(
            new_vars, {v: cols[v] for v in new_vars}, freq))
        if expansion_cache is not None:
            expansion_cache.append(cache)

    return GFJS(levels_out, list(gen.column_order), gen.join_size, domains)


# ---------------------------------------------------------------------------
# Desummarization (paper §3.6) — full, ranged, and streaming variants.
# ---------------------------------------------------------------------------

def rle_expand(values: np.ndarray, freq: np.ndarray) -> np.ndarray:
    """Expand RLE runs to a flat column (cost == join size, paper §3.5.1)."""
    return np.repeat(values, freq)


def desummarize(gfjs: "GFJS | ShardedGFJS", *, decode: bool = True
                ) -> Dict[str, np.ndarray]:
    """Materialize the full flat join result from the summary.

    A :class:`ShardedGFJS` expands shard by shard and concatenates in
    shard order — the row *multiset* equals the monolithic expansion, but
    rows arrive grouped by partition hash rather than globally sorted.
    """
    if isinstance(gfjs, ShardedGFJS):
        parts = [desummarize(s, decode=decode) for s in gfjs.shards]
        return {v: np.concatenate([p[v] for p in parts])
                for v in gfjs.column_order}
    out: Dict[str, np.ndarray] = {}
    for lvl in gfjs.levels:
        for v in lvl.vars:
            col = rle_expand(lvl.key_cols[v], lvl.freq)
            out[v] = gfjs.domains[v].decode(col) if decode else col
    return {v: out[v] for v in gfjs.column_order}


def desummarize_range(
    gfjs: "GFJS | ShardedGFJS", lo: int, hi: int, *, decode: bool = True
) -> Dict[str, np.ndarray]:
    """Materialize join-result rows [lo, hi) only — O((hi-lo) + log runs).

    Beyond-paper extension (DESIGN.md §7): GFJS run boundaries are prefix
    sums, so any row range is addressable without touching the rest of the
    result.  This is what makes GFJS range-shardable across a TPU mesh: each
    data host expands only its own slice.

    For a :class:`ShardedGFJS` the row space is the shard-concatenated
    order (shard 0's rows, then shard 1's, ...) — the same order
    :func:`desummarize` and :func:`stream_desummarize` emit — and a range
    resolves through the cumulative shard sizes to per-shard sub-ranges.
    """
    if isinstance(gfjs, ShardedGFJS):
        lo = max(0, int(lo))
        hi = min(int(hi), gfjs.join_size)
        parts: List[Dict[str, np.ndarray]] = []
        base = 0
        for shard in gfjs.shards:
            s_lo = max(lo - base, 0)
            s_hi = min(hi - base, shard.join_size)
            if s_lo < s_hi or not parts:   # keep >=1 part for dtypes
                parts.append(desummarize_range(
                    shard, s_lo, max(s_hi, s_lo), decode=decode))
            base += shard.join_size
        return {v: np.concatenate([p[v] for p in parts])
                for v in gfjs.column_order}
    lo = max(0, int(lo))
    hi = min(int(hi), gfjs.join_size)
    out: Dict[str, np.ndarray] = {}
    for li, lvl in enumerate(gfjs.levels):
        bounds = gfjs.bounds(li)
        first = int(np.searchsorted(bounds, lo, side="right"))
        last = int(np.searchsorted(bounds, hi - 1, side="right")) if hi > lo else first
        sl = slice(first, last + 1) if hi > lo else slice(first, first)
        freq = lvl.freq[sl].copy()
        if hi > lo and len(freq):
            start_of_first = int(bounds[first] - lvl.freq[first])
            freq[0] -= lo - start_of_first
            freq[-1] -= int(bounds[last]) - hi
        for v in lvl.vars:
            col = np.repeat(lvl.key_cols[v][sl], freq)
            out[v] = gfjs.domains[v].decode(col) if decode else col
    return {v: out[v] for v in gfjs.column_order}


def stream_desummarize(
    gfjs: "GFJS | ShardedGFJS", chunk_rows: int = 1 << 20, *,
    decode: bool = True
) -> Iterator[Dict[str, np.ndarray]]:
    """Yield the join result in row chunks without full materialization.

    Sharded summaries stream shard by shard (chunk boundaries reset at
    shard edges; each chunk is still at most ``chunk_rows`` rows).
    """
    if isinstance(gfjs, ShardedGFJS):
        for shard in gfjs.shards:
            yield from stream_desummarize(shard, chunk_rows, decode=decode)
        return
    for lo in range(0, gfjs.join_size, chunk_rows):
        yield desummarize_range(gfjs, lo, min(lo + chunk_rows, gfjs.join_size),
                                decode=decode)


def row_at(gfjs: "GFJS | ShardedGFJS", t: int, *,
           decode: bool = True) -> Dict[str, object]:
    """O(levels * log runs) random access to join-result row ``t``.

    Sharded row space is the shard-concatenated order of
    :func:`desummarize`; the shard lookup adds O(num_partitions).
    """
    if not (0 <= t < gfjs.join_size):
        raise IndexError(t)
    if isinstance(gfjs, ShardedGFJS):
        for shard in gfjs.shards:
            if t < shard.join_size:
                return row_at(shard, t, decode=decode)
            t -= shard.join_size
        raise IndexError(t)  # pragma: no cover - join_size == sum invariant
    out: Dict[str, object] = {}
    for li, lvl in enumerate(gfjs.levels):
        bounds = gfjs.bounds(li)
        r = int(np.searchsorted(bounds, t, side="right"))
        for v in lvl.vars:
            code = lvl.key_cols[v][r]
            out[v] = gfjs.domains[v].decode(np.asarray([code]))[0] if decode else int(code)
    return {v: out[v] for v in gfjs.column_order}
