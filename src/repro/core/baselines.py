"""Competitor join algorithms, for the paper's experimental comparison.

* :func:`binary_join_plan` — a left-deep binary-join plan over sorted-merge
  products, materializing every intermediate result.  This is the execution
  model of PostgreSQL/MonetDB in the paper's tables; it pays the full UIR
  cost, which the benchmarks surface as ``peak_intermediate`` rows.
* :func:`leapfrog_join` — a generic worst-case-optimal join over the *data*
  (the execution model of Umbra's WOJA): breadth-first variable-at-a-time
  binding over distinct keys with semijoin filtering, then one multiplicity
  expansion to the flat result.  Avoids UIR but still materializes the full
  redundant join result (the cost GJ's summary avoids).

Both operate on the same encoded inputs as GJ so comparisons isolate the
algorithm, not parsing or storage engines (DESIGN.md §8).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.potentials import INT, Factor
from repro.core.potential_join import multiway_product
from repro.core.gfjs import _expand
from repro.relational.encoding import EncodedQuery


@dataclass
class JoinRunResult:
    columns: Dict[str, np.ndarray]      # flat join result (encoded codes)
    rows: int
    peak_intermediate: int              # max intermediate rows materialized
    seconds: float


def _row_factor(cols: Dict[str, np.ndarray], sizes: Dict[str, int]) -> Factor:
    names = tuple(cols.keys())
    keys = np.stack([np.asarray(cols[v], dtype=INT) for v in names], axis=1)
    n = keys.shape[0]
    return Factor(names, keys, np.ones(n, INT), np.ones(n, INT),
                  tuple(int(sizes[v]) for v in names))


def binary_join_plan(
    enc: EncodedQuery, order: Optional[Sequence[int]] = None
) -> JoinRunResult:
    """Left-deep binary plan; ``order`` permutes the table sequence."""
    t0 = time.perf_counter()
    sizes = enc.domain_sizes()
    tables = [_row_factor(c, sizes) for c in enc.encoded_tables]
    if order is not None:
        tables = [tables[i] for i in order]
    acc = tables[0]
    peak = acc.num_entries
    rest = tables[1:]
    while rest:
        nxt = next((f for f in rest if set(f.vars) & set(acc.vars)), rest[0])
        rest.remove(nxt)
        acc = acc.multiply(nxt)
        peak = max(peak, acc.num_entries)
    out_vars = enc.query.output_variables
    acc = acc.project(tuple(out_vars))
    cols = {v: acc.col(v).copy() for v in out_vars}
    return JoinRunResult(cols, acc.num_entries, peak, time.perf_counter() - t0)


def leapfrog_join(
    enc: EncodedQuery, var_order: Optional[Sequence[str]] = None
) -> JoinRunResult:
    """Generic WCOJ over data: distinct-key frontier + final expansion.

    The frontier over bound variables is AGM-bounded per prefix (no UIR);
    multiplicities are applied once at the end, costing exactly |Q|.
    """
    t0 = time.perf_counter()
    sizes = enc.domain_sizes()
    # grouped potentials (the 'tries'): unique keys + multiplicities
    pots = [Factor.from_columns(c, sizes) for c in enc.encoded_tables]
    order = list(var_order) if var_order else list(enc.query.variables)
    joint = multiway_product(pots, var_order=order)
    peak = joint.num_entries
    # expand multiplicities to the flat result
    mult = joint.bucket * joint.fac
    src, _ = _expand(mult)
    out_vars = enc.query.output_variables
    proj = joint.project(tuple(out_vars))
    cols = {v: proj.keys[src, i].copy() for i, v in enumerate(proj.vars)}
    rows = int(mult.sum())
    return JoinRunResult(cols, rows, peak, time.perf_counter() - t0)


def store_result_csv(columns: Dict[str, np.ndarray], domains, path: str) -> int:
    """Write a flat join result as CSV (what the competitors store on disk)."""
    import os
    names = list(columns.keys())
    cols = [domains[v].decode(columns[v]) if domains else columns[v] for v in names]
    with open(path, "w") as f:
        f.write(",".join(names) + "\n")
        n = len(cols[0]) if cols else 0
        CHUNK = 1 << 16
        for lo in range(0, n, CHUNK):
            hi = min(lo + CHUNK, n)
            block = np.stack([np.asarray(c[lo:hi]).astype(str) for c in cols], axis=1)
            f.write("\n".join(",".join(r) for r in block) + "\n")
    return os.path.getsize(path)


def store_result_binary(columns: Dict[str, np.ndarray], path: str) -> int:
    """Columnar binary storage of a flat result (MonetDB-style), compressed.

    Frames are self-describing: each column is one length-prefixed compressed
    block so the loader needs no external schema (see benchmarks/tables.py).
    """
    import os
    import struct

    from repro.core.storage import compress_bytes
    with open(path, "wb") as f:
        for v, c in columns.items():
            codec, comp = compress_bytes(np.ascontiguousarray(c).tobytes())
            f.write(struct.pack("<4sQ", codec.encode().ljust(4), len(comp)))
            f.write(comp)
    return os.path.getsize(path)
