"""Algorithm 1 — worst-case-optimal join *of potentials* (not data).

The paper's Algorithm 1 recursively binds one variable at a time: for each
value shared by every potential containing the variable, it restricts those
potentials and recurses; at the leaves it multiplies entry frequencies
(Bucket_Product).  Depth-first per-value recursion is hostile to TPUs, so —
exactly like our Algorithm 3/4 treatment — we run the *level-synchronous*
(breadth-first) form: the frontier after binding variables v_1..v_i is the
set of all viable prefixes, computed with sorted-merge joins and semijoin
filters.  Each prefix frontier is bounded by the AGM bound of its prefix
query, so the total work stays O(M^rho) — the same worst-case-optimality
argument as the paper's.

The same routine drives (a) joint-potential construction for junction-tree
maxcliques and (b) the product step of Algorithm 2 when several factors
contain the variable being eliminated, and (c) the leapfrog baseline
(over row-level indicator factors).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.potentials import INT, Factor, _rank_rows


def distinct_projection(f: Factor, vars: Sequence[str]) -> Factor:
    """Distinct rows of f's projection onto ``vars`` (indicator factor)."""
    idx = [f.var_index(v) for v in vars]
    sub = f.keys[:, idx]
    sizes = tuple(f.sizes[i] for i in idx)
    if len(sub) == 0:
        return Factor(tuple(vars), sub, np.zeros(0, INT), np.zeros(0, INT), sizes)
    ranks, _ = _rank_rows(sub, sizes)
    order = np.argsort(ranks, kind="stable")
    sranks = ranks[order]
    new = np.ones(len(sranks), dtype=bool)
    new[1:] = sranks[1:] != sranks[:-1]
    starts = np.flatnonzero(new)
    u = sub[order][starts]
    ones = np.ones(len(u), INT)
    return Factor(tuple(vars), u, ones, ones, sizes)


def _match_indices(joint: Factor, f: Factor) -> np.ndarray:
    """For each joint row, the index of the matching row in f (grouped keys).

    f must have unique key rows over vars(f) (true for potentials).
    Rows with no match return -1.
    """
    fv = list(f.vars)
    ji = [joint.var_index(v) for v in fv]
    a = joint.keys[:, ji]
    b = f.keys
    sizes = [f.sizes[i] for i in range(len(fv))]
    from repro.core.potentials import _rank_rows_joint

    (ra, rb), _ = _rank_rows_joint(a, b, sizes)
    order = np.argsort(rb, kind="stable")
    rb_sorted = rb[order]
    pos = np.searchsorted(rb_sorted, ra)
    pos = np.clip(pos, 0, max(len(rb_sorted) - 1, 0))
    ok = (len(rb_sorted) > 0) & (rb_sorted[pos] == ra) if len(rb_sorted) else np.zeros(len(ra), bool)
    out = np.where(ok, order[pos], -1)
    return out.astype(INT)


def multiway_product(
    factors: List[Factor],
    var_order: Optional[Sequence[str]] = None,
) -> Factor:
    """Join a set of potentials into one joint potential, worst-case optimally.

    Buckets multiply with buckets and facs with facs (provenance split
    preserved) — the Bucket_Product of the paper's Algorithm 1 line 11.
    """
    if len(factors) == 1:
        return factors[0]
    all_vars: List[str] = []
    for f in factors:
        for v in f.vars:
            if v not in all_vars:
                all_vars.append(v)
    order = [v for v in (var_order or all_vars) if v in all_vars]
    for v in all_vars:
        if v not in order:
            order.append(v)

    # beyond-paper optimization (EXPERIMENTS.md #Perf GJ-1): single-variable
    # semijoin pre-reduction.  Every factor is filtered to the intersection
    # of each shared variable's value set across all factors before any
    # expansion -- a Yannakakis-style pass that removes most UIR up front
    # and shrinks both the pairwise products and the WCOJ frontier.
    if len(factors) >= 2:
        var_sets: dict = {}
        for f in factors:
            for v in f.vars:
                var_sets.setdefault(v, []).append(f)
        inter: dict = {}
        for v, fs in var_sets.items():
            if len(fs) < 2:
                continue
            cur = None
            for f in fs:
                vals = np.unique(f.col(v))
                cur = vals if cur is None else cur[
                    np.searchsorted(vals, cur) < len(vals)]
                if cur is not None and len(cur) and len(vals):
                    pos = np.clip(np.searchsorted(vals, cur), 0, len(vals) - 1)
                    cur = cur[vals[pos] == cur]
            inter[v] = cur
        reduced = []
        for f in factors:
            mask = np.ones(f.num_entries, bool)
            for v in f.vars:
                if v in inter:
                    vals = inter[v]
                    col = f.col(v)
                    if len(vals) == 0:
                        mask &= False
                        continue
                    pos = np.clip(np.searchsorted(vals, col), 0, len(vals) - 1)
                    mask &= vals[pos] == col
            if mask.all():
                reduced.append(f)
            else:
                reduced.append(Factor(f.vars, f.keys[mask], f.bucket[mask],
                                      f.fac[mask], f.sizes))
        factors = reduced

    # fast path: two factors -> plain sorted-merge product
    if len(factors) == 2:
        return factors[0].multiply(factors[1])

    # frontier WCOJ over distinct keys
    sizes_of = {}
    for f in factors:
        for v, s in zip(f.vars, f.sizes):
            sizes_of[v] = s
    frontier = Factor((), np.zeros((1, 0), INT), np.ones(1, INT), np.ones(1, INT), ())
    bound: List[str] = []
    for v in order:
        rel = [f for f in factors if v in f.vars]
        # expand through the SMALLEST projection and semijoin with the
        # rest: the frontier set is the same whichever relation expands
        # (intersection semantics), but expansion cost is the frontier x
        # per-key degree of the expander, so the fewest-distinct-rows
        # projection is the cheapest intersection anchor — this is the
        # "per-level intersection on the smallest potential" of generic
        # join, and it is what keeps skewed bag steps near the AGM bound
        # instead of near the hottest relation's degree.
        projs = [distinct_projection(
            f, [u for u in bound if u in f.vars] + [v]) for f in rel]
        if projs:
            k = min(range(len(projs)), key=lambda i: projs[i].num_entries)
            frontier = frontier.multiply(projs[k])
            for i, proj in enumerate(projs):
                if i != k:
                    frontier = frontier.semijoin(proj)
        bound.append(v)

    # Bucket_Product: fold every factor's values into the joint keys
    joint = frontier.project(tuple(order))
    bucket = np.ones(joint.num_entries, INT)
    fac = np.ones(joint.num_entries, INT)
    for f in factors:
        idx = _match_indices(joint, f)
        # every surviving prefix extends to full matches in every factor
        if joint.num_entries and (idx < 0).any():  # pragma: no cover - invariant
            raise AssertionError("WCOJ frontier produced a non-matching row")
        if joint.num_entries:
            bucket *= f.bucket[idx]
            fac *= f.fac[idx]
    return Factor(joint.vars, joint.keys, bucket, fac, joint.sizes)
