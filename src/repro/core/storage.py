"""GFJS disk format — the compute-and-reuse scenario's store/load path.

Single-file container: an 8-byte magic+version, a JSON manifest (level
structure, dtypes, domains metadata), then zstd-compressed binary blobs.
Each level's freq column and each variable's code column are separate blobs
so a loader can stream one column at a time; domains (the raw dictionary
values) are stored so the file is self-contained.

The paper stores GFJS as one CSV per column; we keep the per-column layout
but use dictionary codes + zstd, which is the columnar-RDBMS-internal
encoding the paper says would make GJ "even faster".  A `to_csv` escape
hatch writes the paper's exact format for the storage benchmark.
"""

from __future__ import annotations

import io
import json
import os
import struct
from typing import BinaryIO, Dict, List, Tuple

import numpy as np
import zstandard

from repro.core.gfjs import GFJS, LevelSummary
from repro.relational.encoding import Domain

MAGIC = b"GFJS"
VERSION = 1


def _write_blob(f: BinaryIO, arr: np.ndarray, cctx: zstandard.ZstdCompressor) -> Tuple[int, int]:
    raw = arr.tobytes()
    comp = cctx.compress(raw)
    off = f.tell()
    f.write(comp)
    return off, len(comp)


def save_gfjs(gfjs: GFJS, path: str, *, level: int = 3) -> int:
    """Write the summary; returns bytes on disk (Table 4's metric)."""
    cctx = zstandard.ZstdCompressor(level=level)
    blobs: List[Dict] = []
    body = io.BytesIO()

    def add(name: str, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr)
        off, n = _write_blob(body, arr, cctx)
        blobs.append({"name": name, "offset": off, "nbytes": n,
                      "dtype": str(arr.dtype), "shape": list(arr.shape)})

    for i, lvl in enumerate(gfjs.levels):
        add(f"level{i}/freq", lvl.freq)
        for v in lvl.vars:
            add(f"level{i}/key/{v}", lvl.key_cols[v])
    for v, dom in gfjs.domains.items():
        add(f"domain/{v}", dom.values)

    manifest = {
        "version": VERSION,
        "join_size": gfjs.join_size,
        "column_order": gfjs.column_order,
        "levels": [{"vars": list(l.vars)} for l in gfjs.levels],
        "domains": list(gfjs.domains.keys()),
        "blobs": blobs,
    }
    mjson = json.dumps(manifest).encode()

    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", VERSION))
        f.write(struct.pack("<Q", len(mjson)))
        f.write(mjson)
        f.write(body.getvalue())
    return os.path.getsize(path)


def load_gfjs(path: str) -> GFJS:
    dctx = zstandard.ZstdDecompressor()
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path} is not a GFJS file")
        (version,) = struct.unpack("<I", f.read(4))
        if version != VERSION:
            raise ValueError(f"unsupported GFJS version {version}")
        (mlen,) = struct.unpack("<Q", f.read(8))
        manifest = json.loads(f.read(mlen))
        base = f.tell()
        data = f.read()

    def get(name: str) -> np.ndarray:
        for b in manifest["blobs"]:
            if b["name"] == name:
                raw = dctx.decompress(
                    data[b["offset"]: b["offset"] + b["nbytes"]],
                    max_output_size=1 << 34)
                return np.frombuffer(raw, dtype=np.dtype(b["dtype"])).reshape(b["shape"]).copy()
        raise KeyError(name)

    domains = {v: Domain(v, get(f"domain/{v}")) for v in manifest["domains"]}
    levels: List[LevelSummary] = []
    for i, meta in enumerate(manifest["levels"]):
        vars_ = tuple(meta["vars"])
        freq = get(f"level{i}/freq")
        keys = {v: get(f"level{i}/key/{v}") for v in vars_}
        levels.append(LevelSummary(vars_, keys, freq))
    return GFJS(levels, list(manifest["column_order"]), int(manifest["join_size"]), domains)


def gfjs_to_csv(gfjs: GFJS, directory: str) -> int:
    """Paper-exact format: one CSV of (value,freq) pairs per column."""
    os.makedirs(directory, exist_ok=True)
    total = 0
    for i, lvl in enumerate(gfjs.levels):
        for v in lvl.vars:
            p = os.path.join(directory, f"{v}.csv")
            vals = gfjs.domains[v].decode(lvl.key_cols[v])
            with open(p, "w") as f:
                for val, fr in zip(vals, lvl.freq):
                    f.write(f"{val},{fr}\n")
            total += os.path.getsize(p)
    return total
