"""GFJS container format — the compute-and-reuse store/load path **and**
the wire format of the shard-action protocol (repro/dist/actions.py).

Single container: an 8-byte magic+version, a JSON manifest (level
structure, dtypes, domains metadata), then compressed binary blobs.  Each
level's freq column and each variable's code column are separate blobs so a
loader can stream one column at a time; domains (the raw dictionary values)
are stored so the container is self-contained.

The container is a byte string first (:func:`gfjs_to_bytes` /
:func:`gfjs_from_bytes`, :func:`encoded_query_to_bytes` /
:func:`encoded_query_from_bytes`) and a file second (:func:`save_gfjs` /
:func:`load_gfjs` just add the filesystem round-trip): the process-pool
shard executor ships per-shard ``EncodedQuery`` slices out and GFJS blobs
back through exactly the on-disk codec, so a worker reply could be spilled
to disk and loaded years later unchanged.

Compression codec: zstd when the ``zstandard`` package is importable, else
stdlib zlib.  The codec is recorded both in the file header flags and per
blob in the manifest, so a reader with either capability set can decode
files written by the other (zstd-written files still need zstandard to
*read*, and loaders raise a clear error if it's absent).

The paper stores GFJS as one CSV per column; we keep the per-column layout
but use dictionary codes + compression, which is the columnar-RDBMS-internal
encoding the paper says would make GJ "even faster".  A `to_csv` escape
hatch writes the paper's exact format for the storage benchmark.
"""

from __future__ import annotations

import io
import json
import os
import struct
import zlib
from typing import BinaryIO, Dict, List, Optional, Tuple

import numpy as np

try:  # optional: the container may not ship zstandard
    import zstandard  # type: ignore
except ImportError:  # pragma: no cover - depends on environment
    zstandard = None

from repro.core.gfjs import GFJS, LevelSummary, ShardedGFJS
from repro.relational.encoding import Domain

MAGIC = b"GFJS"
VERSION = 2

ENC_MAGIC = b"GJEQ"    # EncodedQuery container (shard-action wire format)
ENC_VERSION = 1

CODEC_ZSTD = "zstd"
CODEC_ZLIB = "zlib"
_CODEC_FLAG = {CODEC_ZSTD: 1, CODEC_ZLIB: 2}
_FLAG_CODEC = {v: k for k, v in _CODEC_FLAG.items()}


def default_codec() -> str:
    """zstd when available, else the always-present zlib fallback."""
    return CODEC_ZSTD if zstandard is not None else CODEC_ZLIB


def compress_bytes(raw: bytes, *, codec: Optional[str] = None,
                   level: int = 3) -> Tuple[str, bytes]:
    """Compress ``raw``; returns (codec actually used, payload)."""
    codec = default_codec() if codec is None else codec
    if codec == CODEC_ZSTD:
        if zstandard is None:
            raise RuntimeError("zstd codec requested but zstandard is not installed")
        return codec, zstandard.ZstdCompressor(level=level).compress(raw)
    if codec == CODEC_ZLIB:
        return codec, zlib.compress(raw, level)
    raise ValueError(f"unknown codec {codec!r}")


def decompress_bytes(payload: bytes, codec: str,
                     *, max_output_size: int = 1 << 34) -> bytes:
    if codec == CODEC_ZSTD:
        if zstandard is None:
            raise RuntimeError(
                "file was written with the zstd codec but zstandard is not "
                "installed; install it or re-save with the zlib codec")
        return zstandard.ZstdDecompressor().decompress(
            payload, max_output_size=max_output_size)
    if codec == CODEC_ZLIB:
        return zlib.decompress(payload)
    raise ValueError(f"unknown codec {codec!r}")


class _BlobWriter:
    """Accumulates named compressed array blobs + their manifest entries."""

    def __init__(self, codec: Optional[str], level: int) -> None:
        self.codec = default_codec() if codec is None else codec
        self.level = level
        self.blobs: List[Dict] = []
        self.body = io.BytesIO()

    def add(self, name: str, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr)
        used, comp = compress_bytes(arr.tobytes(), codec=self.codec,
                                    level=self.level)
        off = self.body.tell()
        self.body.write(comp)
        self.blobs.append({"name": name, "offset": off, "nbytes": len(comp),
                           "dtype": str(arr.dtype), "shape": list(arr.shape),
                           "codec": used})

    def finish(self, magic: bytes, version: int, manifest: Dict) -> bytes:
        manifest["blobs"] = self.blobs
        mjson = json.dumps(manifest).encode()
        out = io.BytesIO()
        out.write(magic)
        out.write(struct.pack("<HH", version, _CODEC_FLAG[self.codec]))
        out.write(struct.pack("<Q", len(mjson)))
        out.write(mjson)
        out.write(self.body.getvalue())
        return out.getvalue()


def _open_container(data: bytes, magic: bytes, what: str):
    """(version, manifest, get) for a container byte string."""
    if data[:4] != magic:
        raise ValueError(f"not a {what} container (bad magic)")
    (version, codec_flag) = struct.unpack("<HH", data[4:8])
    header_codec = _FLAG_CODEC.get(codec_flag, CODEC_ZSTD)
    (mlen,) = struct.unpack("<Q", data[8:16])
    manifest = json.loads(data[16:16 + mlen])
    body = data[16 + mlen:]

    def get(name: str) -> np.ndarray:
        for b in manifest["blobs"]:
            if b["name"] == name:
                raw = decompress_bytes(
                    body[b["offset"]: b["offset"] + b["nbytes"]],
                    b.get("codec", header_codec))
                return np.frombuffer(
                    raw, dtype=np.dtype(b["dtype"])).reshape(b["shape"]).copy()
        raise KeyError(name)

    return version, manifest, get


def gfjs_to_bytes(gfjs, *, level: int = 3,
                  codec: Optional[str] = None) -> bytes:
    """Serialize a :class:`GFJS` or :class:`ShardedGFJS` to a byte string.

    Identical format to :func:`save_gfjs` files — a sharded summary writes
    one set of level blobs per shard (``shard{i}/...``) plus the shared
    domains and partition metadata.  This is also the GFJS half of the
    shard-action wire format (workers return their shard's summary as one
    of these blobs).
    """
    w = _BlobWriter(codec, level)

    def add_levels(g: GFJS, prefix: str) -> List[Dict]:
        for i, lvl in enumerate(g.levels):
            w.add(f"{prefix}level{i}/freq", lvl.freq)
            for v in lvl.vars:
                w.add(f"{prefix}level{i}/key/{v}", lvl.key_cols[v])
        return [{"vars": list(l.vars)} for l in g.levels]

    manifest = {
        "version": VERSION,
        "codec": w.codec,
        "join_size": gfjs.join_size,
        "column_order": gfjs.column_order,
        "domains": list(gfjs.domains.keys()),
    }
    if isinstance(gfjs, ShardedGFJS):
        manifest["sharded"] = {"partition_var": gfjs.partition_var,
                               "salt": int(gfjs.salt)}
        manifest["shards"] = [
            {"join_size": s.join_size,
             "levels": add_levels(s, f"shard{i}/")}
            for i, s in enumerate(gfjs.shards)]
    else:
        manifest["levels"] = add_levels(gfjs, "")
    for v, dom in gfjs.domains.items():
        w.add(f"domain/{v}", dom.values)
    return w.finish(MAGIC, VERSION, manifest)


def save_gfjs(gfjs, path: str, *, level: int = 3,
              codec: Optional[str] = None) -> int:
    """Write the summary; returns bytes on disk (Table 4's metric).

    Accepts a :class:`GFJS` or a :class:`ShardedGFJS` (the cache's spill
    path round-trips both transparently); the file body is exactly
    :func:`gfjs_to_bytes`.
    """
    data = gfjs_to_bytes(gfjs, level=level, codec=codec)
    with open(path, "wb") as f:
        f.write(data)
    return os.path.getsize(path)


def gfjs_from_bytes(data: bytes):
    """Load a GFJS/ShardedGFJS from a :func:`gfjs_to_bytes` byte string."""
    if data[:4] != MAGIC:
        raise ValueError("not a GFJS container (bad magic)")
    (version, codec_flag) = struct.unpack("<HH", data[4:8])
    if version == 1:
        # v1 headers packed version as one <I (no codec flag) and wrote
        # zstd-only blobs without per-blob codec entries
        header_codec = CODEC_ZSTD
    elif version == VERSION:
        header_codec = _FLAG_CODEC.get(codec_flag, CODEC_ZSTD)
    else:
        raise ValueError(f"unsupported GFJS version {version}")
    (mlen,) = struct.unpack("<Q", data[8:16])
    manifest = json.loads(data[16:16 + mlen])
    body = data[16 + mlen:]

    def get(name: str) -> np.ndarray:
        for b in manifest["blobs"]:
            if b["name"] == name:
                raw = decompress_bytes(
                    body[b["offset"]: b["offset"] + b["nbytes"]],
                    b.get("codec", header_codec))
                return np.frombuffer(raw, dtype=np.dtype(b["dtype"])).reshape(b["shape"]).copy()
        raise KeyError(name)

    domains = {v: Domain(v, get(f"domain/{v}")) for v in manifest["domains"]}

    def read_levels(levels_meta: List[Dict], prefix: str) -> List[LevelSummary]:
        levels: List[LevelSummary] = []
        for i, meta in enumerate(levels_meta):
            vars_ = tuple(meta["vars"])
            freq = get(f"{prefix}level{i}/freq")
            keys = {v: get(f"{prefix}level{i}/key/{v}") for v in vars_}
            levels.append(LevelSummary(vars_, keys, freq))
        return levels

    if "sharded" in manifest:
        shards = [
            GFJS(read_levels(sm["levels"], f"shard{i}/"),
                 list(manifest["column_order"]), int(sm["join_size"]), domains)
            for i, sm in enumerate(manifest["shards"])]
        return ShardedGFJS(shards, list(manifest["column_order"]),
                           int(manifest["join_size"]), domains,
                           manifest["sharded"]["partition_var"],
                           int(manifest["sharded"]["salt"]))
    return GFJS(read_levels(manifest["levels"], ""),
                list(manifest["column_order"]),
                int(manifest["join_size"]), domains)


def load_gfjs(path: str):
    """Load a summary written by :func:`save_gfjs` (GFJS or ShardedGFJS)."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:4] != MAGIC:
        raise ValueError(f"{path} is not a GFJS file")
    return gfjs_from_bytes(data)


# ---------------------------------------------------------------------------
# EncodedQuery (de)serialization — the outbound shard-action wire format.
# ---------------------------------------------------------------------------

def encoded_query_to_bytes(enc, *, level: int = 3,
                           codec: Optional[str] = None) -> bytes:
    """Serialize an :class:`~repro.relational.encoding.EncodedQuery`.

    Everything a worker needs to run the per-shard pipeline rides in one
    self-describing container: the :class:`JoinQuery` shape (name, table
    occurrences, projection), the shared per-variable domains (raw
    dictionary values, so decode works worker-side too), and each
    occurrence's encoded code columns.  Replicated-by-reference arrays are
    materialized in the blob — the wire carries values, not aliases.
    """
    q = enc.query
    w = _BlobWriter(codec, level)
    for v, dom in enc.domains.items():
        w.add(f"domain/{v}", dom.values)
    for i, cols in enumerate(enc.encoded_tables):
        for v, arr in cols.items():
            w.add(f"occ{i}/{v}", arr)
    manifest = {
        "version": ENC_VERSION,
        "codec": w.codec,
        "query": {
            "name": q.name,
            "tables": [[qt.table, [list(cv) for cv in qt.var_map]]
                       for qt in q.tables],
            "output": list(q.output) if q.output is not None else None,
        },
        "domains": list(enc.domains.keys()),
        "occurrences": [sorted(cols.keys()) for cols in enc.encoded_tables],
    }
    return w.finish(ENC_MAGIC, ENC_VERSION, manifest)


def encoded_query_from_bytes(data: bytes):
    """Inverse of :func:`encoded_query_to_bytes`."""
    from repro.relational.encoding import EncodedQuery
    from repro.relational.query import JoinQuery, QueryTable
    version, manifest, get = _open_container(
        data, ENC_MAGIC, "EncodedQuery")
    if version != ENC_VERSION:
        raise ValueError(f"unsupported EncodedQuery version {version}")
    qm = manifest["query"]
    query = JoinQuery(
        qm["name"],
        tuple(QueryTable(t, tuple((c, v) for c, v in vm))
              for t, vm in qm["tables"]),
        tuple(qm["output"]) if qm["output"] is not None else None,
    )
    domains = {v: Domain(v, get(f"domain/{v}")) for v in manifest["domains"]}
    encoded_tables = [
        {v: get(f"occ{i}/{v}") for v in occ_vars}
        for i, occ_vars in enumerate(manifest["occurrences"])]
    return EncodedQuery(query, domains, encoded_tables)


def gfjs_to_csv(gfjs: GFJS, directory: str) -> int:
    """Paper-exact format: one CSV of (value,freq) pairs per column."""
    os.makedirs(directory, exist_ok=True)
    total = 0
    for i, lvl in enumerate(gfjs.levels):
        for v in lvl.vars:
            p = os.path.join(directory, f"{v}.csv")
            vals = gfjs.domains[v].decode(lvl.key_cols[v])
            with open(p, "w") as f:
                for val, fr in zip(vals, lvl.freq):
                    f.write(f"{val},{fr}\n")
            total += os.path.getsize(p)
    return total
