"""JAX engine for the GJ hot phases (the TPU execution path).

The numpy engine (default) is the dynamic-shape oracle; this module provides
jit-compiled, Pallas-backed implementations of the two phases that dominate
GJ runtime — quantitative learning (GROUP BY count) and desummarization
(RLE expansion) — using the bucketized-padding scheme from DESIGN.md §2:
irregular sizes are rounded up to power-of-two buckets so the jit cache
holds O(log max-size) entries.

Frequencies here ride in int64 (joins overflow int32); x64 is enabled
process-wide at import, which is safe for the LM stack because it pins
explicit dtypes everywhere.

Dense-vs-COO dispatch: `maybe_dense_message` routes the sum-product
contraction to the MXU matmul kernel when the densified key space is small
(fill-ratio budget), else to the COO segment-sum path — a beyond-paper
optimization measured in benchmarks/table5_inmemory.py.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 flag)

from repro.core.elimination import Generator, Psi  # noqa: E402
from repro.core.gfjs import GFJS, LevelSummary, generate_gfjs  # noqa: E402
from repro.core.potentials import INT, Factor, pack_keys  # noqa: E402
from repro.obs.metrics import REGISTRY  # noqa: E402
from repro.obs.trace import span as _span  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.kernels import expand_fused as _expand_fused  # noqa: E402

I32_MAX = (1 << 31) - 1
DENSE_BUDGET = 1 << 22   # max densified cells for the MXU message path
PACK_SENTINEL = np.int64(1 << 62)  # > any packed key (pack_keys caps at 2**62)
# run counts below this: the host argsort beats device round-trips
GROUP_DEVICE_MIN_RUNS = 1 << 15


def group_device_enabled() -> bool:
    """Route group_by sorts to the device only when a real accelerator is
    attached: on CPU jax's sort pays dispatch + sentinel padding for
    nothing (measured ~3x slower than np.argsort at 1e6 runs)."""
    return not ops.default_interpret()


# ---------------------------------------------------------------------------
# quantitative learning (potential build)
# ---------------------------------------------------------------------------

def build_factor_jax(
    cols: Dict[str, np.ndarray], sizes: Dict[str, int],
    *, interpret: Optional[bool] = None,
) -> Factor:
    """GROUP BY count on-device: pack -> sort -> run_boundaries -> segsum."""
    names = tuple(cols.keys())
    keys = np.stack([np.asarray(cols[v], dtype=INT) for v in names], axis=1)
    sz = tuple(int(sizes[v]) for v in names)
    n = keys.shape[0]
    if n == 0:
        return Factor(names, keys, np.zeros(0, INT), np.zeros(0, INT), sz)
    try:
        packed = pack_keys(keys, sz)
        packable = bool(np.all(packed <= I32_MAX))
    except OverflowError:
        packable = False
    if not packable:  # fall back to the numpy oracle for huge key spaces
        return Factor.from_columns(cols, sizes)

    sp = jnp.sort(jnp.asarray(packed, jnp.int32))
    flags = ops.run_boundaries(sp, interpret=interpret)
    seg = jnp.cumsum(flags) - 1
    num = int(jnp.sum(flags))
    ones = jnp.ones_like(sp, dtype=jnp.float32)
    counts = ops.mul_segsum(seg, ones, ones, num, interpret=interpret)
    # unique packed keys = sorted packed values at boundary positions
    upacked = np.asarray(sp)[np.asarray(flags, bool)]
    # unpack mixed radix
    ukeys = np.empty((num, len(names)), dtype=INT)
    rem = upacked.astype(np.int64)
    for j in range(len(names) - 1, -1, -1):
        s = max(sz[j], 1)
        ukeys[:, j] = rem % s
        rem //= s
    return Factor(names, ukeys, np.asarray(counts, dtype=INT),
                  np.ones(num, INT), sz)


# ---------------------------------------------------------------------------
# message passing (sum-product contraction)
# ---------------------------------------------------------------------------

def maybe_dense_message(
    phi: Factor, child: str, msg_vals: np.ndarray,
    *, interpret: Optional[bool] = None,
) -> Optional[np.ndarray]:
    """MXU path: densify phi(parentxchild) if small and contract.

    Returns per-parent-code sums, or None if the dense route is off-budget
    (caller then uses the COO segment-sum path).  Exact below 2**24.
    """
    if len(phi.vars) != 2 or child not in phi.vars:
        return None
    ci = phi.var_index(child)
    pi = 1 - ci
    P, V = phi.sizes[pi], phi.sizes[ci]
    if P * V > DENSE_BUDGET:
        return None
    vals = phi.bucket * phi.fac
    if vals.max(initial=0) >= (1 << 24) or msg_vals.max(initial=0) >= (1 << 24):
        return None
    dense = np.zeros((P, V), np.float32)
    dense[phi.keys[:, pi], phi.keys[:, ci]] = vals
    out = ops.dense_message(jnp.asarray(dense),
                            jnp.asarray(msg_vals, jnp.float32)[:, None],
                            interpret=interpret)
    return np.asarray(out[:, 0]).astype(INT)


# ---------------------------------------------------------------------------
# summary-side reductions (repro.summary.algebra's hot loop)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_segments", "acc_dtype"))
def _segsum_padded(seg, x, w, *, num_segments: int, acc_dtype):
    """Fused multiply + segment-sum on bucket-padded inputs (DESIGN.md §2)."""
    prod = x.astype(acc_dtype) * w.astype(acc_dtype)
    return jax.ops.segment_sum(prod, seg, num_segments=num_segments)


def _f32_exact_conclusive(values: np.ndarray, weights: np.ndarray, n: int,
                          bound: Optional[float]) -> bool:
    """Can the f32 kernel accumulate sum|values*weights| exactly?

    Kernel-pick guard in O(1) whenever possible: first the dtype-range
    bound (narrow integer dtypes can't overflow f32-exact at this length no
    matter the data), then the caller's ``bound`` hint (summary algebra
    passes ``count * max|domain value|`` — both O(1) facts: every level of a
    frame sums to the same filtered count, and dictionary values are sorted
    so the extreme is an endpoint read).  Only when both are inconclusive
    does the historical full O(n) float64 abs-product scan run.
    """
    if values.dtype.kind in "iu" and weights.dtype.kind in "iu":
        iv, iw = np.iinfo(values.dtype), np.iinfo(weights.dtype)
        vmax = max(abs(int(iv.min)), int(iv.max))
        wmax = max(abs(int(iw.min)), int(iw.max))
        if n * vmax * wmax < ops.F32_EXACT:   # python ints: no overflow
            return True
    if bound is not None:
        return float(bound) < ops.F32_EXACT
    total = float(np.abs(values.astype(np.float64)
                         * weights.astype(np.float64)).sum())
    return total < ops.F32_EXACT


def segment_weighted_sum(
    seg_ids: np.ndarray, values: np.ndarray, weights: np.ndarray,
    num_segments: int, *, interpret: Optional[bool] = None,
    bound: Optional[float] = None,
) -> np.ndarray:
    """Per-segment sum of values*weights over sorted dense segment ids.

    The dispatch point for every summary-side aggregate: on TPU, integer
    inputs whose total magnitude fits f32-exact range ride the Pallas
    ``mul_segsum`` kernel (MXU one-hot matmul per tile); everything else —
    including all CPU traffic, where the kernel would only run interpreted —
    takes a jit'd XLA segment-sum with bucketized padding (int64 exact for
    integers, f64 for floats), so the jit cache stays O(log^2 max-size).

    ``bound``: optional caller-known upper bound on sum|values*weights|,
    letting the kernel pick skip its O(n) exactness scan (see
    :func:`_f32_exact_conclusive`).  A too-large bound only costs the fast
    path, never correctness.
    """
    values = np.asarray(values)
    weights = np.asarray(weights)
    n = len(values)
    floaty = values.dtype.kind == "f" or weights.dtype.kind == "f"
    if n == 0:
        return np.zeros(num_segments, np.float64 if floaty else np.int64)
    interpret = ops.default_interpret() if interpret is None else interpret
    if not floaty and not interpret and \
            _f32_exact_conclusive(values, weights, n, bound):
        out = ops.mul_segsum(seg_ids, values, weights, num_segments,
                             interpret=interpret)
        return np.asarray(out).astype(INT)
    # exact path: pad entries + segment count to power-of-two buckets;
    # padding rows land in a dead trailing segment that gets sliced off
    acc = jnp.float64 if floaty else jnp.int64
    s_pad = ops.next_bucket(num_segments + 1)
    n_pad = ops.next_bucket(n)
    seg_p = np.full(n_pad, s_pad - 1, np.int32)
    seg_p[:n] = seg_ids
    x_p = np.zeros(n_pad, values.dtype)
    x_p[:n] = values
    w_p = np.zeros(n_pad, weights.dtype)
    w_p[:n] = weights
    out = _segsum_padded(jnp.asarray(seg_p), jnp.asarray(x_p),
                         jnp.asarray(w_p), num_segments=s_pad, acc_dtype=acc)
    res = np.asarray(out)[:num_segments]
    return res if floaty else res.astype(INT)


def weighted_total(
    values: np.ndarray, weights: np.ndarray,
    *, interpret: Optional[bool] = None, bound: Optional[float] = None,
):
    """sum(values * weights) — a one-segment reduction."""
    seg = np.zeros(len(np.asarray(values)), np.int32)
    out = segment_weighted_sum(seg, values, weights, 1, interpret=interpret,
                               bound=bound)
    return out[0] if len(out) else out.dtype.type(0)


# ---------------------------------------------------------------------------
# on-device grouped-run sort (summary algebra's group_by hot loop)
# ---------------------------------------------------------------------------

@jax.jit
def _sorted_runs(ranks_p: jax.Array):
    """argsort + run boundaries of sentinel-padded packed ranks."""
    order = jnp.argsort(ranks_p)          # stable; pads sort to the tail
    s = ranks_p[order]
    new = jnp.concatenate([jnp.ones(1, bool), s[1:] != s[:-1]])
    seg = (jnp.cumsum(new) - 1).astype(jnp.int32)
    return order.astype(jnp.int32), new, seg


def group_runs_device(ranks: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, int]:
    """Grouped-run decomposition via an on-device packed-key sort.

    Input: packed int64 ranks (one per live run, ``pack_keys`` semantics so
    every rank < 2**62).  Output matches the host path of
    ``SummaryFrame.group_by``: (sort order, dense segment ids, group starts,
    group count).  The O(n log n) sort runs on the accelerator with
    bucketized sentinel padding (pads sort past every real key and are
    sliced off); only the O(n) boundary scan stays on the host.
    """
    n = len(ranks)
    if n == 0:
        return (np.zeros(0, INT), np.zeros(0, np.int32),
                np.zeros(0, INT), 0)
    n_pad = ops.next_bucket(n)
    r_p = np.full(n_pad, PACK_SENTINEL, np.int64)
    r_p[:n] = ranks
    order, new, seg = _sorted_runs(jnp.asarray(r_p))
    order = np.asarray(order[:n]).astype(INT)
    new = np.asarray(new[:n])
    starts = np.flatnonzero(new)
    return order, np.asarray(seg[:n]), starts, int(len(starts))


# ---------------------------------------------------------------------------
# desummarization
# ---------------------------------------------------------------------------

def desummarize_jax(
    gfjs: GFJS, *, decode: bool = False, interpret: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """RLE-expand every level with the fused `expand_gather_many` kernel.

    One kernel launch per *level* (not per column): the level's columns ride
    as a [K, runs] payload stack, the run search is amortized over all K,
    and the launch metadata (padded bounds + tile starts) is memoized on the
    summary so repeated desummarization skips the per-call searchsorted.
    """
    if gfjs.join_size > I32_MAX:
        raise ValueError("join size exceeds the int32 TPU kernel range; "
                         "use range-sharded desummarization (repro.dist)")
    out: Dict[str, np.ndarray] = {}
    total = gfjs.join_size
    t_pad = ops.next_bucket(max(total, 1))
    for li, lvl in enumerate(gfjs.levels):
        with _span(f"desummarize:level:{li}", cat="gen", backend="jax",
                   device=True, runs=len(lvl.freq)):
            if any(lvl.key_cols[v].size
                   and int(lvl.key_cols[v].max()) > I32_MAX
                   for v in lvl.vars):
                # codes past the int32 kernel range (domains >= 2**31
                # values): numpy-expand this level instead of wrapping
                for v in lvl.vars:
                    col = np.repeat(lvl.key_cols[v], lvl.freq)
                    out[v] = gfjs.domains[v].decode(col) if decode else col
                continue
            meta = ops.gfjs_expand_meta(gfjs, li, t_pad)
            payloads = jnp.stack(
                [jnp.asarray(lvl.key_cols[v], jnp.int32) for v in lvl.vars])
            cols = np.asarray(
                ops.rle_expand_many(payloads, None, total,
                                    interpret=interpret, meta=meta))
            for k, v in enumerate(lvl.vars):
                out[v] = gfjs.domains[v].decode(cols[k]) if decode \
                    else cols[k]
    return {v: out[v] for v in gfjs.column_order}


# ---------------------------------------------------------------------------
# device-resident GFJS generation (Algorithms 3/4 on the accelerator)
# ---------------------------------------------------------------------------
#
# The frontier (`cols`, `p_bucket`, per-level `fac_acc`) stays on-device as
# bucket-padded jnp arrays with an explicit live count ``n``: group lookup is
# a packed-key `jnp.searchsorted` against each psi's pre-packed parent keys,
# and expansion is ONE fused `expand_gather_many` launch per psi that carries
# every frontier column plus the (src, CSR start, offset) index columns in
# the same pass.  The host sees one scalar per psi (the new frontier size,
# needed to pick the next padding bucket) and the final per-level arrays when
# a LevelSummary is emitted.  numpy (`generate_gfjs`) remains the
# dynamic-shape oracle; `generate_gfjs_jax` falls back to it whenever the
# int32/packing preconditions don't hold.


@dataclass
class _DevicePsi:
    """One psi, uploaded once: packed parent keys + padded CSR arrays."""

    child: str
    parents: Tuple[str, ...]
    radices: Tuple[int, ...]   # parent domain sizes (packing, static)
    keys_p: jax.Array          # [g_pad] int64, sentinel-padded packed keys
    start_p: jax.Array         # [g_pad] int32
    count_p: jax.Array         # [g_pad] int32
    child_p: jax.Array         # [m_pad] int32
    bucket_p: jax.Array        # [m_pad] int64
    fac_p: jax.Array           # [m_pad] int64


def _radix_packable(sizes: Sequence[int]) -> bool:
    total = 1
    for s in sizes:
        total *= max(int(s), 1)
        if total >= (1 << 62):
            return False
    return True


def _jax_generable(gen: Generator) -> bool:
    """Do the int32-kernel / int64-packing preconditions hold?"""
    if gen.join_size > I32_MAX or len(gen.root_codes) > I32_MAX:
        return False
    if len(gen.root_codes) and int(gen.root_codes.max()) > I32_MAX:
        return False
    for level in gen.levels:
        for psi in level:
            if not _radix_packable(psi.parent_sizes):
                return False
            if psi.child_size > I32_MAX or psi.num_entries > I32_MAX \
                    or psi.num_groups > I32_MAX:
                return False
            if any(s > I32_MAX for s in psi.parent_sizes):
                return False
    return True


def _device_psi(psi: Psi) -> _DevicePsi:
    """Pack + pad + upload one psi (memoized on the Psi object)."""
    cached = getattr(psi, "_device", None)
    if cached is not None:
        return cached
    g = psi.num_groups
    g_pad = ops.next_bucket(max(g, 1))
    packed = pack_keys(psi.parent_keys, list(psi.parent_sizes)) if g else \
        np.zeros(0, INT)
    keys_p = np.full(g_pad, PACK_SENTINEL, np.int64)
    keys_p[:g] = packed
    start_p = np.zeros(g_pad, np.int32)
    start_p[:g] = psi.start
    count_p = np.zeros(g_pad, np.int32)
    count_p[:g] = psi.count
    m = psi.num_entries
    m_pad = ops.next_bucket(max(m, 1))
    child_p = np.zeros(m_pad, np.int32)
    child_p[:m] = psi.child_codes
    bucket_p = np.zeros(m_pad, np.int64)
    bucket_p[:m] = psi.bucket
    fac_p = np.zeros(m_pad, np.int64)
    fac_p[:m] = psi.fac
    dp = _DevicePsi(psi.child, psi.parents, tuple(int(s) for s in psi.parent_sizes),
                    jnp.asarray(keys_p), jnp.asarray(start_p),
                    jnp.asarray(count_p), jnp.asarray(child_p),
                    jnp.asarray(bucket_p), jnp.asarray(fac_p))
    psi._device = dp
    return dp


@functools.partial(jax.jit, static_argnames=("radices",))
def _frontier_lookup(parent_cols, n, keys_p, start_p, count_p, *, radices):
    """Packed-key group lookup + expansion counts for one psi.

    ``parent_cols`` is [P, n_pad] int32 (P == len(radices), possibly 0 for a
    parentless psi — the empty pack is key 0, matching `pack_keys` of a
    zero-width row).  Rows at or past the live count ``n`` and rows whose
    key misses psi's parent groups get count 0 — exactly the numpy
    `_lookup_groups` miss semantics.
    """
    n_pad = parent_cols.shape[1]
    key = jnp.zeros((n_pad,), jnp.int64)
    for j, s in enumerate(radices):
        key = key * max(int(s), 1) + parent_cols[j].astype(jnp.int64)
    pos = jnp.clip(jnp.searchsorted(keys_p, key), 0,
                   keys_p.shape[0] - 1).astype(jnp.int32)
    live = jax.lax.iota(jnp.int32, n_pad) < n
    hit = (keys_p[pos] == key) & live
    counts = jnp.where(hit, count_p[pos], 0).astype(jnp.int32)
    bounds = jnp.cumsum(counts, dtype=jnp.int32)
    start_g = jnp.where(hit, start_p[pos], 0).astype(jnp.int32)
    return counts, bounds, start_g, bounds - counts


@jax.jit
def _psi_weights(src_x, start_x, offs_x, child_p, bucket_p, fac_p,
                 p_bucket, fac_acc):
    """Recover cidx from the expanded index columns; gather psi payloads.

    ``cidx = start[g[src]] + within`` where ``within = t - offsets[src]`` —
    both ingredients were expanded by the fused kernel, so this is pure
    gathers.  Rows past the live total produce clipped garbage that the
    caller never reads (sliced off at LevelSummary emission).
    """
    t = jax.lax.iota(jnp.int32, src_x.shape[0])
    cidx = jnp.clip(start_x + (t - offs_x), 0, child_p.shape[0] - 1)
    src = jnp.clip(src_x, 0, p_bucket.shape[0] - 1)
    child = child_p[cidx]
    pb = p_bucket[src] * bucket_p[cidx]
    fa = fac_acc[src] * fac_p[cidx]
    return child, pb, fa


def expand_level_jax(
    cols: Dict[str, jax.Array], p_bucket: jax.Array,
    level: Sequence[Psi], n: int, *, interpret: Optional[bool] = None,
) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array, Tuple[str, ...], int]:
    """Device-resident `expand_level`: one fused kernel launch per psi.

    ``cols``/``p_bucket`` are bucket-padded device arrays with ``n`` live
    rows.  Returns ``(cols, p_bucket, freq, new_vars, n_new)`` with ``freq``
    still on-device ([t_pad], slice [:n_new] when emitting).  The only host
    syncs are the per-psi frontier totals (one scalar each, needed to pick
    the next padding bucket).
    """
    interpret = ops.default_interpret() if interpret is None else interpret
    fac_acc = jnp.ones_like(p_bucket)
    new_vars: List[str] = []
    names = list(cols.keys())
    for psi in level:
        dp = _device_psi(psi)
        parent_cols = (jnp.stack([cols[p] for p in dp.parents])
                       if dp.parents
                       else jnp.zeros((0,) + p_bucket.shape, jnp.int32))
        counts, bounds, start_g, offs = _frontier_lookup(
            parent_cols, jnp.int32(n), dp.keys_p, dp.start_p, dp.count_p,
            radices=dp.radices)
        total = int(bounds[-1])          # host sync: one scalar per psi
        if total == 0:
            # dead frontier: keep padded shapes, mark zero live rows — the
            # remaining psis of the level still bind their (empty) children
            # so the emitted LevelSummary names every child, like numpy
            cols = dict(cols)
            cols[dp.child] = jnp.zeros(p_bucket.shape, jnp.int32)
            names.append(dp.child)
            new_vars.append(dp.child)
            n = 0
            continue
        t_pad = ops.next_bucket(total)
        src_iota = jax.lax.iota(jnp.int32, p_bucket.shape[0])
        payloads = jnp.concatenate([
            jnp.stack([cols[v] for v in names]),
            src_iota[None], start_g[None], offs[None]])
        expanded = _expand_fused.expand_gather_many(
            payloads, bounds, t_pad=t_pad, interpret=interpret)
        child, p_bucket, fac_acc = _psi_weights(
            expanded[-3], expanded[-2], expanded[-1],
            dp.child_p, dp.bucket_p, dp.fac_p, p_bucket, fac_acc)
        cols = {v: expanded[i] for i, v in enumerate(names)}
        cols[dp.child] = child
        names.append(dp.child)
        new_vars.append(dp.child)
        n = total
    return cols, p_bucket, p_bucket * fac_acc, tuple(new_vars), n


def generate_gfjs_jax(
    gen: Generator, domains: Dict[str, "Domain"],
    *, interpret: Optional[bool] = None,
) -> GFJS:
    """Device-resident Algorithms 3/4; falls back to the numpy oracle.

    Level-for-level identical to :func:`repro.core.gfjs.generate_gfjs`
    (expansion is order-preserving in both engines).  The numpy path remains
    authoritative for dynamic shapes, trace recording (incremental
    maintenance needs host (src, cidx) caches), and any generator outside
    the int32/packing envelope (`_jax_generable`).
    """
    if not _jax_generable(gen):
        return generate_gfjs(gen, domains)

    levels_out: List[LevelSummary] = [
        LevelSummary((gen.root,), {gen.root: gen.root_codes}, gen.root_freq)]

    n = len(gen.root_codes)
    n_pad = ops.next_bucket(max(n, 1))
    root_p = np.zeros(n_pad, np.int32)
    root_p[:n] = gen.root_codes
    cols: Dict[str, jax.Array] = {gen.root: jnp.asarray(root_p)}
    p_bucket = jnp.ones((n_pad,), jnp.int64)

    runs_hist = REGISTRY.histogram("gfjs.runs_per_level", unit="runs")
    runs_hist.observe(n)
    for depth, level in enumerate(gen.levels):
        children = tuple(p.child for p in level)
        if n == 0:     # dead frontier: remaining levels are all empty
            levels_out.append(LevelSummary(
                children, {v: np.zeros(0, INT) for v in children},
                np.zeros(0, INT)))
            for p in level:
                cols[p.child] = jnp.zeros((0,), jnp.int32)
            runs_hist.observe(0)
            continue
        with _span(f"gfjs:level:{depth}", cat="gen", backend="jax",
                   device=True, depth=depth) as sp:
            cols, p_bucket, freq, new_vars, n = expand_level_jax(
                cols, p_bucket, level, n, interpret=interpret)
            sp.set(runs=n, vars=",".join(new_vars))
        runs_hist.observe(n)
        levels_out.append(LevelSummary(
            new_vars,
            {v: np.asarray(cols[v][:n]).astype(INT) for v in new_vars},
            np.asarray(freq[:n]).astype(INT)))

    return GFJS(levels_out, list(gen.column_order), gen.join_size, domains)
