"""JAX engine for the GJ hot phases (the TPU execution path).

The numpy engine (default) is the dynamic-shape oracle; this module provides
jit-compiled, Pallas-backed implementations of the two phases that dominate
GJ runtime — quantitative learning (GROUP BY count) and desummarization
(RLE expansion) — using the bucketized-padding scheme from DESIGN.md §2:
irregular sizes are rounded up to power-of-two buckets so the jit cache
holds O(log max-size) entries.

Frequencies here ride in int64 (joins overflow int32); x64 is enabled
process-wide at import, which is safe for the LM stack because it pins
explicit dtypes everywhere.

Dense-vs-COO dispatch: `maybe_dense_message` routes the sum-product
contraction to the MXU matmul kernel when the densified key space is small
(fill-ratio budget), else to the COO segment-sum path — a beyond-paper
optimization measured in benchmarks/table5_inmemory.py.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 flag)

from repro.core.gfjs import GFJS  # noqa: E402
from repro.core.potentials import INT, Factor, pack_keys  # noqa: E402
from repro.kernels import ops  # noqa: E402

I32_MAX = (1 << 31) - 1
DENSE_BUDGET = 1 << 22   # max densified cells for the MXU message path


# ---------------------------------------------------------------------------
# quantitative learning (potential build)
# ---------------------------------------------------------------------------

def build_factor_jax(
    cols: Dict[str, np.ndarray], sizes: Dict[str, int],
    *, interpret: Optional[bool] = None,
) -> Factor:
    """GROUP BY count on-device: pack -> sort -> run_boundaries -> segsum."""
    names = tuple(cols.keys())
    keys = np.stack([np.asarray(cols[v], dtype=INT) for v in names], axis=1)
    sz = tuple(int(sizes[v]) for v in names)
    n = keys.shape[0]
    if n == 0:
        return Factor(names, keys, np.zeros(0, INT), np.zeros(0, INT), sz)
    try:
        packed = pack_keys(keys, sz)
        packable = bool(np.all(packed <= I32_MAX))
    except OverflowError:
        packable = False
    if not packable:  # fall back to the numpy oracle for huge key spaces
        return Factor.from_columns(cols, sizes)

    sp = jnp.sort(jnp.asarray(packed, jnp.int32))
    flags = ops.run_boundaries(sp, interpret=interpret)
    seg = jnp.cumsum(flags) - 1
    num = int(jnp.sum(flags))
    ones = jnp.ones_like(sp, dtype=jnp.float32)
    counts = ops.mul_segsum(seg, ones, ones, num, interpret=interpret)
    # unique packed keys = sorted packed values at boundary positions
    upacked = np.asarray(sp)[np.asarray(flags, bool)]
    # unpack mixed radix
    ukeys = np.empty((num, len(names)), dtype=INT)
    rem = upacked.astype(np.int64)
    for j in range(len(names) - 1, -1, -1):
        s = max(sz[j], 1)
        ukeys[:, j] = rem % s
        rem //= s
    return Factor(names, ukeys, np.asarray(counts, dtype=INT),
                  np.ones(num, INT), sz)


# ---------------------------------------------------------------------------
# message passing (sum-product contraction)
# ---------------------------------------------------------------------------

def maybe_dense_message(
    phi: Factor, child: str, msg_vals: np.ndarray,
    *, interpret: Optional[bool] = None,
) -> Optional[np.ndarray]:
    """MXU path: densify phi(parentxchild) if small and contract.

    Returns per-parent-code sums, or None if the dense route is off-budget
    (caller then uses the COO segment-sum path).  Exact below 2**24.
    """
    if len(phi.vars) != 2 or child not in phi.vars:
        return None
    ci = phi.var_index(child)
    pi = 1 - ci
    P, V = phi.sizes[pi], phi.sizes[ci]
    if P * V > DENSE_BUDGET:
        return None
    vals = phi.bucket * phi.fac
    if vals.max(initial=0) >= (1 << 24) or msg_vals.max(initial=0) >= (1 << 24):
        return None
    dense = np.zeros((P, V), np.float32)
    dense[phi.keys[:, pi], phi.keys[:, ci]] = vals
    out = ops.dense_message(jnp.asarray(dense),
                            jnp.asarray(msg_vals, jnp.float32)[:, None],
                            interpret=interpret)
    return np.asarray(out[:, 0]).astype(INT)


# ---------------------------------------------------------------------------
# summary-side reductions (repro.summary.algebra's hot loop)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_segments", "acc_dtype"))
def _segsum_padded(seg, x, w, *, num_segments: int, acc_dtype):
    """Fused multiply + segment-sum on bucket-padded inputs (DESIGN.md §2)."""
    prod = x.astype(acc_dtype) * w.astype(acc_dtype)
    return jax.ops.segment_sum(prod, seg, num_segments=num_segments)


def segment_weighted_sum(
    seg_ids: np.ndarray, values: np.ndarray, weights: np.ndarray,
    num_segments: int, *, interpret: Optional[bool] = None,
) -> np.ndarray:
    """Per-segment sum of values*weights over sorted dense segment ids.

    The dispatch point for every summary-side aggregate: on TPU, integer
    inputs whose total magnitude fits f32-exact range ride the Pallas
    ``mul_segsum`` kernel (MXU one-hot matmul per tile); everything else —
    including all CPU traffic, where the kernel would only run interpreted —
    takes a jit'd XLA segment-sum with bucketized padding (int64 exact for
    integers, f64 for floats), so the jit cache stays O(log^2 max-size).
    """
    values = np.asarray(values)
    weights = np.asarray(weights)
    n = len(values)
    floaty = values.dtype.kind == "f" or weights.dtype.kind == "f"
    if n == 0:
        return np.zeros(num_segments, np.float64 if floaty else np.int64)
    interpret = ops.default_interpret() if interpret is None else interpret
    if not floaty and not interpret:
        # TPU fast path when f32 accumulation is exact: one cheap O(n) bound
        total = float(np.abs(values.astype(np.float64)
                             * weights.astype(np.float64)).sum())
        if total < ops.F32_EXACT:
            out = ops.mul_segsum(seg_ids, values, weights, num_segments,
                                 interpret=interpret)
            return np.asarray(out).astype(INT)
    # exact path: pad entries + segment count to power-of-two buckets;
    # padding rows land in a dead trailing segment that gets sliced off
    acc = jnp.float64 if floaty else jnp.int64
    s_pad = ops.next_bucket(num_segments + 1)
    n_pad = ops.next_bucket(n)
    seg_p = np.full(n_pad, s_pad - 1, np.int32)
    seg_p[:n] = seg_ids
    x_p = np.zeros(n_pad, values.dtype)
    x_p[:n] = values
    w_p = np.zeros(n_pad, weights.dtype)
    w_p[:n] = weights
    out = _segsum_padded(jnp.asarray(seg_p), jnp.asarray(x_p),
                         jnp.asarray(w_p), num_segments=s_pad, acc_dtype=acc)
    res = np.asarray(out)[:num_segments]
    return res if floaty else res.astype(INT)


def weighted_total(
    values: np.ndarray, weights: np.ndarray,
    *, interpret: Optional[bool] = None,
):
    """sum(values * weights) — a one-segment reduction."""
    seg = np.zeros(len(np.asarray(values)), np.int32)
    out = segment_weighted_sum(seg, values, weights, 1, interpret=interpret)
    return out[0] if len(out) else out.dtype.type(0)


# ---------------------------------------------------------------------------
# desummarization
# ---------------------------------------------------------------------------

def desummarize_jax(
    gfjs: GFJS, *, decode: bool = False, interpret: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """RLE-expand every level with the `expand_gather` kernel."""
    if gfjs.join_size > I32_MAX:
        raise ValueError("join size exceeds the int32 TPU kernel range; "
                         "use range-sharded desummarization (repro.dist)")
    out: Dict[str, np.ndarray] = {}
    for li, lvl in enumerate(gfjs.levels):
        bounds = jnp.asarray(gfjs.bounds(li), jnp.int32)
        for v in lvl.vars:
            codes = jnp.asarray(lvl.key_cols[v], jnp.int32)
            col = np.asarray(ops.rle_expand(codes, bounds, gfjs.join_size,
                                            interpret=interpret))
            out[v] = gfjs.domains[v].decode(col) if decode else col
    return {v: out[v] for v in gfjs.column_order}
