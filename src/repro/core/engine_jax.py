"""JAX engine for the GJ hot phases (the TPU execution path).

The numpy engine (default) is the dynamic-shape oracle; this module provides
jit-compiled, Pallas-backed implementations of the two phases that dominate
GJ runtime — quantitative learning (GROUP BY count) and desummarization
(RLE expansion) — using the bucketized-padding scheme from DESIGN.md §2:
irregular sizes are rounded up to power-of-two buckets so the jit cache
holds O(log max-size) entries.

Frequencies here ride in int64 (joins overflow int32); x64 is enabled
process-wide at import, which is safe for the LM stack because it pins
explicit dtypes everywhere.

Dense-vs-COO dispatch: `maybe_dense_message` routes the sum-product
contraction to the MXU matmul kernel when the densified key space is small
(fill-ratio budget), else to the COO segment-sum path — a beyond-paper
optimization measured in benchmarks/table5_inmemory.py.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 flag)

from repro.core.gfjs import GFJS  # noqa: E402
from repro.core.potentials import INT, Factor, pack_keys  # noqa: E402
from repro.kernels import ops  # noqa: E402

I32_MAX = (1 << 31) - 1
DENSE_BUDGET = 1 << 22   # max densified cells for the MXU message path


# ---------------------------------------------------------------------------
# quantitative learning (potential build)
# ---------------------------------------------------------------------------

def build_factor_jax(
    cols: Dict[str, np.ndarray], sizes: Dict[str, int],
    *, interpret: Optional[bool] = None,
) -> Factor:
    """GROUP BY count on-device: pack -> sort -> run_boundaries -> segsum."""
    names = tuple(cols.keys())
    keys = np.stack([np.asarray(cols[v], dtype=INT) for v in names], axis=1)
    sz = tuple(int(sizes[v]) for v in names)
    n = keys.shape[0]
    if n == 0:
        return Factor(names, keys, np.zeros(0, INT), np.zeros(0, INT), sz)
    try:
        packed = pack_keys(keys, sz)
        packable = bool(np.all(packed <= I32_MAX))
    except OverflowError:
        packable = False
    if not packable:  # fall back to the numpy oracle for huge key spaces
        return Factor.from_columns(cols, sizes)

    sp = jnp.sort(jnp.asarray(packed, jnp.int32))
    flags = ops.run_boundaries(sp, interpret=interpret)
    seg = jnp.cumsum(flags) - 1
    num = int(jnp.sum(flags))
    ones = jnp.ones_like(sp, dtype=jnp.float32)
    counts = ops.mul_segsum(seg, ones, ones, num, interpret=interpret)
    # unique packed keys = sorted packed values at boundary positions
    upacked = np.asarray(sp)[np.asarray(flags, bool)]
    # unpack mixed radix
    ukeys = np.empty((num, len(names)), dtype=INT)
    rem = upacked.astype(np.int64)
    for j in range(len(names) - 1, -1, -1):
        s = max(sz[j], 1)
        ukeys[:, j] = rem % s
        rem //= s
    return Factor(names, ukeys, np.asarray(counts, dtype=INT),
                  np.ones(num, INT), sz)


# ---------------------------------------------------------------------------
# message passing (sum-product contraction)
# ---------------------------------------------------------------------------

def maybe_dense_message(
    phi: Factor, child: str, msg_vals: np.ndarray,
    *, interpret: Optional[bool] = None,
) -> Optional[np.ndarray]:
    """MXU path: densify phi(parentxchild) if small and contract.

    Returns per-parent-code sums, or None if the dense route is off-budget
    (caller then uses the COO segment-sum path).  Exact below 2**24.
    """
    if len(phi.vars) != 2 or child not in phi.vars:
        return None
    ci = phi.var_index(child)
    pi = 1 - ci
    P, V = phi.sizes[pi], phi.sizes[ci]
    if P * V > DENSE_BUDGET:
        return None
    vals = phi.bucket * phi.fac
    if vals.max(initial=0) >= (1 << 24) or msg_vals.max(initial=0) >= (1 << 24):
        return None
    dense = np.zeros((P, V), np.float32)
    dense[phi.keys[:, pi], phi.keys[:, ci]] = vals
    out = ops.dense_message(jnp.asarray(dense),
                            jnp.asarray(msg_vals, jnp.float32)[:, None],
                            interpret=interpret)
    return np.asarray(out[:, 0]).astype(INT)


# ---------------------------------------------------------------------------
# desummarization
# ---------------------------------------------------------------------------

def desummarize_jax(
    gfjs: GFJS, *, decode: bool = False, interpret: Optional[bool] = None,
) -> Dict[str, np.ndarray]:
    """RLE-expand every level with the `expand_gather` kernel."""
    if gfjs.join_size > I32_MAX:
        raise ValueError("join size exceeds the int32 TPU kernel range; "
                         "use range-sharded desummarization (repro.dist)")
    out: Dict[str, np.ndarray] = {}
    for li, lvl in enumerate(gfjs.levels):
        bounds = jnp.asarray(gfjs.bounds(li), jnp.int32)
        for v in lvl.vars:
            codes = jnp.asarray(lvl.key_cols[v], jnp.int32)
            col = np.asarray(ops.rle_expand(codes, bounds, gfjs.join_size,
                                            interpret=interpret))
            out[v] = gfjs.domains[v].decode(col) if decode else col
    return {v: out[v] for v in gfjs.column_order}
