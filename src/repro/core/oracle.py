"""Brute-force reference join — the ground truth for every GJ test.

Materializes the full n-way join by left-deep pairwise sorted-merge products
over *row-level* factors (one entry per tuple, multiplicity 1), so the output
is the exact join multiset.  Only safe for test-sized inputs.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.potentials import INT, Factor
from repro.relational.encoding import EncodedQuery


def _row_factor(cols: Dict[str, np.ndarray], sizes: Dict[str, int]) -> Factor:
    names = tuple(cols.keys())
    keys = np.stack([np.asarray(cols[v], dtype=INT) for v in names], axis=1)
    n = keys.shape[0]
    return Factor(names, keys, np.ones(n, INT), np.ones(n, INT),
                  tuple(int(sizes[v]) for v in names))


def oracle_join(enc: EncodedQuery) -> Dict[str, np.ndarray]:
    """Full join result (encoded codes), all query variables, unsorted."""
    sizes = enc.domain_sizes()
    fs = [_row_factor(c, sizes) for c in enc.encoded_tables]
    # join connected-first to avoid Cartesian products
    acc = fs[0]
    rest = fs[1:]
    while rest:
        nxt = next((f for f in rest if set(f.vars) & set(acc.vars)), rest[0])
        rest.remove(nxt)
        acc = acc.multiply(nxt)
    out_vars = enc.query.variables
    acc = acc.project(tuple(out_vars))
    return {v: acc.col(v).copy() for v in out_vars}


def sort_rows(cols: Dict[str, np.ndarray], order: Sequence[str]) -> np.ndarray:
    """Row matrix [n, k] sorted lexicographically by ``order``."""
    mat = np.stack([np.asarray(cols[v], dtype=INT) for v in order], axis=1)
    idx = np.lexsort(mat.T[::-1])
    return mat[idx]


def grouped_rle(
    sorted_mat: np.ndarray, groups: Sequence[int]
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-level grouped RLE of a sorted row matrix (Definition 1).

    ``groups`` gives how many columns each GFJS level spans (1 for ordinary
    levels; >1 for joint Cartesian levels).  A level's runs break whenever
    the *prefix through that level* changes — the 'Grouped' in GFJS.
    Returns [(values [runs, group_width], freqs)] per level.
    """
    n, k = sorted_mat.shape
    assert sum(groups) == k
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    if n == 0:
        return [(np.zeros((0, g), INT), np.zeros(0, INT)) for g in groups]
    change = np.zeros(n, dtype=bool)
    change[0] = True
    col = 0
    for g in groups:
        for i in range(col, col + g):
            change[1:] |= sorted_mat[1:, i] != sorted_mat[:-1, i]
        starts = np.flatnonzero(change)
        freqs = np.diff(np.append(starts, n)).astype(INT)
        out.append((sorted_mat[starts][:, col:col + g].copy(), freqs))
        col += g
    return out
