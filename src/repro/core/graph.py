"""Qualitative PGM structure for a join query.

Implements the paper's Section 2.2/3.2 machinery: the query MRF (one node per
variable, one clique per table occurrence), min-fill triangulation producing
an elimination order + maxcliques, and the junction tree via maximal
spanning tree over separator sizes, with a Running-Intersection-Property
verifier used by the test suite.

Early projection (paper §3.7): non-output variables are placed *first* in
the elimination order (the paper's O' before O); the elimination driver
skips emitting conditional factors for them.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.relational.query import JoinQuery


@dataclass
class QueryGraph:
    """Primal (moralized) graph of the query MRF."""

    variables: List[str]
    adjacency: Dict[str, Set[str]]
    hyperedges: List[FrozenSet[str]]   # one clique per query table

    @staticmethod
    def from_query(query: JoinQuery) -> "QueryGraph":
        variables = query.variables
        adj: Dict[str, Set[str]] = {v: set() for v in variables}
        edges = query.hyperedges()
        for e in edges:
            vs = sorted(e)
            for i, u in enumerate(vs):
                for w in vs[i + 1:]:
                    adj[u].add(w)
                    adj[w].add(u)
        return QueryGraph(variables, adj, edges)

    def is_connected(self) -> bool:
        if not self.variables:
            return True
        seen = {self.variables[0]}
        stack = [self.variables[0]]
        while stack:
            u = stack.pop()
            for w in self.adjacency[u]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return len(seen) == len(self.variables)


@dataclass
class Triangulation:
    """Output of min-fill: order, fill-in edges, maxcliques, parents."""

    order: List[str]                       # elimination order
    fill_edges: List[Tuple[str, str]]
    cliques: List[FrozenSet[str]]          # elimination cliques ({v} ∪ nbrs(v))
    maxcliques: List[FrozenSet[str]]
    parents: Dict[str, Tuple[str, ...]]    # v -> separator (nbrs at elim time)


def min_fill_order(
    graph: QueryGraph,
    *,
    first: Optional[Sequence[str]] = None,
    forced_order: Optional[Sequence[str]] = None,
) -> Triangulation:
    """Min-fill heuristic (paper §2.2.1).

    ``first``: variables that must be eliminated before all others (early
    projection's O'); within each group ties break by fill count then name.
    ``forced_order``: full user-specified order (overrides the heuristic).
    """
    adj = {v: set(ns) for v, ns in graph.adjacency.items()}
    remaining = set(graph.variables)
    first_set = set(first or ())

    order: List[str] = []
    fill_edges: List[Tuple[str, str]] = []
    cliques: List[FrozenSet[str]] = []
    parents: Dict[str, Tuple[str, ...]] = {}

    def fill_count(v: str) -> int:
        ns = list(adj[v])
        cnt = 0
        for i, a in enumerate(ns):
            for b in ns[i + 1:]:
                if b not in adj[a]:
                    cnt += 1
        return cnt

    forced = list(forced_order) if forced_order is not None else None
    step = 0
    while remaining:
        if forced is not None:
            v = forced[step]
            step += 1
        else:
            pool = remaining & first_set if remaining & first_set else remaining
            v = min(pool, key=lambda u: (fill_count(u), u))
        remaining.discard(v)

        nbrs = sorted(adj[v] & remaining)
        parents[v] = tuple(nbrs)
        cliques.append(frozenset([v, *nbrs]))
        # connect the neighbours (fill-in edges)
        for i, a in enumerate(nbrs):
            for b in nbrs[i + 1:]:
                if b not in adj[a]:
                    adj[a].add(b)
                    adj[b].add(a)
                    fill_edges.append((a, b))
        for a in nbrs:
            adj[a].discard(v)
        order.append(v)

    # maxcliques = elimination cliques not contained in a later clique
    maxcliques: List[FrozenSet[str]] = []
    for i, c in enumerate(cliques):
        if not any(c < cliques[j] for j in range(len(cliques)) if j != i) and \
           not any(c == m for m in maxcliques):
            maxcliques.append(c)
    return Triangulation(order, fill_edges, cliques, maxcliques, parents)


def structurally_acyclic(graph: QueryGraph) -> bool:
    """True iff the query hypergraph is alpha-acyclic.

    Beeri et al.: a hypergraph is alpha-acyclic iff its primal graph is
    chordal AND conformal (every maximal clique of the primal graph is
    contained in some hyperedge).  Chordality falls out of an
    unconstrained min-fill sweep — on a chordal graph a simplicial
    (zero-fill) vertex always exists and eliminating it preserves
    chordality, so the heuristic adds no fill edges exactly when the graph
    is chordal; the sweep's maxcliques are then the maximal cliques the
    conformality check needs.

    The planner uses this as the hybrid gate: acyclic queries never get
    bag steps, so their plan signatures (and cache keys) are unchanged.
    """
    tri = min_fill_order(graph)
    if tri.fill_edges:
        return False
    return all(any(c <= e for e in graph.hyperedges) for c in tri.maxcliques)


def decompose_bags(
    graph: QueryGraph, order: Sequence[str]
) -> Tuple[List[Tuple[Tuple[str, ...], Tuple[int, ...]]], Triangulation]:
    """Cover the table occurrences with cliques of ``order``'s triangulation.

    Returns ``(bags, tri)`` where each bag is ``(scope, occurrences)``:
    ``occurrences`` indexes ``graph.hyperedges`` (== the query's table
    occurrences in encoding order) and ``scope`` is the union of their
    variables, listed in elimination order (the global attribute order a
    WCOJ bag step binds in).  Only bags joining >= 2 occurrences are
    returned; singleton occurrences stay ordinary per-table factors.

    Every hyperedge is a clique of the primal graph, hence of the
    triangulated graph, hence contained in one of its maximal cliques —
    so assignment never fails.  Each occurrence goes to the containing
    maxclique that contains the most hyperedges overall (co-location:
    the whole cyclic core lands in one bag when a clique covers it),
    ties broken toward smaller cliques then discovery order, so the
    decomposition is deterministic given the order.

    Keeping every bag inside a clique of the *chosen order's*
    triangulation is what makes hybrid execution bit-identical to the
    monolithic build: elimination over bag potentials then meets exactly
    the same separators (parents) at every step as elimination over the
    raw table factors (see DESIGN.md §19 for the induction).
    """
    tri = min_fill_order(graph, forced_order=order)
    contains = [[i for i, e in enumerate(graph.hyperedges) if e <= c]
                for c in tri.maxcliques]
    assignment: Dict[int, int] = {}
    for i, e in enumerate(graph.hyperedges):
        cands = [j for j, c in enumerate(tri.maxcliques) if e <= c]
        if not cands:  # pragma: no cover - chordal-cover invariant
            continue
        assignment[i] = max(
            cands, key=lambda j: (len(contains[j]), -len(tri.maxcliques[j]), -j))
    grouped: Dict[int, List[int]] = {}
    for i in sorted(assignment):
        grouped.setdefault(assignment[i], []).append(i)
    bags: List[Tuple[Tuple[str, ...], Tuple[int, ...]]] = []
    for j in sorted(grouped):
        occs = grouped[j]
        if len(occs) < 2:
            continue
        scope_set: Set[str] = set()
        for i in occs:
            scope_set |= graph.hyperedges[i]
        scope = tuple(v for v in order if v in scope_set)
        bags.append((scope, tuple(occs)))
    return bags, tri


@dataclass
class JunctionTree:
    """Tree of maxcliques with separators (paper §2.2.1)."""

    cliques: List[FrozenSet[str]]
    edges: List[Tuple[int, int, FrozenSet[str]]]  # (i, j, separator)

    def neighbors(self, i: int) -> List[Tuple[int, FrozenSet[str]]]:
        out = []
        for a, b, s in self.edges:
            if a == i:
                out.append((b, s))
            elif b == i:
                out.append((a, s))
        return out

    def satisfies_rip(self) -> bool:
        """Running Intersection Property: for every pair of cliques, their
        intersection is contained in every clique on the path between them."""
        n = len(self.cliques)
        adj: Dict[int, List[int]] = {i: [] for i in range(n)}
        for a, b, _ in self.edges:
            adj[a].append(b)
            adj[b].append(a)

        def path(a: int, b: int) -> List[int]:
            prev = {a: a}
            stack = [a]
            while stack:
                u = stack.pop()
                if u == b:
                    break
                for w in adj[u]:
                    if w not in prev:
                        prev[w] = u
                        stack.append(w)
            out = [b]
            while out[-1] != a:
                out.append(prev[out[-1]])
            return out

        for i in range(n):
            for j in range(i + 1, n):
                inter = self.cliques[i] & self.cliques[j]
                if not inter:
                    continue
                for k in path(i, j):
                    if not inter <= self.cliques[k]:
                        return False
        return True


def junction_tree(maxcliques: List[FrozenSet[str]]) -> JunctionTree:
    """Maximal spanning tree over separator sizes (Kruskal)."""
    n = len(maxcliques)
    cand: List[Tuple[int, int, int]] = []
    for i in range(n):
        for j in range(i + 1, n):
            w = len(maxcliques[i] & maxcliques[j])
            if w > 0:
                cand.append((w, i, j))
    cand.sort(key=lambda t: (-t[0], t[1], t[2]))

    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    edges: List[Tuple[int, int, FrozenSet[str]]] = []
    for w, i, j in cand:
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj
            edges.append((i, j, maxcliques[i] & maxcliques[j]))
    return JunctionTree(maxcliques, edges)


def is_chordal(adj: Dict[str, Set[str]]) -> bool:
    """Chordality check via a zero-fill min-fill sweep."""
    a = {v: set(ns) for v, ns in adj.items()}
    remaining = set(a.keys())
    while remaining:
        # find a simplicial vertex
        found = None
        for v in sorted(remaining):
            ns = [u for u in a[v] if u in remaining]
            ok = all(b in a[x] for i, x in enumerate(ns) for b in ns[i + 1:])
            if ok:
                found = v
                break
        if found is None:
            return False
        remaining.discard(found)
    return True
