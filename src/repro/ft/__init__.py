"""Fault-tolerance scenarios: failure injection, deterministic resume,
straggler mitigation.  The mechanisms live in train/trainer.py and
checkpoint/; this package hosts their test scenarios and docs."""
