"""Straggler mitigation at fleet scale.

Mechanisms (layered, per DESIGN.md's 1000+-node posture):

1. **Host-level** (implemented, used by the Trainer): the prefetch queue in
   train/trainer.py decouples storage latency from step latency, and the
   GFJS-range data layout (data/pipeline.py) makes host re-balancing O(1):
   a slow or dead data host's row-range is re-assigned by changing two
   integers — no data movement, because every host holds the (tiny) summary.

2. **Step-level** (this module): a deadline monitor that records per-step
   wall times, flags steps exceeding `k * median` as straggler events, and
   recommends an action: re-balance data ranges (host skew), checkpoint+
   evict (persistent slow node), or nothing (transient).  On a real fleet
   the recommendation feeds the cluster scheduler; here it feeds logs and
   the FT test-suite.

3. **Collective-level** (documented): synchronous SPMD means one slow chip
   stalls the all-reduce.  The standard mitigations our stack composes
   with: smaller microbatches (train_step ``microbatches``) to shrink the
   blast radius of a stall, gradient compression (train_step
   ``compressed_psum``) to shrink exposure to network jitter, and elastic
   restart from the checkpoint manager when a node is evicted (restore is
   topology-independent — checkpoint/store.py re-shards on load).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class StragglerEvent:
    step: int
    seconds: float
    median: float
    ratio: float
    recommendation: str


@dataclass
class ShardStraggler:
    """One shard of a partitioned summarize that blew the deadline.

    Produced by :func:`flag_shard_stragglers` from the per-shard wall
    times the executor's shard spans measure; surfaced by
    ``explain(analyze=True)`` and counted in the ``dist.stragglers``
    metric.  Same ``k * median`` rule as the step-level monitor, applied
    across shards of one build instead of across steps of one shard.
    """

    shard: int
    seconds: float
    median: float
    ratio: float


def flag_shard_stragglers(seconds: List[float],
                          threshold: float = 2.0) -> List[ShardStraggler]:
    """Shards whose wall time exceeds ``threshold * median(seconds)``.

    With fewer than 3 shards a median is meaningless (any imbalance
    would flag one of two shards), so nothing is flagged.
    """
    if len(seconds) < 3:
        return []
    med = sorted(seconds)[len(seconds) // 2]
    if med <= 0.0:
        return []
    return [ShardStraggler(shard=i, seconds=dt, median=med, ratio=dt / med)
            for i, dt in enumerate(seconds) if dt > threshold * med]


@dataclass
class StragglerMonitor:
    """Deadline-based step-time monitor."""

    threshold: float = 2.0          # x median => straggler
    evict_after: int = 3            # consecutive stragglers => evict advice
    window: int = 50
    _times: List[float] = field(default_factory=list)
    _consecutive: int = 0
    events: List[StragglerEvent] = field(default_factory=list)
    _t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self, step: int) -> Optional[StragglerEvent]:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        med = sorted(self._times)[len(self._times) // 2]
        if len(self._times) >= 5 and dt > self.threshold * med:
            self._consecutive += 1
            rec = ("evict-and-restore" if self._consecutive >= self.evict_after
                   else "rebalance-data-ranges" if self._consecutive > 1
                   else "transient-ignore")
            ev = StragglerEvent(step, dt, med, dt / med, rec)
            self.events.append(ev)
            return ev
        self._consecutive = 0
        return None
