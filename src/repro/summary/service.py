"""JoinService — the query-answering front-end over summaries.

One object owns a catalog, a :class:`SummaryCache`, a plan cache, and the
decision of when to actually run the Graphical Join:

    svc = JoinService(catalog, byte_budget=64 << 20, spill_dir=".../spill")
    n    = svc.count(query)                              # O(runs) after 1st
    tbl  = svc.group_by(query, "A", total=("sum", "D"))
    r    = svc.frame(query)            # SummaryFrame + provenance/timings
    plan = svc.compile(query)          # pre-compiled PhysicalPlan (serve path)
    r2   = svc.frame(query, plan=plan) # keyed on plan identity

Summaries are keyed on (canonical query fingerprint × table content
versions × physical-plan signature): the same query executed under a
different plan is a different summary (the GFJS column order depends on the
elimination order).  `compile` runs the cost-based planner once and caches
the PhysicalPlan per (query, table versions); `frame` reuses it so warm
requests never re-plan.

Cache hits skip ``build_model`` / ``build_generator`` / ``summarize``
entirely — a request served from cache carries no build-phase timings,
which is the service-level observable the tests assert on.

The service is safe to call from multiple threads: the summary cache locks
internally and the plan cache is guarded here.  Two threads racing on the
same cold query may both compute it (last put wins) — duplicate work, never
a wrong answer.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.api import GraphicalJoin
from repro.plan.ir import PhysicalPlan
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog
from repro.summary.algebra import AggSpec, Predicate, SummaryFrame
from repro.summary.cache import SummaryCache, cache_key


@dataclass
class ServiceReply:
    """A frame plus how it was produced (the service's provenance record)."""

    frame: SummaryFrame
    source: str                      # "memory" | "disk" | "computed"
    key: str
    timings: Dict[str, float] = field(default_factory=dict)
    plan: Optional[PhysicalPlan] = None

    @property
    def cache_hit(self) -> bool:
        return self.source != "computed"


class JoinService:
    """Answer join queries from cached summaries; compute-and-reuse on miss."""

    def __init__(self, catalog: Catalog, *,
                 cache: Optional[SummaryCache] = None,
                 byte_budget: int = 256 << 20,
                 spill_dir: Optional[str] = None,
                 ttl_seconds: Optional[float] = None,
                 planner: str = "cost",
                 max_plans: int = 256) -> None:
        self.catalog = catalog
        self.cache = cache if cache is not None else SummaryCache(
            byte_budget=byte_budget, spill_dir=spill_dir,
            ttl_seconds=ttl_seconds)
        self.planner = planner
        self.max_plans = int(max_plans)
        self.requests = 0
        self._lock = threading.RLock()
        # (query fingerprint, table versions) -> (plan, base-table names).
        # Keys embed content versions, so every table refresh mints a new
        # key — LRU-bounded at max_plans so version churn can't grow it
        # without bound (plans are tiny; re-planning a evicted one is ms).
        self._plans: "OrderedDict[Tuple[str, Tuple[str, ...]], " \
                     "Tuple[PhysicalPlan, frozenset]]" = OrderedDict()

    # -- planning -----------------------------------------------------------
    def _plan_key(self, query: JoinQuery) -> Tuple[str, Tuple[str, ...]]:
        names = sorted({qt.table for qt in query.tables})
        return (query.fingerprint(),
                tuple(self.catalog[n].version() for n in names))

    def _remember_plan(self, pkey, plan: PhysicalPlan,
                       tables: frozenset) -> None:
        """Insert into the LRU-bounded plan cache (lock held by caller)."""
        self._plans.setdefault(pkey, (plan, tables))
        self._plans.move_to_end(pkey)
        while len(self._plans) > self.max_plans:
            self._plans.popitem(last=False)

    def compile(self, query: JoinQuery) -> PhysicalPlan:
        """The PhysicalPlan for ``query`` on the current table versions.

        Compiled once per (query shape, table versions) and cached; the
        serve path calls this up front and hands the plan to `frame`.
        """
        pkey = self._plan_key(query)
        with self._lock:
            hit = self._plans.get(pkey)
            if hit is not None:
                self._plans.move_to_end(pkey)
                return hit[0]
        gj = GraphicalJoin(self.catalog, query, planner=self.planner)
        plan = gj.plan()
        with self._lock:
            self._remember_plan(
                pkey, plan, frozenset(qt.table for qt in query.tables))
        return plan

    # -- summary acquisition ----------------------------------------------
    def frame(self, query: JoinQuery,
              plan: Optional[PhysicalPlan] = None) -> ServiceReply:
        """The summary for ``query``: cache first, GraphicalJoin on miss."""
        with self._lock:
            self.requests += 1
        gj: Optional[GraphicalJoin] = None
        if plan is None:
            pkey = self._plan_key(query)
            with self._lock:
                hit = self._plans.get(pkey)
                if hit is not None:
                    self._plans.move_to_end(pkey)
            if hit is not None:
                plan = hit[0]
            else:
                # plan inline and keep the GraphicalJoin: a cache miss below
                # reuses its encoding/potentials instead of re-planning
                gj = GraphicalJoin(self.catalog, query, planner=self.planner)
                plan = gj.plan()
                with self._lock:
                    self._remember_plan(
                        pkey, plan,
                        frozenset(qt.table for qt in query.tables))
        key = cache_key(query, self.catalog, plan=plan)
        t0 = time.perf_counter()
        cached, source = self.cache.get_with_source(key)
        lookup = time.perf_counter() - t0
        if cached is not None:
            return ServiceReply(SummaryFrame.of(cached), source, key,
                                {"cache_lookup": lookup}, plan)
        if gj is None:
            gj = GraphicalJoin(self.catalog, query, plan=plan)
        gfjs = gj.run()
        self.cache.put(key, gfjs, tables={qt.table for qt in query.tables})
        timings = dict(gj.timings)
        timings["cache_lookup"] = lookup
        return ServiceReply(SummaryFrame.of(gfjs), "computed", key,
                            timings, plan)

    def invalidate(self, table: str) -> int:
        """Force-drop cached summaries and compiled plans built on ``table``."""
        removed = self.cache.invalidate(table)
        with self._lock:
            self._plans = OrderedDict(
                (k, v) for k, v in self._plans.items() if table not in v[1])
        return removed

    # -- one-shot aggregate API -------------------------------------------
    def count(self, query: JoinQuery,
              where: Optional[Mapping[str, Predicate]] = None) -> int:
        return self._filtered(query, where).frame.count()

    def sum(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.sum(var)

    def mean(self, query: JoinQuery, var: str,
             where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.mean(var)

    def min(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.min(var)

    def max(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.max(var)

    def distinct(self, query: JoinQuery, var: str) -> np.ndarray:
        return self.frame(query).frame.distinct(var)

    def group_by(self, query: JoinQuery, keys: Union[str, Sequence[str]],
                 where: Optional[Mapping[str, Predicate]] = None,
                 **aggs: AggSpec) -> Dict[str, np.ndarray]:
        return self._filtered(query, where).frame.group_by(keys, **aggs)

    def _filtered(self, query: JoinQuery,
                  where: Optional[Mapping[str, Predicate]]) -> ServiceReply:
        reply = self.frame(query)
        if where:
            reply.frame = reply.frame.filter(where)
        return reply

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, int]:
        out = self.cache.stats.as_dict()
        with self._lock:
            out["requests"] = self.requests
            out["compiled_plans"] = len(self._plans)
        out["resident_bytes"] = self.cache.resident_bytes
        out["resident_entries"] = len(self.cache)
        return out
