"""JoinService — the query-answering front-end over summaries.

One object owns a catalog, a :class:`SummaryCache`, and the decision of
when to actually run the Graphical Join:

    svc = JoinService(catalog, byte_budget=64 << 20, spill_dir=".../spill")
    n    = svc.count(query)                              # O(runs) after 1st
    tbl  = svc.group_by(query, "A", total=("sum", "D"))
    r    = svc.frame(query)            # SummaryFrame + provenance/timings

Cache hits skip ``build_model`` / ``build_generator`` / ``summarize``
entirely — a request served from cache carries no build-phase timings,
which is the service-level observable the tests assert on.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core.api import GraphicalJoin
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog
from repro.summary.algebra import AggSpec, Predicate, SummaryFrame
from repro.summary.cache import SummaryCache, cache_key


@dataclass
class ServiceReply:
    """A frame plus how it was produced (the service's provenance record)."""

    frame: SummaryFrame
    source: str                      # "memory" | "disk" | "computed"
    key: str
    timings: Dict[str, float] = field(default_factory=dict)

    @property
    def cache_hit(self) -> bool:
        return self.source != "computed"


class JoinService:
    """Answer join queries from cached summaries; compute-and-reuse on miss."""

    def __init__(self, catalog: Catalog, *,
                 cache: Optional[SummaryCache] = None,
                 byte_budget: int = 256 << 20,
                 spill_dir: Optional[str] = None) -> None:
        self.catalog = catalog
        self.cache = cache if cache is not None else SummaryCache(
            byte_budget=byte_budget, spill_dir=spill_dir)
        self.requests = 0

    # -- summary acquisition ----------------------------------------------
    def frame(self, query: JoinQuery) -> ServiceReply:
        """The summary for ``query``: cache first, GraphicalJoin on miss."""
        self.requests += 1
        key = cache_key(query, self.catalog)
        disk_before = self.cache.stats.disk_hits
        t0 = time.perf_counter()
        cached = self.cache.get(key)
        lookup = time.perf_counter() - t0
        if cached is not None:
            source = "disk" if self.cache.stats.disk_hits > disk_before \
                else "memory"
            return ServiceReply(SummaryFrame.of(cached), source, key,
                                {"cache_lookup": lookup})
        gj = GraphicalJoin(self.catalog, query)
        gfjs = gj.run()
        self.cache.put(key, gfjs)
        timings = dict(gj.timings)
        timings["cache_lookup"] = lookup
        return ServiceReply(SummaryFrame.of(gfjs), "computed", key, timings)

    # -- one-shot aggregate API -------------------------------------------
    def count(self, query: JoinQuery,
              where: Optional[Mapping[str, Predicate]] = None) -> int:
        return self._filtered(query, where).frame.count()

    def sum(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.sum(var)

    def mean(self, query: JoinQuery, var: str,
             where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.mean(var)

    def min(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.min(var)

    def max(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.max(var)

    def distinct(self, query: JoinQuery, var: str) -> np.ndarray:
        return self.frame(query).frame.distinct(var)

    def group_by(self, query: JoinQuery, keys: Union[str, Sequence[str]],
                 where: Optional[Mapping[str, Predicate]] = None,
                 **aggs: AggSpec) -> Dict[str, np.ndarray]:
        return self._filtered(query, where).frame.group_by(keys, **aggs)

    def _filtered(self, query: JoinQuery,
                  where: Optional[Mapping[str, Predicate]]) -> ServiceReply:
        reply = self.frame(query)
        if where:
            reply.frame = reply.frame.filter(where)
        return reply

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, int]:
        out = self.cache.stats.as_dict()
        out["requests"] = self.requests
        out["resident_bytes"] = self.cache.resident_bytes
        out["resident_entries"] = len(self.cache)
        return out
