"""JoinService — the query-answering front-end over summaries.

One object owns a catalog, a :class:`SummaryCache`, a plan cache, and the
decision of when to actually run the Graphical Join:

    svc = JoinService(catalog, byte_budget=64 << 20, spill_dir=".../spill")
    n    = svc.count(query)                              # O(runs) after 1st
    tbl  = svc.group_by(query, "A", total=("sum", "D"))
    r    = svc.frame(query)            # SummaryFrame + provenance/timings
    plan = svc.compile(query)          # pre-compiled PhysicalPlan (serve path)
    r2   = svc.frame(query, plan=plan) # keyed on plan identity
    svc.append("user_friends", rows)   # live growth; summaries refresh
    r3   = svc.frame(query)            # ... lazily: source == "refreshed"

Summaries are keyed on (canonical query fingerprint × table content
versions × physical-plan signature): the same query executed under a
different plan is a different summary (the GFJS column order depends on the
elimination order).  `compile` runs the cost-based planner once and caches
the PhysicalPlan per (query, table versions); `frame` reuses it so warm
requests never re-plan.

Cache hits skip ``build_model`` / ``build_generator`` / ``summarize``
entirely — a request served from cache carries no build-phase timings,
which is the service-level observable the tests assert on.

Below whole-summary reuse sits *message* reuse (DESIGN.md §20): every
build this service runs shares one :class:`MessageCache`, so a cold build
whose elimination subtrees match an earlier query's — same occurrence
structure over the same table contents — injects the cached messages and
skips those product+marginalization steps outright.  The message cache is
byte-pooled with the summary cache and spills under ``<spill_dir>/msg``;
``message_reuse=False`` disables it.  Cost-model drift corrections are
persisted to a ``calibration.json`` sidecar in ``spill_dir`` and seed the
planner in later processes (``calib(loaded)=`` in ``explain()``).

Base-table appends are first-class: `append` upgrades the catalog and
queues a :class:`~repro.relational.table.TableDelta`; the next `frame()`
for an affected query chains the pending deltas through the incremental
refresher (re-encode the blocks, re-run only dirty elimination steps,
splice — DESIGN.md §12) and upgrades the cache entry in place via
`SummaryCache.refresh`.  A broken delta chain, a mixed-dtype block, or a
dropped state all fall back to the cold compute path — refresh is an
optimization, never a correctness dependency.

The service is safe to call from multiple threads: the summary cache locks
internally, the plan cache is guarded here, and append *staging* (the
O(table) column copy) is serialized per table.  Two threads racing on the
same cold query may both compute it (last put wins) — duplicate work, never
a wrong answer.  Refresh races the same way: both threads derive the same
new-consistent summary, and `SummaryCache.refresh` commits atomically.
Serving tiers that cannot afford the duplicate work put
`repro.serve.server.JoinServer` in front: it collapses concurrent
identical-key misses onto one build (waiters' replies carry
``source="collapsed"``), batches per-key probes, and admission-controls
cold builds by the plan's cost estimate (DESIGN.md §18).
"""

from __future__ import annotations

import json
import math
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.api import GraphicalJoin
from repro.obs.metrics import REGISTRY
from repro.obs.trace import span as _span
from repro.plan.ir import PhysicalPlan
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog, TableDelta
from repro.summary.algebra import AggSpec, Predicate, SummaryFrame
from repro.summary.cache import SummaryCache, cache_key, cache_key_for_versions
from repro.summary.msgcache import MessageCache
from repro.summary.incremental import (DeltaError, IncrementalState,
                                       capture_state, refresh_state)


@dataclass
class ServiceReply:
    """A frame plus how it was produced (the service's provenance record)."""

    frame: SummaryFrame
    source: str                # "memory" | "disk" | "refreshed" | "computed"
                               # (+ "collapsed": a JoinServer waiter that
                               #  shared another request's in-flight build)
    key: str
    timings: Dict[str, float] = field(default_factory=dict)
    plan: Optional[PhysicalPlan] = None

    @property
    def cache_hit(self) -> bool:
        return self.source in ("memory", "disk")

    def explain(self) -> str:
        """Provenance report: where the frame came from, what it cost,
        and (when available) the plan it was built under."""
        lines = [
            f"ServiceReply  source={self.source}  key={self.key[:16]}…",
            "  timings:",
        ]
        for k, v in self.timings.items():
            lines.append(f"    {k:<16s} {v * 1e3:10.2f}ms")
        if self.plan is not None:
            lines.append(self.plan.explain())
        return "\n".join(lines)


class JoinService:
    """Answer join queries from cached summaries; compute-and-reuse on miss."""

    def __init__(self, catalog: Catalog, *,
                 cache: Optional[SummaryCache] = None,
                 byte_budget: int = 256 << 20,
                 spill_dir: Optional[str] = None,
                 ttl_seconds: Optional[float] = None,
                 planner: str = "cost",
                 max_plans: int = 256,
                 incremental: bool = True,
                 max_states: int = 16,
                 max_state_bytes: int = 512 << 20,
                 max_pending_deltas: int = 64,
                 partitions: int = 1,
                 partition_fold: Optional[int] = None,
                 shard_executor: Optional[str] = None,
                 message_reuse: bool = True,
                 message_cache: Optional[MessageCache] = None) -> None:
        self.catalog = catalog
        self.cache = cache if cache is not None else SummaryCache(
            byte_budget=byte_budget, spill_dir=spill_dir,
            ttl_seconds=ttl_seconds)
        # elimination-message reuse (DESIGN.md §20): one MessageCache shared
        # across every build this service runs, byte-pooled with the summary
        # cache (messages yield budget to summaries, never the reverse) and
        # spilling under <spill_dir>/msg.  message_reuse=False turns the
        # whole mechanism off; a caller-supplied message_cache wins.
        if message_cache is not None:
            self.message_cache: Optional[MessageCache] = message_cache
        elif message_reuse:
            self.message_cache = MessageCache(
                spill_dir=os.path.join(spill_dir, "msg") if spill_dir
                else None,
                summary_cache=self.cache)
        else:
            self.message_cache = None
        # CostModel calibration sidecar (JSON next to the spill dir): drift
        # corrections measured by past builds persist across processes and
        # seed the planner until this session measures its own
        self.calibration_path = (
            os.path.join(spill_dir, "calibration.json") if spill_dir
            else None)
        self._corrections: Optional[Dict[str, float]] = None
        self._corrections_loaded = False
        self.planner = planner
        # > 1: plans pin hash-partitioned execution; summaries are
        # ShardedGFJS, cache keys fold the shard scheme in through the plan
        # signature, and appends fall back to rebuild (no splice-refresh of
        # sharded summaries) — the aggregate API is shape-oblivious
        self.partitions = int(partitions)
        # partitioned-execution knobs, pinned into every compiled plan:
        # shard_executor="process" routes shard builds to the
        # repro/dist/actions.py spawn pool; partition_fold over-partitions
        # for skew smoothing (None = planner auto-choice from stats)
        self.partition_fold = partition_fold
        self.shard_executor = shard_executor
        self.max_plans = int(max_plans)
        self.incremental = bool(incremental)
        self.max_states = int(max_states)
        self.max_state_bytes = int(max_state_bytes)
        self.max_pending_deltas = int(max_pending_deltas)
        self.requests = 0
        self.refreshes = 0
        self._lock = threading.RLock()
        # (query fingerprint, table versions) -> (plan, base-table names).
        # Keys embed content versions, so every table refresh mints a new
        # key — LRU-bounded at max_plans so version churn can't grow it
        # without bound (plans are tiny; re-planning a evicted one is ms).
        self._plans: "OrderedDict[Tuple[str, Tuple[str, ...]], " \
                     "Tuple[PhysicalPlan, frozenset]]" = OrderedDict()
        # incremental-maintenance side state, all guarded by self._lock:
        # plan-keyed fingerprint -> IncrementalState (LRU-bounded), and the
        # per-table append log frame() chains through to catch a state up
        self._states: "OrderedDict[str, IncrementalState]" = OrderedDict()
        self._pending: Dict[str, list] = {}
        # per-table append staging locks (guarded by self._lock): k
        # concurrent appenders to one hot table serialize the O(table)
        # column copy — k stagings total, not the O(k²·table) of every
        # loser re-staging against each winner's new base
        self._append_locks: Dict[str, threading.Lock] = {}

    # -- planning -----------------------------------------------------------
    def _plan_key(self, query: JoinQuery) -> Tuple[str, Tuple[str, ...]]:
        # literal=True: plans embed the query's own variable names in
        # ``order`` — serving one to an alias-renamed twin would crash the
        # executor.  (Summary cache keys stay canonical: GFJS columns are
        # the output variables, which keep their literal labels.)
        names = sorted({qt.table for qt in query.tables})
        return (query.fingerprint(literal=True),
                tuple(self.catalog[n].version() for n in names))

    def _load_corrections(self) -> Optional[Dict[str, float]]:
        """Calibration corrections from the sidecar (lazy, once)."""
        with self._lock:
            if not self._corrections_loaded:
                self._corrections_loaded = True
                p = self.calibration_path
                if p is not None and os.path.exists(p):
                    try:
                        with open(p) as f:
                            raw = json.load(f)
                        self._corrections = {
                            str(k): float(v) for k, v in raw.items()
                            if math.isfinite(float(v)) and float(v) > 0}
                    except (ValueError, TypeError, OSError):
                        self._corrections = None   # corrupt sidecar: ignore
            return dict(self._corrections) if self._corrections else None

    def _persist_calibration(self, measured: Dict[str, float]) -> None:
        """Blend a build's measured drift into the sidecar (geometric mean
        with the stored factor — one outlier build can't whipsaw the
        planner) and write it back atomically."""
        if not measured:
            return
        with self._lock:
            cur = dict(self._corrections or {})
            for op, f in measured.items():
                f = float(f)
                if not (math.isfinite(f) and f > 0):
                    continue
                prev = cur.get(op)
                cur[op] = f if prev is None else math.sqrt(prev * f)
            self._corrections = cur
            self._corrections_loaded = True
            p = self.calibration_path
            payload = dict(cur)
        if p is None:
            return
        try:
            tmp = p + ".tmp"
            os.makedirs(os.path.dirname(p), exist_ok=True)
            with open(tmp, "w") as f:
                json.dump(payload, f, sort_keys=True)
            os.replace(tmp, p)
        except OSError:
            pass    # persistence is best-effort, never a failure path

    def _remember_plan(self, pkey, plan: PhysicalPlan,
                       tables: frozenset) -> None:
        """Insert into the LRU-bounded plan cache (lock held by caller)."""
        self._plans.setdefault(pkey, (plan, tables))
        self._plans.move_to_end(pkey)
        while len(self._plans) > self.max_plans:
            self._plans.popitem(last=False)

    def compile(self, query: JoinQuery) -> PhysicalPlan:
        """The PhysicalPlan for ``query`` on the current table versions.

        Compiled once per (query shape, table versions) and cached; the
        serve path calls this up front and hands the plan to `frame`.
        """
        pkey = self._plan_key(query)
        with self._lock:
            hit = self._plans.get(pkey)
            if hit is not None:
                self._plans.move_to_end(pkey)
                return hit[0]
        gj = GraphicalJoin(self.catalog, query, planner=self.planner,
                           partitions=self.partitions,
                           partition_fold=self.partition_fold,
                           shard_executor=self.shard_executor,
                           message_cache=self.message_cache,
                           corrections=self._load_corrections())
        plan = gj.plan()
        with self._lock:
            self._remember_plan(
                pkey, plan, frozenset(qt.table for qt in query.tables))
        return plan

    # -- summary acquisition ----------------------------------------------
    def frame(self, query: JoinQuery,
              plan: Optional[PhysicalPlan] = None) -> ServiceReply:
        """The summary for ``query``: cache first, GraphicalJoin on miss.

        Every reply — cache hits included — carries a ``"service"``
        timing (end-to-end request latency) and lands in the
        ``service.latency_seconds.<source>`` histogram, so the serving
        path is measurable even when no join ever runs.
        """
        with _span("service:frame", cat="service", query=query.name) as sp:
            t_req = time.perf_counter()
            reply = self._frame_inner(query, plan)
            dt = time.perf_counter() - t_req
            reply.timings["service"] = dt
            sp.set(source=reply.source)
            REGISTRY.counter("service.requests").inc()
            REGISTRY.counter(f"service.source.{reply.source}").inc()
            REGISTRY.histogram(
                f"service.latency_seconds.{reply.source}",
                unit="s").observe(dt)
            return reply

    def _frame_inner(self, query: JoinQuery,
                     plan: Optional[PhysicalPlan] = None) -> ServiceReply:
        with self._lock:
            self.requests += 1
        gj: Optional[GraphicalJoin] = None
        if plan is None:
            pkey = self._plan_key(query)
            with self._lock:
                hit = self._plans.get(pkey)
                if hit is not None:
                    self._plans.move_to_end(pkey)
            if hit is not None:
                plan = hit[0]
            else:
                # plan inline and keep the GraphicalJoin: a cache miss below
                # reuses its encoding/potentials instead of re-planning
                # no trace under partitioned plans: refresh is rebuild there
                gj = GraphicalJoin(self.catalog, query, planner=self.planner,
                                   record_trace=self.incremental
                                   and self.partitions == 1,
                                   partitions=self.partitions,
                                   partition_fold=self.partition_fold,
                                   shard_executor=self.shard_executor,
                                   message_cache=self.message_cache,
                                   corrections=self._load_corrections())
                plan = gj.plan()
                with self._lock:
                    self._remember_plan(
                        pkey, plan,
                        frozenset(qt.table for qt in query.tables))
        versions = {qt.table: self.catalog[qt.table].version()
                    for qt in query.tables}
        key = cache_key_for_versions(query, versions, plan=plan)
        t0 = time.perf_counter()
        cached, source = self.cache.get_with_source(key)
        lookup = time.perf_counter() - t0
        if cached is not None:
            return ServiceReply(SummaryFrame.of(cached), source, key,
                                {"cache_lookup": lookup}, plan)
        # a miss after an append: catch the retained state up through the
        # delta chain instead of recomputing from scratch
        refreshed = self._try_refresh(query, plan, lookup)
        if refreshed is not None:
            return refreshed
        if gj is None:
            gj = GraphicalJoin(self.catalog, query, plan=plan,
                               record_trace=self.incremental
                               and plan.partitions == 1
                               and not plan.bags,
                               message_cache=self.message_cache,
                               corrections=self._load_corrections())
        gfjs = gj.run()
        # key on what the executor actually encoded: an append racing this
        # compute may have advanced the catalog past the entry snapshot,
        # and mislabeling the summary would make a later delta refresh
        # double-apply the append
        built = getattr(gj._executor, "source_versions", None) or versions
        if built != versions:
            key = cache_key_for_versions(query, built, plan=plan)
        self.cache.put(key, gfjs, tables={qt.table for qt in query.tables})
        self._persist_calibration(gj._executor.calibration())
        if self.incremental:
            self._remember_state(query, plan, gj, gfjs, built, key)
        timings = dict(gj.timings)
        timings["cache_lookup"] = lookup
        return ServiceReply(SummaryFrame.of(gfjs), "computed", key,
                            timings, plan)

    # -- incremental maintenance ------------------------------------------
    def append(self, table: str, rows) -> TableDelta:
        """Append rows to a base table; summaries refresh lazily.

        The catalog is upgraded immediately (new content version), the
        delta is queued, and compiled plans are carried forward to the new
        version — a refreshed summary must run under the plan it was built
        with, and re-planning on every append would fork the cache key.
        Nothing is recomputed here: the next `frame()` for an affected
        query chains the pending deltas through the incremental refresher
        (repro/summary/incremental.py) and upgrades the cache entry in
        place; queries never asked again never pay for the append.

        The O(table) column copy of the grown table is staged *outside*
        the service lock (a slow copy must not stall readers) but
        *serialized per table*: concurrent appenders to one hot table
        queue on the table's staging lock, so k appends cost k copies —
        the unbounded lost-race re-staging this path used to do was
        O(k²·table).  The retry loop survives only as a guard against
        out-of-band catalog mutation (a table replaced around `append`);
        the delta chain stays linear either way.
        """
        with self._lock:
            tlock = self._append_locks.setdefault(table, threading.Lock())
        with tlock:
            return self._append_staged(table, rows)

    def _append_staged(self, table: str, rows) -> TableDelta:
        """Stage + install one append (table staging lock held)."""
        while True:
            base = self.catalog[table]
            delta = base.append(rows)          # O(table) copy, unlocked
            with self._lock:
                if self.catalog.tables.get(table) is not base:
                    # only an out-of-band catalog.add can get here now:
                    # same-table appends serialize on the staging lock
                    REGISTRY.counter("service.append_restages").inc()
                    continue                   # lost the race: re-stage
                self.catalog.add(delta.new_table)
                log = self._pending.setdefault(table, [])
                # slim(): the log must not pin a full table copy per append
                log.append(delta.slim())
                del log[:max(0, len(log) - self.max_pending_deltas)]
                for pkey, (plan, tabs) in list(self._plans.items()):
                    if table not in tabs:
                        continue
                    idx = sorted(tabs).index(table)
                    if pkey[1][idx] != delta.base_version:
                        continue
                    versions = list(pkey[1])
                    versions[idx] = delta.new_version
                    self._plans.pop(pkey)
                    self._remember_plan((pkey[0], tuple(versions)), plan, tabs)
            # message fingerprints embed content versions, so the grown
            # table's old messages can never be *served* stale — but they
            # can never hit again either; reclaim their bytes eagerly
            if self.message_cache is not None:
                self.message_cache.invalidate(table)
            return delta

    def _state_key(self, query: JoinQuery, plan: PhysicalPlan) -> str:
        # literal: an IncrementalState replays this query's own trace —
        # sharing it across alias-renamed twins would splice wrong names
        return query.fingerprint(plan=plan, literal=True)

    def _remember_state(self, query: JoinQuery, plan: PhysicalPlan,
                        gj: GraphicalJoin, gfjs, versions, key: str) -> None:
        try:
            state = capture_state(gj, gfjs, versions=versions)
        except ValueError:      # ran without a trace (e.g. incremental off)
            return
        state.cache_key = key
        with self._lock:
            skey = self._state_key(query, plan)
            self._states[skey] = state
            self._states.move_to_end(skey)
            self._shrink_states()

    def _shrink_states(self) -> None:
        """LRU-evict retained states past the count AND byte bounds (lock
        held).  A state pins the elimination trace, a second GFJS, and the
        expansion cache — entry counting alone would let a few giant
        summaries dwarf the summary cache's own byte budget."""
        while len(self._states) > self.max_states or (
                len(self._states) > 1
                and sum(s.nbytes() for s in self._states.values())
                > self.max_state_bytes):
            self._states.popitem(last=False)

    def _chain_deltas(self, state: IncrementalState):
        """Pending deltas that carry ``state`` to the current catalog.

        None means the chain is broken (a table changed outside `append`,
        or the log was trimmed past the state's version) — rebuild.
        Caller holds the lock.
        """
        deltas = []
        for t in sorted({qt.table for qt in state.query.tables}):
            have = state.table_versions[t]
            want = self.catalog[t].version()
            if have == want:
                continue
            for d in self._pending.get(t, []):
                if have == want:
                    break
                if d.base_version == have:
                    deltas.append(d)
                    have = d.new_version
            if have != want:
                return None
        return deltas

    def can_refresh(self, query: JoinQuery, plan: PhysicalPlan) -> bool:
        """True if a cache miss for (query, plan) would be served by a
        delta refresh of a retained state rather than a cold GJ build.

        Advisory — the answer can go stale the moment the lock drops —
        but it is the admission gate ``repro.serve.server.JoinServer``
        uses to price only genuinely cold builds: a refreshable miss
        costs O(delta), not O(full build), and must not be rejected or
        queued by a cost ceiling sized for the latter.
        """
        if not self.incremental:
            return False
        with self._lock:
            state = self._states.get(self._state_key(query, plan))
            return (state is not None
                    and self._chain_deltas(state) is not None)

    def _try_refresh(self, query: JoinQuery, plan: PhysicalPlan,
                     lookup: float) -> Optional[ServiceReply]:
        """Serve a cache miss by delta-refreshing a retained state."""
        if not self.incremental:
            return None
        with self._lock:
            state = self._states.get(self._state_key(query, plan))
            if state is None:
                return None
            deltas = self._chain_deltas(state)
        if not deltas:      # broken chain (None) or nothing to apply ([])
            return None
        t0 = time.perf_counter()
        try:
            new_state, report = refresh_state(state, deltas)
        except DeltaError:
            return None     # fall back to the cold compute path
        dt = time.perf_counter() - t0
        new_key = cache_key_for_versions(
            query, new_state.table_versions, plan=plan)
        new_state.cache_key = new_key
        old_key = state.cache_key or new_key
        with self._lock:
            # commit only if the state we refreshed from is still current:
            # a concurrent invalidate() dropped it precisely to declare its
            # history untrustworthy, and re-admitting the spliced summary
            # would resurrect that history under unchanged content versions
            skey = self._state_key(query, plan)
            if self._states.get(skey) is not state:
                return None
            # cache.refresh runs under the service lock by design: the
            # atomic pairing with the state check above is what closes the
            # invalidate() race.  Eviction spills triggered by this admit
            # are *deferred* — only the in-memory bookkeeping happens under
            # the lock; the disk writes run below, after release, so a slow
            # spill can't stall concurrent cache-hit readers.
            spills = self.cache.refresh(
                old_key, new_key, new_state.gfjs,
                tables={qt.table for qt in query.tables}, defer_spill=True)
            self.refreshes += 1
            self._states[skey] = new_state
            self._states.move_to_end(skey)
            self._shrink_states()
        self.cache.write_spills(spills)
        timings = {"cache_lookup": lookup, "refresh": dt}
        timings.update({f"refresh_{k}": v for k, v in report.items()
                        if k != "seconds"})
        return ServiceReply(SummaryFrame.of(new_state.gfjs), "refreshed",
                            new_key, timings, plan)

    def invalidate(self, table: str) -> int:
        """Force-drop cached summaries and compiled plans built on ``table``.

        Also drops retained incremental states and the table's pending
        delta log: invalidation declares the table's history untrustworthy,
        so nothing derived from it may be spliced forward.  State removal
        and cache invalidation happen under one service-lock hold, ordered
        before the cache sweep — an in-flight refresh either sees its state
        gone (and aborts) or commits first (and its entry is swept here).
        """
        with self._lock:
            self._plans = OrderedDict(
                (k, v) for k, v in self._plans.items() if table not in v[1])
            self._pending.pop(table, None)
            self._states = OrderedDict(
                (k, s) for k, s in self._states.items()
                if table not in s.table_versions)
            removed = self.cache.invalidate(table)
        if self.message_cache is not None:
            self.message_cache.invalidate(table)
        return removed

    # -- one-shot aggregate API -------------------------------------------
    def count(self, query: JoinQuery,
              where: Optional[Mapping[str, Predicate]] = None) -> int:
        return self._filtered(query, where).frame.count()

    def sum(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.sum(var)

    def mean(self, query: JoinQuery, var: str,
             where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.mean(var)

    def min(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.min(var)

    def max(self, query: JoinQuery, var: str,
            where: Optional[Mapping[str, Predicate]] = None):
        return self._filtered(query, where).frame.max(var)

    def distinct(self, query: JoinQuery, var: str) -> np.ndarray:
        return self.frame(query).frame.distinct(var)

    def group_by(self, query: JoinQuery, keys: Union[str, Sequence[str]],
                 where: Optional[Mapping[str, Predicate]] = None,
                 **aggs: AggSpec) -> Dict[str, np.ndarray]:
        return self._filtered(query, where).frame.group_by(keys, **aggs)

    def _filtered(self, query: JoinQuery,
                  where: Optional[Mapping[str, Predicate]]) -> ServiceReply:
        reply = self.frame(query)
        if where:
            reply.frame = reply.frame.filter(where)
        return reply

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, int]:
        out = self.cache.stats.as_dict()
        with self._lock:
            out["requests"] = self.requests
            out["compiled_plans"] = len(self._plans)
            out["refreshed_requests"] = self.refreshes
            out["retained_states"] = len(self._states)
            out["pending_deltas"] = sum(
                len(v) for v in self._pending.values())
        out["resident_bytes"] = self.cache.resident_bytes
        out["resident_entries"] = len(self.cache)
        if self.message_cache is not None:
            for k, v in self.message_cache.stats.as_dict().items():
                out[f"msgcache_{k}"] = v
            out["msgcache_resident_bytes"] = \
                self.message_cache.resident_bytes
            out["msgcache_entries"] = len(self.message_cache)
        return out
