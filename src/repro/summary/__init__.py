"""Summary-side query answering (DESIGN.md §9).

The GFJS "entails all statistics necessary to materialize the join result"
(paper Definition 1) — this package exploits that in the other direction:
COUNT / SUM / MIN / MAX / AVG / GROUP BY / DISTINCT and predicate filters
are answered directly from the RLE runs in O(num_runs), never paying the
O(|Q|) desummarization the paper's storage scenario budgets for.

* :mod:`repro.summary.algebra` — :class:`SummaryFrame`, the summary-side
  relational algebra;
* :mod:`repro.summary.cache` — :class:`SummaryCache`, the compute-and-reuse
  LRU store keyed by (query fingerprint, table versions);
* :mod:`repro.summary.service` — :class:`JoinService`, the front-end that
  consults the cache and runs :class:`repro.core.api.GraphicalJoin` on miss;
* :mod:`repro.summary.incremental` — delta refresh (DESIGN.md §12): on a
  base-table append, re-encode only the block, re-run only the dirty
  elimination steps, splice the result into the retained summary.
"""

from repro.summary.algebra import ShardedSummaryFrame, SummaryFrame
from repro.summary.cache import CacheStats, SummaryCache
from repro.summary.incremental import (DeltaError, IncrementalState,
                                       StaleDeltaError, capture_state,
                                       refresh_state)
from repro.summary.service import JoinService, ServiceReply

__all__ = ["SummaryFrame", "ShardedSummaryFrame", "SummaryCache",
           "CacheStats", "JoinService",
           "ServiceReply", "DeltaError", "StaleDeltaError",
           "IncrementalState", "capture_state", "refresh_state"]
