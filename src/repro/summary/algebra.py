"""GFJS relational algebra — aggregates and filters in O(num_runs).

Every operator here reads the RLE runs of the summary, never the |Q| rows
they encode.  The enabling facts (paper Definition 1 + DESIGN.md §9):

* a level's run lengths sum to |Q|, so COUNT is one reduction;
* consecutive levels *refine* each other (every parent boundary appears
  among child boundaries), so any run maps to its enclosing run at a
  shallower level with one ``searchsorted`` of start offsets — that is how
  GROUP BY keys and filter masks travel between levels;
* dictionary codes are assigned in sorted raw order, so MIN/MAX over codes
  equal MIN/MAX over values.

A :class:`SummaryFrame` pairs an (immutable) GFJS with per-level *effective*
run weights.  ``filter`` zeroes the weights of runs whose codes fail a
predicate and re-propagates down the level chain: children of a zeroed run
die with it, and every shallower level's weights are recomputed as the
segment-sum of its surviving deepest-level weights — so all levels keep
counting the same filtered multiset.  Weighted reductions route through
``repro.core.engine_jax.segment_weighted_sum`` (the Pallas ``mul_segsum``
path), which is the jit-backed hot loop of the whole subsystem.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.gfjs import GFJS
from repro.core.potentials import INT, _rank_rows, group_ranks

Predicate = Union[Callable[[np.ndarray], np.ndarray], int, float, str,
                  Sequence, set, frozenset]

# (op, variable) pairs; "count" needs no variable
AggSpec = Union[str, Tuple[str, str]]

_NUMERIC_KINDS = ("i", "u", "f")


def _run_values(gfjs: GFJS, var: str, codes: np.ndarray) -> np.ndarray:
    vals = gfjs.domains[var].decode(codes)
    if vals.dtype.kind not in _NUMERIC_KINDS:
        raise TypeError(f"variable {var!r} has non-numeric domain "
                        f"({vals.dtype}); only count/distinct apply")
    return vals


def _eval_predicate(pred: Predicate, values: np.ndarray) -> np.ndarray:
    if callable(pred):
        mask = np.asarray(pred(values), dtype=bool)
        if mask.shape != values.shape:
            raise ValueError("predicate must return one bool per run value")
        return mask
    if isinstance(pred, (list, tuple, set, frozenset)):
        return np.isin(values, np.asarray(sorted(pred)))
    return values == pred


@dataclass
class SummaryFrame:
    """A GFJS plus per-level effective run weights (filters applied)."""

    gfjs: GFJS
    weights: List[np.ndarray]  # one int64 array per level, same runs as gfjs

    # -- constructors ------------------------------------------------------
    @staticmethod
    def of(gfjs) -> "SummaryFrame":
        """Frame over a summary; a ShardedGFJS gets the shard-merging twin.

        Dispatching here keeps every caller (service, cache hits, serve
        provider, ``GraphicalJoin.aggregate``) oblivious to sharding.
        """
        from repro.core.gfjs import ShardedGFJS
        if isinstance(gfjs, ShardedGFJS):
            return ShardedSummaryFrame.of(gfjs)
        return SummaryFrame(gfjs, [lvl.freq.astype(INT) for lvl in gfjs.levels])

    # -- structure helpers -------------------------------------------------
    def level_of(self, var: str) -> int:
        for i, lvl in enumerate(self.gfjs.levels):
            if var in lvl.vars:
                return i
        raise KeyError(f"variable {var!r} is not in the summary "
                       f"(columns: {self.gfjs.column_order})")

    def _starts(self, level: int) -> np.ndarray:
        """Exclusive row-offset starts of a level's runs."""
        lvl = self.gfjs.levels[level]
        return self.gfjs.bounds(level) - lvl.freq

    def _ancestors(self, deep: int, shallow: int) -> np.ndarray:
        """Enclosing run index at ``shallow`` for every run of ``deep``.

        Levels refine, so each deep run's start offset falls inside exactly
        one shallow run: one binary search over the cached prefix bounds.
        """
        if deep == shallow:
            return np.arange(self.gfjs.levels[deep].num_runs, dtype=INT)
        return np.searchsorted(self.gfjs.bounds(shallow),
                               self._starts(deep), side="right").astype(INT)

    @property
    def _deepest(self) -> int:
        return len(self.gfjs.levels) - 1

    def _codes_at(self, var: str, level: int) -> np.ndarray:
        """``var``'s code per run of ``level`` (>= var's own level)."""
        own = self.level_of(var)
        codes = self.gfjs.levels[own].key_cols[var]
        if own == level:
            return codes
        return codes[self._ancestors(level, own)]

    def _abs_value_bound(self, var: str) -> Optional[float]:
        """O(1) upper bound on |raw value| of ``var``.

        Dictionary values are stored sorted, so the extremes are the
        endpoints — no scan.  None for empty or non-numeric domains.
        """
        vals = self.gfjs.domains[var].values
        if len(vals) == 0 or vals.dtype.kind not in _NUMERIC_KINDS:
            return None
        return float(max(abs(float(vals[0])), abs(float(vals[-1]))))

    # -- filtering ---------------------------------------------------------
    def filter(self, preds: Optional[Mapping[str, Predicate]] = None,
               **kw: Predicate) -> "SummaryFrame":
        """Predicate pushdown: zero failing runs, re-propagate weights.

        ``preds`` maps variable -> predicate (a callable over the run's raw
        values, a scalar for equality, or a list/set for membership).  Cost
        is O(runs log runs); the result is a new frame over the same GFJS.
        """
        merged: Dict[str, Predicate] = dict(preds or {})
        merged.update(kw)
        if not merged:
            return self
        deep = self._deepest
        nd = self.gfjs.levels[deep].num_runs
        keep = np.ones(nd, dtype=bool)
        for var, pred in merged.items():
            own = self.level_of(var)
            codes = self.gfjs.levels[own].key_cols[var]
            mask = _eval_predicate(pred, self.gfjs.domains[var].decode(codes))
            keep &= mask if own == deep else mask[self._ancestors(deep, own)]
        deep_w = np.where(keep, self.weights[deep], 0).astype(INT)
        return self._with_deep_weights(deep_w)

    def _with_deep_weights(self, deep_w: np.ndarray) -> "SummaryFrame":
        """Rebuild every level's weights from new deepest-level weights."""
        from repro.core.engine_jax import segment_weighted_sum
        deep = self._deepest
        ones = np.ones(len(deep_w), INT)
        new: List[np.ndarray] = [None] * (deep + 1)  # type: ignore[list-item]
        new[deep] = deep_w
        # deep_w only zeroes existing weights, so this frame's (cached)
        # count bounds every propagated segment sum — the O(1) kernel guard
        bound = float(self.count())
        for j in range(deep):
            anc = self._ancestors(deep, j)
            # anc is sorted ascending and dense over 0..runs_j-1
            new[j] = segment_weighted_sum(
                anc.astype(np.int32), deep_w, ones,
                self.gfjs.levels[j].num_runs, bound=bound)
        return SummaryFrame(self.gfjs, new)

    # -- scalar aggregates -------------------------------------------------
    def count(self) -> int:
        """|Q| under the current filters — one O(runs) reduction.

        Filter propagation keeps every level summing to the same filtered
        total, so the root level (fewest runs) is the cheapest to read.
        Cached per frame: it doubles as the O(1) exactness bound for every
        weighted reduction (each level sums to the same filtered count).
        """
        c = getattr(self, "_count", None)
        if c is None:
            c = int(self.weights[0].sum()) if self.gfjs.levels else 0
            self._count = c
        return c

    def sum(self, var: str):
        """SUM(var) over the (filtered) join multiset."""
        from repro.core.engine_jax import weighted_total
        lv = self.level_of(var)
        vals = _run_values(self.gfjs, var, self.gfjs.levels[lv].key_cols[var])
        vb = self._abs_value_bound(var)
        bound = None if vb is None else vb * self.count()
        out = weighted_total(vals, self.weights[lv], bound=bound)
        return float(out) if vals.dtype.kind == "f" else int(out)

    def mean(self, var: str) -> Optional[float]:
        c = self.count()
        return None if c == 0 else self.sum(var) / c

    def min(self, var: str):
        return self._extreme(var, np.min)

    def max(self, var: str):
        return self._extreme(var, np.max)

    def _extreme(self, var: str, reduce_fn):
        lv = self.level_of(var)
        codes = self.gfjs.levels[lv].key_cols[var]
        live = self.weights[lv] > 0
        if not live.any():
            return None
        # codes order == raw-value order (dictionary encode is sorted)
        code = reduce_fn(codes[live])
        return self.gfjs.domains[var].decode(np.asarray([code]))[0]

    def distinct(self, var: str) -> np.ndarray:
        """Sorted distinct raw values of ``var`` with surviving weight."""
        lv = self.level_of(var)
        codes = self.gfjs.levels[lv].key_cols[var]
        live = np.unique(codes[self.weights[lv] > 0])
        return self.gfjs.domains[var].decode(live)

    def count_distinct(self, var: str) -> int:
        lv = self.level_of(var)
        codes = self.gfjs.levels[lv].key_cols[var]
        return int(len(np.unique(codes[self.weights[lv] > 0])))

    # -- grouped aggregates ------------------------------------------------
    def group_by(self, keys: Union[str, Sequence[str]],
                 **aggs: AggSpec) -> Dict[str, np.ndarray]:
        """GROUP BY ``keys`` with named aggregates, all in O(runs log runs).

            frame.group_by("A", n="count", total=("sum", "D"))
            frame.group_by(["A", "B"], lo=("min", "D"), avg=("mean", "D"))

        Returns a dict of aligned arrays: one decoded column per key plus
        one per aggregate, rows sorted by key values.  Supported ops:
        count, sum, mean, min, max.
        """
        from repro.core import engine_jax
        segment_weighted_sum = engine_jax.segment_weighted_sum
        if isinstance(keys, str):
            keys = [keys]
        if not keys:
            raise ValueError("group_by needs at least one key variable")
        if not aggs:
            aggs = {"count": "count"}
        specs: Dict[str, Tuple[str, Optional[str]]] = {}
        for name, spec in aggs.items():
            if spec == "count":
                specs[name] = ("count", None)
            else:
                op, var = spec  # type: ignore[misc]
                if op not in ("sum", "mean", "min", "max", "count"):
                    raise ValueError(f"unknown aggregate op {op!r}")
                specs[name] = (op, var)

        involved = list(keys) + [v for _, v in specs.values() if v is not None]
        work = max(self.level_of(v) for v in involved)
        w = self.weights[work]
        live = w > 0

        key_codes = np.stack(
            [self._codes_at(k, work)[live] for k in keys], axis=1)
        w = w[live].astype(INT)
        nlive = key_codes.shape[0]
        empty: Dict[str, np.ndarray] = {}
        if nlive == 0:
            for k in keys:
                empty[k] = self.gfjs.domains[k].decode(np.zeros(0, INT))
            for name, (op, var) in specs.items():
                # dtype-match the non-empty result so callers can concatenate
                if op == "count":
                    empty[name] = np.zeros(0, INT)
                elif op == "mean":
                    empty[name] = np.zeros(0, np.float64)
                else:
                    assert var is not None
                    empty[name] = np.zeros(
                        0, self.gfjs.domains[var].values.dtype)
            return empty

        sizes = [self.gfjs.domains[k].size for k in keys]
        ranks, packed = _rank_rows(key_codes, sizes)
        if packed and nlive >= engine_jax.GROUP_DEVICE_MIN_RUNS \
                and engine_jax.group_device_enabled():
            # large run counts: packed-key sort on the accelerator
            # (DESIGN.md §14); host keeps only the O(n) boundary scan
            order, seg, starts, ngroups = engine_jax.group_runs_device(ranks)
        else:
            order, seg, starts, ngroups = group_ranks(ranks)
        w_s = w[order]
        sorted_codes = key_codes[order]

        out: Dict[str, np.ndarray] = {}
        for j, k in enumerate(keys):
            out[k] = self.gfjs.domains[k].decode(sorted_codes[starts, j])

        counts: Optional[np.ndarray] = None

        total_w = float(self.count())   # O(1)-guard bound: sum w_s <= count

        def group_counts() -> np.ndarray:
            nonlocal counts
            if counts is None:
                counts = segment_weighted_sum(
                    seg, np.ones(nlive, INT), w_s, ngroups, bound=total_w)
            return counts

        for name, (op, var) in specs.items():
            if op == "count":
                out[name] = group_counts().copy()
                continue
            assert var is not None
            vals = _run_values(self.gfjs, var,
                               self._codes_at(var, work)[live])[order]
            if op in ("sum", "mean"):
                vb = self._abs_value_bound(var)
                sums = segment_weighted_sum(
                    seg, vals, w_s, ngroups,
                    bound=None if vb is None else vb * total_w)
                if op == "sum":
                    out[name] = sums
                else:
                    out[name] = sums / group_counts()
            else:  # min / max — ufunc scatter over runs, O(runs)
                if op == "min":
                    acc = np.full(ngroups, np.inf)
                    np.minimum.at(acc, seg, vals)
                else:
                    acc = np.full(ngroups, -np.inf)
                    np.maximum.at(acc, seg, vals)
                if vals.dtype.kind in ("i", "u"):
                    acc = acc.astype(vals.dtype)
                out[name] = acc
        return out

    # -- interop -----------------------------------------------------------
    def to_gfjs(self) -> GFJS:
        """Materialize the filtered frame as a standalone GFJS.

        Zero-weight runs are dropped; run boundaries are rebuilt from the
        surviving weights.  The result desummarizes to exactly the filtered
        join result (used by tests to cross-check filters row-by-row).
        """
        from repro.core.gfjs import LevelSummary
        levels = []
        for lvl, w in zip(self.gfjs.levels, self.weights):
            live = w > 0
            levels.append(LevelSummary(
                lvl.vars,
                {v: lvl.key_cols[v][live] for v in lvl.vars},
                w[live].astype(INT)))
        return GFJS(levels, list(self.gfjs.column_order), self.count(),
                    self.gfjs.domains)


# internal per-shard column names for the group_by merge; NUL bytes cannot
# collide with user aggregate names (they pass through **kwargs unharmed)
_MERGE_SUM = "\x00sum:"
_MERGE_CNT = "\x00cnt"


@dataclass
class ShardedSummaryFrame:
    """Shard-aware twin of :class:`SummaryFrame` over a ``ShardedGFJS``.

    Holds one :class:`SummaryFrame` per hash shard and merges at the
    *aggregate* level — never by concatenating summaries:

    * ``count`` / ``sum`` / ``mean`` distribute (sums of shard partials;
      mean is merged-sum over merged-count);
    * ``min`` / ``max`` / ``distinct`` reduce over shard results;
    * ``filter`` pushes the predicate into every shard frame;
    * ``group_by`` computes per-shard grouped partials (means decomposed
      into sum + count) and merges groups by key — shard results are
      key-sorted, and the merge re-sorts on dictionary codes, so the
      output ordering matches the monolithic frame exactly.

    Integer aggregates merge to *exactly* the monolithic numbers; float
    SUM/MEAN may differ in the last ulp (shard partial sums reassociate
    the additions).
    """

    sharded: "object"               # repro.core.gfjs.ShardedGFJS
    frames: List[SummaryFrame]

    @staticmethod
    def of(sharded) -> "ShardedSummaryFrame":
        return ShardedSummaryFrame(
            sharded, [SummaryFrame.of(s) for s in sharded.shards])

    # the summary backing this frame, under the same attribute name
    # SummaryFrame uses (provenance-reading callers stay oblivious)
    @property
    def gfjs(self):
        return self.sharded

    def level_of(self, var: str) -> int:
        return self.frames[0].level_of(var)   # identical structure per shard

    # -- filtering ---------------------------------------------------------
    def filter(self, preds: Optional[Mapping[str, Predicate]] = None,
               **kw: Predicate) -> "ShardedSummaryFrame":
        return ShardedSummaryFrame(
            self.sharded, [f.filter(preds, **kw) for f in self.frames])

    # -- scalar aggregates -------------------------------------------------
    def count(self) -> int:
        c = getattr(self, "_count", None)
        if c is None:
            c = int(sum(f.count() for f in self.frames))
            self._count = c
        return c

    def sum(self, var: str):
        return sum(f.sum(var) for f in self.frames)

    def mean(self, var: str) -> Optional[float]:
        c = self.count()
        return None if c == 0 else self.sum(var) / c

    def min(self, var: str):
        vals = [v for v in (f.min(var) for f in self.frames) if v is not None]
        return min(vals) if vals else None

    def max(self, var: str):
        vals = [v for v in (f.max(var) for f in self.frames) if v is not None]
        return max(vals) if vals else None

    def distinct(self, var: str) -> np.ndarray:
        return np.unique(np.concatenate(
            [f.distinct(var) for f in self.frames]))

    def count_distinct(self, var: str) -> int:
        return int(len(self.distinct(var)))

    # -- grouped aggregates ------------------------------------------------
    def group_by(self, keys: Union[str, Sequence[str]],
                 **aggs: AggSpec) -> Dict[str, np.ndarray]:
        """GROUP BY with shard merge; same contract as the monolithic frame."""
        if isinstance(keys, str):
            keys = [keys]
        if not keys:
            raise ValueError("group_by needs at least one key variable")
        if not aggs:
            aggs = {"count": "count"}
        specs: Dict[str, Tuple[str, Optional[str]]] = {}
        for name, spec in aggs.items():
            if spec == "count":
                specs[name] = ("count", None)
            else:
                op, var = spec  # type: ignore[misc]
                if op not in ("sum", "mean", "min", "max", "count"):
                    raise ValueError(f"unknown aggregate op {op!r}")
                specs[name] = (op, var)

        # shard-level request: a mean cannot be merged, its sum and count
        # can — decompose, merge, divide
        shard_aggs: Dict[str, AggSpec] = {}
        need_cnt = any(op == "mean" for op, _ in specs.values())
        for name, (op, var) in specs.items():
            if op == "mean":
                shard_aggs[_MERGE_SUM + name] = ("sum", var)
            else:
                shard_aggs[name] = (op, var)
        if need_cnt:
            shard_aggs[_MERGE_CNT] = "count"
        tabs = [f.group_by(list(keys), **shard_aggs) for f in self.frames]

        def col(name: str) -> np.ndarray:
            return np.concatenate([t[name] for t in tabs])

        key_vals = {k: col(k) for k in keys}
        n = len(key_vals[keys[0]])
        out: Dict[str, np.ndarray] = {}
        if n == 0:
            out.update(key_vals)
            for name, (op, _) in specs.items():
                out[name] = (np.zeros(0, np.float64) if op == "mean"
                             else col(name))
            return out

        # group on re-encoded dictionary codes: code order == raw-value
        # order, so the merged ordering equals the monolithic frame's
        doms = self.sharded.domains
        codes = np.stack([doms[k].encode(key_vals[k]) for k in keys], axis=1)
        sizes = [doms[k].size for k in keys]
        ranks, _ = _rank_rows(codes, sizes)
        order, seg, starts, ngroups = group_ranks(ranks)
        for k in keys:
            out[k] = key_vals[k][order][starts]

        cnt: Optional[np.ndarray] = None
        if need_cnt:
            c = col(_MERGE_CNT)[order]
            cnt = np.zeros(ngroups, c.dtype)
            np.add.at(cnt, seg, c)
        for name, (op, _) in specs.items():
            if op == "mean":
                s = col(_MERGE_SUM + name)[order]
                acc = np.zeros(ngroups, s.dtype)
                np.add.at(acc, seg, s)
                out[name] = acc / cnt
            elif op in ("count", "sum"):
                c = col(name)[order]
                acc = np.zeros(ngroups, c.dtype)
                np.add.at(acc, seg, c)
                out[name] = acc
            else:  # min / max: reduce from a representative per group
                c = col(name)[order]
                acc = c[starts].copy()
                (np.minimum if op == "min" else np.maximum).at(acc, seg, c)
                out[name] = acc
        return out

    # -- interop -----------------------------------------------------------
    def to_gfjs(self):
        """Materialize the filtered frame as a standalone ShardedGFJS."""
        from repro.core.gfjs import ShardedGFJS
        shards = [f.to_gfjs() for f in self.frames]
        return ShardedGFJS(shards, list(self.sharded.column_order),
                           self.count(), self.sharded.domains,
                           self.sharded.partition_var, self.sharded.salt)
