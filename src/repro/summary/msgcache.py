"""Cross-query elimination-message cache.

The message (and psi) an elimination step emits is fully determined by the
step's *subtree fingerprint* (repro/plan/ir.py::step_fingerprints): the
source-potential closure hanging below its separator — occurrence structure
x per-table content versions x dictionary-domain content, the eliminated
variable, the separator sequence, and the psi-needed flag.  Real workloads
(JOB-style star/snowflake suites) are overlapping query sets that share
dimension subtrees; memoizing messages under that fingerprint turns every
shared subtree into work done once, fleet-wide:

* **version-aware by construction** — a `Table.append` changes the table's
  content version, which changes every fingerprint in the append's closure;
  stale messages are never *served*, only evicted (LRU) or explicitly
  dropped via `invalidate(table)`;
* **byte-budgeted** — LRU over resident entries; when constructed with a
  ``summary_cache``, the budget *pool* is shared with `SummaryCache`
  accounting (messages compete against resident summaries for the same
  bytes, summaries always win: only messages are evicted from here);
* **disk spill** — evictions optionally spill through the storage codec
  (repro/core/storage.py `_BlobWriter` container, magic ``GJM1``) so a
  re-probe pays a load, not a product;
* **single-flight per key** — concurrent builds needing the same message
  compute it exactly once: the first prober leads, the rest wait on the
  leader's latch and adopt the published entry (with a timeout fallback to
  computing locally, so a stuck leader can only delay, never wedge);
* entries store psi/message with the *producer's* variable names; the
  fingerprint pins the separator sequence positionally, so a consumer
  adopts them by positional rename (`adopt`) — arrays are shared, never
  copied, and treated as immutable by every downstream consumer.

Reuse is refused upstream for ``record_trace`` builds (incremental refresh
replays per-step wiring and must own its messages' provenance) and for
bagged (hybrid WCOJ) plans (bag potentials merge occurrences outside the
step wiring the fingerprint simulates) — see DESIGN.md §20.
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, replace
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.elimination import Psi
from repro.core.potentials import Factor
from repro.core.storage import _BlobWriter, _open_container, default_codec
from repro.obs.metrics import REGISTRY
from repro.obs.trace import span as _span

_MAGIC = b"GJM1"
_VERSION = 1


@dataclass
class CachedMessage:
    """One memoized elimination step: the message, and the psi when the
    eliminated variable is an output variable (fingerprint's psi flag)."""

    message: Factor
    psi: Optional[Psi]

    def nbytes(self) -> int:
        n = int(self.message.keys.nbytes + self.message.bucket.nbytes
                + self.message.fac.nbytes)
        if self.psi is not None:
            n += self.psi.nbytes()
        return n


@dataclass
class MsgCacheStats:
    hits: int = 0            # served from memory
    disk_hits: int = 0       # served from spill
    misses: int = 0
    puts: int = 0
    evictions: int = 0
    spills: int = 0
    waits: int = 0           # followers served by a leader's publish
    timeouts: int = 0        # followers that computed locally after waiting
    invalidations: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


class _Flight:
    """Single-flight latch for one message key."""

    __slots__ = ("event",)

    def __init__(self) -> None:
        self.event = threading.Event()


def _entry_to_bytes(entry: CachedMessage) -> bytes:
    """Serialize through the storage codec container (spill format)."""
    w = _BlobWriter(default_codec(), 3)
    msg = entry.message
    w.add("msg_keys", np.ascontiguousarray(msg.keys))
    w.add("msg_bucket", np.ascontiguousarray(msg.bucket))
    w.add("msg_fac", np.ascontiguousarray(msg.fac))
    manifest: Dict[str, object] = {
        "msg_vars": len(msg.vars),
        "msg_sizes": [int(s) for s in msg.sizes],
        "has_psi": entry.psi is not None,
    }
    if entry.psi is not None:
        p = entry.psi
        w.add("psi_parent_keys", np.ascontiguousarray(p.parent_keys))
        w.add("psi_start", np.ascontiguousarray(p.start))
        w.add("psi_count", np.ascontiguousarray(p.count))
        w.add("psi_child_codes", np.ascontiguousarray(p.child_codes))
        w.add("psi_bucket", np.ascontiguousarray(p.bucket))
        w.add("psi_fac", np.ascontiguousarray(p.fac))
        manifest["psi_parent_sizes"] = [int(s) for s in p.parent_sizes]
        manifest["psi_child_size"] = int(p.child_size)
    return w.finish(_MAGIC, _VERSION, manifest)


def _entry_from_bytes(data: bytes) -> CachedMessage:
    _, manifest, get = _open_container(data, _MAGIC, "message-cache entry")
    k = int(manifest["msg_vars"])
    # positional placeholder names; `adopt` renames to the consumer's vars
    mvars = tuple(f"_{i}" for i in range(k))
    msg = Factor(mvars, get("msg_keys"), get("msg_bucket"),
                 get("msg_fac"), tuple(manifest["msg_sizes"]))
    psi = None
    if manifest.get("has_psi"):
        ps = tuple(int(s) for s in manifest["psi_parent_sizes"])
        psi = Psi("_c", tuple(f"_{i}" for i in range(len(ps))),
                  get("psi_parent_keys"), get("psi_start"),
                  get("psi_count"), get("psi_child_codes"),
                  get("psi_bucket"), get("psi_fac"),
                  ps, int(manifest["psi_child_size"]))
    return CachedMessage(message=msg, psi=psi)


class MessageCache:
    """Thread-safe LRU store of elimination messages, keyed by subtree
    fingerprint, with byte budget, optional disk spill, and single-flight.

    ``summary_cache`` (a `repro.summary.cache.SummaryCache`) switches the
    byte accounting to a *shared pool*: the budget is the summary cache's
    ``byte_budget`` and this cache's usage is charged on top of the
    summaries' resident bytes — so hot summaries squeeze messages out, and
    a standalone deployment can still size the message cache independently
    via ``byte_budget``.
    """

    def __init__(self, byte_budget: int = 64 << 20,
                 spill_dir: Optional[str] = None,
                 summary_cache=None,
                 flight_timeout: float = 30.0) -> None:
        if byte_budget <= 0:
            raise ValueError("byte_budget must be positive")
        self.byte_budget = int(byte_budget)
        self.spill_dir = spill_dir
        self.summary_cache = summary_cache
        self.flight_timeout = float(flight_timeout)
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
        self._entries: "Dict[str, CachedMessage]" = {}
        self._lru: List[str] = []          # oldest first
        self._nbytes: Dict[str, int] = {}
        self._tables: Dict[str, FrozenSet[str]] = {}
        self._flights: Dict[str, _Flight] = {}
        self._lock = threading.RLock()
        self.stats = MsgCacheStats()

    def _bump(self, stat: str, n: int = 1) -> None:
        """Increment a stats field and mirror it into the process metrics
        registry (``msgcache.<stat>``) — one write, two views."""
        setattr(self.stats, stat, getattr(self.stats, stat) + n)
        REGISTRY.counter(f"msgcache.{stat}").inc(n)

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(self._nbytes.values())

    def _budget_used(self) -> int:
        """Bytes charged against the pool (lock held)."""
        used = sum(self._nbytes.values())
        if self.summary_cache is not None:
            used += self.summary_cache.resident_bytes
        return used

    def _budget_limit(self) -> int:
        if self.summary_cache is not None:
            return int(self.summary_cache.byte_budget)
        return self.byte_budget

    def resident_keys(self) -> FrozenSet[str]:
        """Snapshot of the fingerprints currently answerable without a
        product — memory-resident plus spilled.  The planner's residency
        pricing (`CostModel.apply_residency`) probes against this."""
        with self._lock:
            keys = set(self._entries)
        if self.spill_dir is not None:
            try:
                for name in os.listdir(self.spill_dir):
                    if name.endswith(".gjm"):
                        keys.add(name[:-4])
            except OSError:
                pass
        return frozenset(keys)

    def _spill_path(self, key: str) -> Optional[str]:
        if self.spill_dir is None:
            return None
        return os.path.join(self.spill_dir, f"{key}.gjm")

    # -- lookup / single-flight -------------------------------------------
    def get(self, key: str) -> Optional[CachedMessage]:
        """Memory first, then spill; None on a true miss.  Counts stats."""
        entry, _ = self._get_counted(key)
        return entry

    def _get_counted(self, key: str) -> Tuple[Optional[CachedMessage], str]:
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                self._lru.remove(key)
                self._lru.append(key)
                self._bump("hits")
                return hit, "memory"
            path = self._spill_path(key)
        if path is not None and os.path.exists(path):
            try:
                with open(path, "rb") as f:
                    entry = _entry_from_bytes(f.read())
            except (OSError, ValueError):
                entry = None
            if entry is not None:
                with self._lock:
                    if not os.path.exists(path):
                        # invalidate() raced the load: entry declared stale
                        self._bump("misses")
                        return None, "miss"
                    self._bump("disk_hits")
                    spills = self._admit(key, entry)
                self._write_spills(spills)
                return entry, "disk"
        with self._lock:
            self._bump("misses")
        return None, "miss"

    def lookup_or_begin(self, key: str
                        ) -> Tuple[Optional[CachedMessage], Optional[_Flight]]:
        """Single-flight probe: ``(entry, None)`` on a hit; ``(None,
        flight)`` when the caller becomes the leader for ``key`` and must
        `publish` (or `abandon`) it; ``(None, None)`` when a wait on
        another leader timed out — compute locally, publish nothing.

        A follower whose leader publishes adopts the published entry
        (counted as a ``wait``).  Leaders never nest: a build computes its
        steps sequentially and resolves each flight before probing the
        next key, so follower waits cannot deadlock.
        """
        deadline = time.monotonic() + self.flight_timeout
        while True:
            with self._lock:
                if key in self._entries:
                    entry, _ = self._get_counted(key)
                    if entry is not None:
                        return entry, None
                flight = self._flights.get(key)
                if flight is None:
                    # nobody is computing this key: probe spill, else lead
                    pass
                else:
                    wait_for = flight
            if flight is None:
                entry, source = self._get_counted(key)
                if entry is not None:
                    return entry, None
                with self._lock:
                    # somebody may have started (or finished) while we
                    # probed the disk outside the lock
                    if key in self._entries:
                        entry, _ = self._get_counted(key)
                        if entry is not None:
                            return entry, None
                    flight = self._flights.get(key)
                    if flight is None:
                        flight = _Flight()
                        self._flights[key] = flight
                        return None, flight
                    wait_for = flight
            remaining = deadline - time.monotonic()
            if remaining <= 0 or not wait_for.event.wait(timeout=remaining):
                with self._lock:
                    self._bump("timeouts")
                return None, None
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    self._lru.remove(key)
                    self._lru.append(key)
                    self._bump("waits")
                    return entry, None
                # leader abandoned (or the entry was instantly evicted):
                # retry — either lead ourselves or find a new leader
                continue

    def publish(self, key: str, flight: Optional[_Flight],
                psi: Optional[Psi], message: Factor,
                tables: Iterable[str] = ()) -> None:
        """Insert the computed step and release the key's latch (if any).

        Values are stored as references — callers and downstream consumers
        must treat the arrays as immutable (every Factor/Psi operation in
        this codebase already copies on write).
        """
        entry = CachedMessage(message=message, psi=psi)
        with self._lock:
            self._bump("puts")
            self._tables[key] = frozenset(tables)
            spills = self._admit(key, entry)
            if flight is not None and self._flights.get(key) is flight:
                del self._flights[key]
        if flight is not None:
            flight.event.set()
        self._write_spills(spills)

    def abandon(self, key: str, flight: Optional[_Flight]) -> None:
        """Release a leader's latch without publishing (compute failed)."""
        if flight is None:
            return
        with self._lock:
            if self._flights.get(key) is flight:
                del self._flights[key]
        flight.event.set()

    @staticmethod
    def adopt(entry: CachedMessage, child: str, parents: Sequence[str]
              ) -> Tuple[Optional[Psi], Factor]:
        """Rename a cached step to the consumer's variable names.

        The fingerprint pins the separator *sequence*, so the rename is
        positional: cached message/psi columns line up 1:1 with the
        consumer's ``parents``.  Arrays are shared (no copy).
        """
        msg = entry.message
        if len(parents) != len(msg.vars):
            raise ValueError(
                f"cached message arity {len(msg.vars)} != separator arity "
                f"{len(parents)} — fingerprint collision?")
        message = Factor(tuple(parents), msg.keys, msg.bucket, msg.fac,
                         msg.sizes)
        psi = None
        if entry.psi is not None:
            psi = replace(entry.psi, child=child, parents=tuple(parents))
        return psi, message

    # -- admission / eviction ---------------------------------------------
    def _admit(self, key: str, entry: CachedMessage) -> List[Tuple]:
        """Insert/refresh + shrink (lock held); returns deferred spills."""
        if key in self._entries:
            self._lru.remove(key)
        self._entries[key] = entry
        self._lru.append(key)
        self._nbytes[key] = entry.nbytes()
        return self._shrink(keep=key)

    def _shrink(self, keep: Optional[str] = None) -> List[Tuple]:
        """Evict LRU entries until the (possibly shared) budget holds
        (lock held).  The entry named by ``keep`` survives even if the
        pool alone exceeds the budget — an oversized message is still
        better served hot once.  Spill writes are deferred and returned
        for `_write_spills` to run outside the lock."""
        pending: List[Tuple] = []
        limit = self._budget_limit()
        while self._budget_used() > limit and len(self._entries) > 1:
            victim = self._lru[0]
            if victim == keep:
                if len(self._lru) < 2:
                    break
                victim = self._lru[1]
            self._lru.remove(victim)
            entry = self._entries.pop(victim)
            self._nbytes.pop(victim, None)
            self._bump("evictions")
            path = self._spill_path(victim)
            if path is None:
                self._tables.pop(victim, None)
            elif not os.path.exists(path):
                pending.append((victim, entry, path))
                # provenance stays: the spill file (about to exist) needs it
        return pending

    def _write_spills(self, pending: List[Tuple]) -> None:
        for key, entry, path in pending:
            with self._lock:
                if key not in self._tables:
                    continue   # invalidated after eviction: declared stale
            with _span("msgcache:spill", cat="msgcache", key=key):
                data = _entry_to_bytes(entry)
                tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
                with open(tmp, "wb") as f:
                    f.write(data)
                os.replace(tmp, path)        # atomic publish
            with self._lock:
                self._bump("spills")

    # -- invalidation ------------------------------------------------------
    def invalidate(self, table: str) -> int:
        """Drop every message recorded as derived from ``table``.

        Version-keyed fingerprints already guarantee an append never
        *serves* a stale message; this is the explicit override for tables
        mutated behind the catalog's back, and the hygiene hook
        `JoinService.invalidate` calls to reclaim dead bytes."""
        removed = 0
        with self._lock:
            for key, tabs in list(self._tables.items()):
                if table not in tabs:
                    continue
                hit = False
                if key in self._entries:
                    self._entries.pop(key)
                    self._nbytes.pop(key, None)
                    self._lru.remove(key)
                    hit = True
                path = self._spill_path(key)
                if path is not None and os.path.exists(path):
                    try:
                        os.remove(path)
                        hit = True
                    except OSError:
                        pass
                self._tables.pop(key, None)
                if hit:
                    removed += 1
            self._bump("invalidations", removed)
        return removed

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._lru.clear()
            self._nbytes.clear()
            self._tables.clear()
