"""Incremental GFJS maintenance on base-table appends (DESIGN.md §12).

A summary built by the Graphical Join pipeline is a pure function of the
per-occurrence potentials, and the elimination trace recorded by
``build_generator(record_trace=True)`` pins exactly how those potentials
flowed through Algorithm 2: which table factors and which messages fed
each step.  On an append, therefore:

* only the appended block is encoded (the base rows are never rescanned);
* each touched occurrence's potential is upgraded with
  ``Factor.merge_counts`` (GROUP BY of the block, pointwise-added);
* only the *dirty* steps — those whose inputs are reachable from the
  appended table in the message-flow DAG — are re-run; every clean step's
  conditional factor and message are reused verbatim;
* the GFJS is re-emitted with a *splice*: for the prefix of levels whose
  psi structure did not change, the cached ``(src, cidx)`` gather indices
  replay the weight propagation (no group lookups, no expansion); the
  first structurally-changed level falls back to the generic frontier
  expansion from there down.

Appends may introduce values never seen before: dictionary codes are
assigned in sorted raw order, so a grown domain *shifts* codes.  The
refresher computes one monotone ``old code -> new code`` remap per grown
variable and rewrites every retained artifact (factors, messages, psis,
summary levels) through it — monotonicity preserves every sort and CSR
grouping, so remapping is a pure gather, never a re-sort.

Equivalence with a from-scratch rebuild under the same plan is the
contract (tests/test_incremental.py runs the differential harness);
``benchmarks/incremental_bench.py`` measures the refresh-vs-rebuild gap.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.elimination import (EliminationTrace, Generator, Psi,
                                    StepTrace, assemble_generator,
                                    eliminate_step, root_marginal)
from repro.core.gfjs import GFJS, LevelSummary, expand_level, generate_gfjs
from repro.core.potentials import INT, Factor
from repro.relational.encoding import Domain
from repro.relational.query import JoinQuery
from repro.relational.table import Table, TableDelta


class DeltaError(ValueError):
    """The delta cannot be applied incrementally; rebuild instead."""


class StaleDeltaError(DeltaError):
    """Version chain mismatch: the state is not at the delta's base."""


ExpansionCache = List[List[Tuple[np.ndarray, np.ndarray]]]


@dataclass
class IncrementalState:
    """Everything needed to refresh one summary without a rebuild."""

    query: JoinQuery
    plan: object                          # PhysicalPlan (kept duck-typed)
    domains: Dict[str, Domain]
    table_versions: Dict[str, str]        # versions this state reflects
    generator: Generator                  # carries the EliminationTrace
    gfjs: GFJS
    expansion_cache: ExpansionCache
    cache_key: Optional[str] = None       # where the service cached gfjs
    last_report: Dict[str, float] = field(default_factory=dict)

    def nbytes(self) -> int:
        n = self.generator.nbytes() + self.gfjs.nbytes()
        if self.generator.trace is not None:
            n += self.generator.trace.nbytes()
        for level in self.expansion_cache:
            n += sum(int(s.nbytes + c.nbytes) for s, c in level)
        return int(n)


def capture_state(executor, gfjs: GFJS,
                  versions: Optional[Mapping[str, str]] = None
                  ) -> IncrementalState:
    """Snapshot a freshly-run pipeline into an :class:`IncrementalState`.

    ``executor`` is a ``repro.plan.executor.Executor`` (or a
    ``GraphicalJoin``, which is unwrapped) that ran with
    ``record_trace=True``.  ``versions`` overrides the table versions read
    from the catalog — pass the versions the caller keyed its cache on so
    a concurrent append cannot skew the snapshot.
    """
    ex = getattr(executor, "_executor", executor)
    gen = ex.generator
    if gen is None or gen.trace is None:
        raise ValueError("capture_state needs a record_trace=True run")
    if ex.expansion_cache is None:
        raise ValueError("capture_state needs the summarize expansion cache")
    query = ex.query
    if versions is None:
        # prefer the versions build_model actually encoded: reading the
        # live catalog here could pick up an append the summary never saw
        versions = getattr(ex, "source_versions", None) or {
            qt.table: ex.catalog[qt.table].version() for qt in query.tables}
    return IncrementalState(
        query=query,
        plan=ex.plan,
        domains=dict(ex.enc.domains),
        table_versions=dict(versions),
        generator=gen,
        gfjs=gfjs,
        expansion_cache=ex.expansion_cache,
    )


# ---------------------------------------------------------------------------
# delta normalization
# ---------------------------------------------------------------------------

def _coalesce_deltas(state: IncrementalState, deltas: Sequence[TableDelta]
                     ) -> Tuple[Dict[str, Table], Dict[str, str]]:
    """Chain-validate and merge deltas into one block per table.

    Deltas for tables outside the query are ignored.  A broken version
    chain (the state is not at a delta's base, or deltas arrive out of
    order) raises :class:`StaleDeltaError` — the caller's cue to rebuild.
    """
    query_tables = {qt.table for qt in state.query.tables}
    per_table: Dict[str, List[TableDelta]] = {}
    for d in deltas:
        if d.table in query_tables:
            per_table.setdefault(d.table, []).append(d)
    blocks: Dict[str, Table] = {}
    new_versions = dict(state.table_versions)
    for t, ds in per_table.items():
        v = new_versions[t]
        for d in ds:
            if d.base_version != v:
                raise StaleDeltaError(
                    f"delta chain for {t!r} expects base {d.base_version[:8]}, "
                    f"state is at {v[:8]}")
            v = d.new_version
        new_versions[t] = v
        block = ds[0].block
        for d in ds[1:]:
            block = block.concat(d.block)
        blocks[t] = block
    return blocks, new_versions


# ---------------------------------------------------------------------------
# domain growth: monotone code remaps
# ---------------------------------------------------------------------------

def _grow_domains(state: IncrementalState, blocks: Mapping[str, Table]
                  ) -> Tuple[Dict[str, Domain], Dict[str, np.ndarray]]:
    """Extend domains with the blocks' unseen values; return code remaps.

    The remap for a grown variable maps every *old* code to its position
    in the grown (still sorted) domain — a monotone injection, so sorted
    structures stay sorted after the gather.
    """
    domains = dict(state.domains)
    remaps: Dict[str, np.ndarray] = {}
    fresh: Dict[str, List[np.ndarray]] = {}
    for qt in state.query.tables:
        blk = blocks.get(qt.table)
        if blk is None:
            continue
        for col, var in qt.var_map:
            fresh.setdefault(var, []).append(blk[col])
    for var, cols in fresh.items():
        old = domains[var]
        vals = np.unique(np.concatenate([np.unique(c) for c in cols]))
        if old.size and old.values.dtype.kind != vals.dtype.kind:
            raise DeltaError(
                f"append changes the dtype kind of variable {var!r} "
                f"({old.values.dtype} vs {vals.dtype})")
        merged = np.union1d(old.values, vals)
        if len(merged) != old.size:
            domains[var] = Domain(var, merged)
            remaps[var] = np.searchsorted(merged, old.values).astype(INT)
    return domains, remaps


def _remap_factor(f: Factor, remaps: Mapping[str, np.ndarray],
                  sizes: Mapping[str, int]) -> Factor:
    if not any(v in remaps for v in f.vars):
        return f
    keys = f.keys.copy()
    for j, v in enumerate(f.vars):
        if v in remaps:
            keys[:, j] = remaps[v][keys[:, j]]
    return Factor(f.vars, keys, f.bucket, f.fac,
                  tuple(int(sizes[v]) for v in f.vars))


def _remap_psi(p: Psi, remaps: Mapping[str, np.ndarray],
               sizes: Mapping[str, int]) -> Psi:
    if not any(v in remaps for v in p.parents) and p.child not in remaps:
        return p
    pk = p.parent_keys
    if any(v in remaps for v in p.parents):
        pk = pk.copy()
        for j, v in enumerate(p.parents):
            if v in remaps:
                pk[:, j] = remaps[v][pk[:, j]]
    cc = p.child_codes
    if p.child in remaps:
        cc = remaps[p.child][cc]
    return Psi(p.child, p.parents, pk, p.start, p.count, cc,
               p.bucket, p.fac,
               tuple(int(sizes[v]) for v in p.parents),
               int(sizes[p.child]))


def _remap_levels(gfjs: GFJS, remaps: Mapping[str, np.ndarray]
                  ) -> List[LevelSummary]:
    """The old summary's levels with grown-domain codes rewritten.

    Arrays untouched by any remap are shared, never copied — concurrent
    readers of the old GFJS are unaffected.
    """
    if not remaps:
        return list(gfjs.levels)
    out = []
    for lvl in gfjs.levels:
        cols = {v: (remaps[v][c] if v in remaps else c)
                for v, c in lvl.key_cols.items()}
        out.append(LevelSummary(lvl.vars, cols, lvl.freq))
    return out


# ---------------------------------------------------------------------------
# the refresh
# ---------------------------------------------------------------------------

def _psi_structure_equal(a: Optional[Psi], b: Optional[Psi]) -> bool:
    """Same CSR layout (groups, counts, child codes) — values may differ."""
    if a is None or b is None:
        return a is b
    return (a.parents == b.parents
            and a.parent_keys.shape == b.parent_keys.shape
            and np.array_equal(a.parent_keys, b.parent_keys)
            and np.array_equal(a.count, b.count)
            and np.array_equal(a.child_codes, b.child_codes))


def _frontier_cols(levels: Sequence[LevelSummary], upto: int
                   ) -> Dict[str, np.ndarray]:
    """Frontier columns (all vars of levels 0..upto) at level-``upto`` runs.

    Levels refine, so each deep run's start offset falls inside exactly one
    shallow run — the same ancestor search the summary algebra uses.
    """
    deep = levels[upto]
    starts_deep = np.cumsum(deep.freq) - deep.freq
    cols: Dict[str, np.ndarray] = {}
    for j in range(upto + 1):
        lvl = levels[j]
        if j == upto:
            anc = np.arange(lvl.num_runs, dtype=INT)
        else:
            anc = np.searchsorted(np.cumsum(lvl.freq), starts_deep,
                                  side="right").astype(INT)
        for v in lvl.vars:
            cols[v] = lvl.key_cols[v][anc]
    return cols


def refresh_state(state: IncrementalState, deltas: Sequence[TableDelta]
                  ) -> Tuple[IncrementalState, Dict[str, float]]:
    """Apply base-table appends to a summary; returns (new state, report).

    The input state is never mutated: clean artifacts are shared between
    old and new state (remapped copies when a domain grew), so concurrent
    readers of the old summary keep a consistent view.
    """
    trace = state.generator.trace
    if trace is None:
        raise ValueError("state has no elimination trace")
    t0 = time.perf_counter()

    blocks, new_versions = _coalesce_deltas(state, deltas)
    appended = sum(b.num_rows for b in blocks.values())

    domains, remaps = _grow_domains(state, blocks)
    sizes = {v: d.size for v, d in domains.items()}

    # 1. upgrade the touched occurrences' potentials from the blocks alone
    factors = [_remap_factor(f, remaps, sizes) for f in trace.factors]
    dirty_occ = set()
    for i, qt in enumerate(state.query.tables):
        blk = blocks.get(qt.table)
        if blk is None or blk.num_rows == 0:
            continue
        enc_cols = {var: domains[var].encode(blk[col])
                    for col, var in qt.var_map}
        factors[i] = factors[i].merge_counts(
            Factor.from_columns(enc_cols, sizes))
        dirty_occ.add(i)

    # 2. re-run only the dirty steps; reuse every clean psi and message
    order = list(state.generator.elimination_order)
    out_vars = state.query.output_variables
    dirty_vars: set = set()
    msg_of: Dict[str, Factor] = {}
    psis: Dict[str, Psi] = {}
    parents_of: Dict[str, Tuple[str, ...]] = {}
    structure_same: Dict[str, bool] = {}
    new_steps: List[StepTrace] = []
    for st in trace.steps:
        dirty = (any(i in dirty_occ for i in st.rel_tables)
                 or any(u in dirty_vars for u in st.rel_msgs))
        if not dirty:
            msg = _remap_factor(st.message, remaps, sizes)
            psi = (_remap_psi(st.psi, remaps, sizes)
                   if st.psi is not None else None)
            structure_same[st.var] = True
            new_steps.append(replace(st, message=msg, psi=psi))
        else:
            dirty_vars.add(st.var)
            rel = [factors[i] for i in st.rel_tables] \
                + [msg_of[u] for u in st.rel_msgs]
            psi, parents, msg = eliminate_step(rel, st.var, order, out_vars)
            if parents != st.parents:  # pragma: no cover - structural invariant
                raise AssertionError(
                    f"refresh changed separator of {st.var}: "
                    f"{st.parents} -> {parents}")
            old_psi = (_remap_psi(st.psi, remaps, sizes)
                       if st.psi is not None else None)
            structure_same[st.var] = _psi_structure_equal(old_psi, psi)
            new_steps.append(replace(st, message=msg, psi=psi))
        last = new_steps[-1]
        msg_of[st.var] = last.message
        parents_of[st.var] = last.parents
        if last.psi is not None:
            psis[st.var] = last.psi

    # 3. root marginal: always recomputed (1-D products; frequencies of the
    # whole tree flow into it, so any append moves it)
    leftover = [factors[i] for i in trace.root_tables] \
        + [msg_of[u] for u in trace.root_msgs]
    phi_root = root_marginal(leftover, order[-1])

    gen = assemble_generator(
        order, psis, parents_of, phi_root, stats=dict(state.generator.stats),
        trace=EliminationTrace(new_steps, trace.root_tables,
                               trace.root_msgs, factors))

    # 4. splice: replay weights over the structurally-unchanged prefix,
    # full expansion from the first changed level down
    old_levels = _remap_levels(state.gfjs, remaps)
    old_root = old_levels[0].key_cols[gen.root]
    root_same = np.array_equal(gen.root_codes, old_root)
    gfjs, cache, spliced = _regenerate(
        gen, domains, old_levels, state.expansion_cache,
        structure_same, root_same)

    report = {
        "rows_appended": float(appended),
        "tables_touched": float(len(blocks)),
        "dirty_steps": float(len(dirty_vars)),
        "total_steps": float(len(trace.steps)),
        "spliced_levels": float(spliced),
        "total_levels": float(len(gfjs.levels)),
        "grown_domains": float(len(remaps)),
        "seconds": time.perf_counter() - t0,
    }
    new_state = IncrementalState(
        query=state.query,
        plan=state.plan,
        domains=domains,
        table_versions=new_versions,
        generator=gen,
        gfjs=gfjs,
        expansion_cache=cache,
        last_report=report,
    )
    return new_state, report


def _regenerate(gen: Generator, domains: Dict[str, Domain],
                old_levels: List[LevelSummary], old_cache: ExpansionCache,
                structure_same: Mapping[str, bool], root_same: bool
                ) -> Tuple[GFJS, ExpansionCache, int]:
    """Emit the refreshed GFJS, splicing over the clean level prefix."""
    n_levels = len(gen.levels) + 1

    # longest prefix of levels whose run structure is provably unchanged
    clean = 0
    if root_same and len(old_levels) == n_levels \
            and len(old_cache) == len(gen.levels):
        clean = 1
        for li, level in enumerate(gen.levels):
            ok = (tuple(p.child for p in level) == old_levels[li + 1].vars
                  and len(old_cache[li]) == len(level)
                  and all(structure_same.get(p.child, False) for p in level))
            if not ok:
                break
            clean += 1

    if clean == 0:
        cache: ExpansionCache = []
        return generate_gfjs(gen, domains, cache), cache, 0

    # weight re-propagation down the unchanged chain: pure gathers
    levels_out: List[LevelSummary] = [
        LevelSummary((gen.root,), {gen.root: gen.root_codes}, gen.root_freq)]
    cache = []
    p_bucket = np.ones(len(gen.root_codes), INT)
    for li in range(clean - 1):
        level = gen.levels[li]
        fac_acc = None
        for psi, (src, cidx) in zip(level, old_cache[li]):
            p_bucket = p_bucket[src] * psi.bucket[cidx]
            # the first psi's fac starts the accumulator directly — a
            # gather of an all-ones array is pure memory traffic, and the
            # replay is gather-bound
            fac_acc = (psi.fac[cidx] if fac_acc is None
                       else fac_acc[src] * psi.fac[cidx])
        old = old_levels[li + 1]
        levels_out.append(LevelSummary(old.vars, dict(old.key_cols),
                                       p_bucket * fac_acc))
        cache.append(list(old_cache[li]))

    if clean < n_levels:
        # resume the generic expansion below the spliced prefix; the
        # frontier there is reconstructible because its structure matches
        # the old summary run-for-run
        cols = _frontier_cols(old_levels, clean - 1)
        for li in range(clean - 1, len(gen.levels)):
            cols, p_bucket, freq, new_vars, level_cache = expand_level(
                cols, p_bucket, gen.levels[li])
            levels_out.append(LevelSummary(
                new_vars, {v: cols[v] for v in new_vars}, freq))
            cache.append(level_cache)

    gfjs = GFJS(levels_out, list(gen.column_order), gen.join_size, domains)
    return gfjs, cache, clean
