"""Compute-and-reuse summary cache.

The paper's 100X+ wins come from storing the (tiny) GFJS and answering
later requests from it instead of re-joining.  :class:`SummaryCache` makes
that a service-grade component:

* keys are (canonical query fingerprint, content versions of every table
  occurrence) — replacing a base table invalidates exactly the summaries
  built on it, nothing else;
* a byte budget bounds resident summaries, LRU order decides eviction;
* evictions optionally *spill* to disk through the GFJS container format
  (repro/core/storage.py), so a later request pays a load, not a re-join;
* hit/miss/eviction counters feed the service's observability.
"""

from __future__ import annotations

import hashlib
import os
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.gfjs import GFJS
from repro.core.storage import load_gfjs, save_gfjs
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog


def cache_key(query: JoinQuery, catalog: Catalog) -> str:
    """(query fingerprint, table versions) -> one stable hex key."""
    h = hashlib.sha256(query.fingerprint().encode())
    for name in sorted({qt.table for qt in query.tables}):
        h.update(name.encode())
        h.update(catalog[name].version().encode())
    return h.hexdigest()


@dataclass
class CacheStats:
    hits: int = 0            # served from memory
    disk_hits: int = 0       # served from spill
    misses: int = 0
    evictions: int = 0
    spills: int = 0
    puts: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


class SummaryCache:
    """LRU GFJS store with a byte budget and optional disk spill."""

    def __init__(self, byte_budget: int = 256 << 20,
                 spill_dir: Optional[str] = None) -> None:
        if byte_budget <= 0:
            raise ValueError("byte_budget must be positive")
        self.byte_budget = int(byte_budget)
        self.spill_dir = spill_dir
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
        self._entries: "OrderedDict[str, GFJS]" = OrderedDict()
        self._nbytes: Dict[str, int] = {}
        self.stats = CacheStats()

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    @property
    def resident_bytes(self) -> int:
        return sum(self._nbytes.values())

    def _spill_path(self, key: str) -> Optional[str]:
        if self.spill_dir is None:
            return None
        return os.path.join(self.spill_dir, f"{key}.gfjs")

    # -- core API ----------------------------------------------------------
    def get(self, key: str) -> Optional[GFJS]:
        """Memory first, then spill; None on a true miss."""
        hit = self._entries.get(key)
        if hit is not None:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return hit
        path = self._spill_path(key)
        if path is not None and os.path.exists(path):
            gfjs = load_gfjs(path)
            self.stats.disk_hits += 1
            self._admit(key, gfjs)   # promote back into memory
            return gfjs
        self.stats.misses += 1
        return None

    def put(self, key: str, gfjs: GFJS) -> None:
        self.stats.puts += 1
        self._admit(key, gfjs)

    def _admit(self, key: str, gfjs: GFJS) -> None:
        self._entries[key] = gfjs      # replace on re-put, insert otherwise
        self._entries.move_to_end(key)
        self._nbytes[key] = gfjs.nbytes()
        self._shrink(keep=key)

    def _shrink(self, keep: Optional[str] = None) -> None:
        """Evict LRU entries until the byte budget holds.

        The entry named by ``keep`` survives even if it alone exceeds the
        budget (an oversized summary is still better served hot once).
        """
        while self.resident_bytes > self.byte_budget and len(self._entries) > 1:
            victim = next(iter(self._entries))
            if victim == keep:
                # keep must stay; evict the next-oldest instead
                it = iter(self._entries)
                next(it)
                victim = next(it)
            gfjs = self._entries.pop(victim)
            self._nbytes.pop(victim)
            self.stats.evictions += 1
            path = self._spill_path(victim)
            if path is not None and not os.path.exists(path):
                save_gfjs(gfjs, path)
                self.stats.spills += 1

    def clear(self) -> None:
        self._entries.clear()
        self._nbytes.clear()
