"""Compute-and-reuse summary cache.

The paper's 100X+ wins come from storing the (tiny) GFJS and answering
later requests from it instead of re-joining.  :class:`SummaryCache` makes
that a service-grade component:

* keys are (canonical query fingerprint, content versions of every table
  occurrence, physical-plan signature) — replacing a base table invalidates
  exactly the summaries built on it, and summaries built under different
  elimination orders never collide; partitioned plans fold their shard
  scheme into the signature, so a ShardedGFJS and a monolithic summary of
  the same query are distinct entries that hit, spill, and reload alike
  (the storage container round-trips both, byte budgets read
  ``resident_nbytes()`` on either shape);
* a byte budget bounds resident summaries, LRU order decides eviction;
* evictions optionally *spill* to disk through the GFJS container format
  (repro/core/storage.py), so a later request pays a load, not a re-join;
* every public operation takes the cache lock, so one cache may serve
  multiple threads (`JoinService` relies on this);
* entries may carry a TTL (seconds); expired residents are dropped on
  access, expired spill files (by mtime) are ignored and unlinked;
* `invalidate(table)` force-drops every entry recorded as built on a base
  table — the explicit override for when content-version keying is not
  enough (e.g. a table mutated in place behind the catalog's back);
* `refresh(old_key, new_key, gfjs)` upgrades an entry in place — the
  commit point of incremental maintenance: retirement of the stale
  summary and admission of the refreshed one are atomic under the lock;
* hit/miss/eviction/expiry counters feed the service's observability.
"""

from __future__ import annotations

import hashlib
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

from repro.core.gfjs import GFJS
from repro.core.storage import load_gfjs, save_gfjs
from repro.obs.metrics import REGISTRY
from repro.obs.trace import span as _span
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog


def cache_key_for_versions(query: JoinQuery, versions, plan=None) -> str:
    """(query fingerprint [× plan signature], table versions) -> hex key.

    ``versions`` maps base-table name -> content version.  The incremental
    refresh path keys the upgraded summary on the versions its delta chain
    ends at, which may already trail the live catalog by a racing append.
    """
    h = hashlib.sha256(query.fingerprint(plan=plan).encode())
    for name in sorted({qt.table for qt in query.tables}):
        h.update(name.encode())
        h.update(versions[name].encode())
    return h.hexdigest()


def cache_key(query: JoinQuery, catalog: Catalog, plan=None) -> str:
    """`cache_key_for_versions` against the catalog's current versions."""
    return cache_key_for_versions(
        query, {qt.table: catalog[qt.table].version() for qt in query.tables},
        plan=plan)


@dataclass
class CacheStats:
    hits: int = 0            # served from memory
    disk_hits: int = 0       # served from spill
    misses: int = 0
    evictions: int = 0
    spills: int = 0
    puts: int = 0
    expirations: int = 0     # TTL drops (resident or spill)
    invalidations: int = 0   # entries dropped by invalidate()
    refreshes: int = 0       # upgrade-in-place via refresh()

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


class SummaryCache:
    """Thread-safe LRU GFJS store with byte budget, TTL, and disk spill."""

    def __init__(self, byte_budget: int = 256 << 20,
                 spill_dir: Optional[str] = None,
                 ttl_seconds: Optional[float] = None) -> None:
        if byte_budget <= 0:
            raise ValueError("byte_budget must be positive")
        if ttl_seconds is not None and ttl_seconds <= 0:
            raise ValueError("ttl_seconds must be positive (or None)")
        self.byte_budget = int(byte_budget)
        self.spill_dir = spill_dir
        self.ttl_seconds = ttl_seconds
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
        self._entries: "OrderedDict[str, GFJS]" = OrderedDict()
        self._nbytes: Dict[str, int] = {}
        self._born: Dict[str, float] = {}                # key -> creation time
        self._tables: Dict[str, FrozenSet[str]] = {}     # key -> base tables
        self._lock = threading.RLock()
        self.stats = CacheStats()

    def _bump(self, stat: str, n: int = 1) -> None:
        """Increment a CacheStats field and mirror it into the process
        metrics registry (``cache.<stat>``) — one write, two views."""
        setattr(self.stats, stat, getattr(self.stats, stat) + n)
        REGISTRY.counter(f"cache.{stat}").inc(n)

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(self._nbytes.values())

    def _spill_path(self, key: str) -> Optional[str]:
        if self.spill_dir is None:
            return None
        return os.path.join(self.spill_dir, f"{key}.gfjs")

    # -- TTL ---------------------------------------------------------------
    # TTL measures age since *computation* (the original put), not since the
    # last promotion or eviction: `_born` carries the wall-clock creation
    # time for resident entries and spill files store the same instant as
    # their mtime (os.utime on write), so the clock survives
    # evict/promote cycles in both directions.

    def _expired(self, born: float) -> bool:
        return (self.ttl_seconds is not None
                and time.time() - born > self.ttl_seconds)

    def _drop(self, key: str) -> None:
        """Remove a resident entry (lock held)."""
        self._entries.pop(key, None)
        self._nbytes.pop(key, None)
        self._born.pop(key, None)
        self._prune_provenance(key)

    def _prune_provenance(self, key: str) -> None:
        """Drop the key's table provenance unless a spill file still needs
        it (lock held) — keeps `_tables` from growing without bound as
        version churn mints ever-new keys."""
        path = self._spill_path(key)
        if path is None or not os.path.exists(path):
            self._tables.pop(key, None)

    def probe(self, key: str) -> str:
        """Non-mutating presence check: ``"memory" | "disk" | "miss"``.

        No promotion, no LRU bump, no stats — the :class:`JoinServer`
        admission gate asks whether a request *would* be a cold build
        without perturbing the cache it is pricing.  TTL is respected
        (an expired entry reads as a miss) but expiry is not acted on;
        the next real ``get`` does the dropping.
        """
        with self._lock:
            if key in self._entries \
                    and not self._expired(self._born.get(key, 0.0)):
                return "memory"
            path = self._spill_path(key)
        if path is not None and os.path.exists(path):
            try:
                if not self._expired(os.path.getmtime(path)):
                    return "disk"
            except OSError:      # raced an unlink between exists and stat
                pass
        return "miss"

    # -- core API ----------------------------------------------------------
    def get(self, key: str) -> Optional[GFJS]:
        """Memory first, then spill; None on a true miss or TTL expiry."""
        return self.get_with_source(key)[0]

    def get_with_source(self, key: str) -> Tuple[Optional[GFJS], str]:
        """(gfjs, "memory" | "disk") on a hit; (None, "miss") otherwise.

        The source tier is determined by *this* lookup, not inferred from
        shared counters — concurrent requests cannot mislabel each other.
        """
        with _span("cache:get", cat="cache") as sp:
            gfjs, source = self._get_with_source(key)
            sp.set(source=source)
            return gfjs, source

    def _get_with_source(self, key: str) -> Tuple[Optional[GFJS], str]:
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                if self._expired(self._born.get(key, 0.0)):
                    self._drop(key)
                    self._bump("expirations")
                else:
                    self._entries.move_to_end(key)
                    # re-measure: expansion caches (_bounds / _launch) grow
                    # lazily after admission, and the byte budget must see
                    # them — O(levels) per hit, settled at the next shrink
                    self._nbytes[key] = hit.resident_nbytes()
                    self._bump("hits")
                    return hit, "memory"
            path = self._spill_path(key)
            load_from: Optional[str] = None
            born = 0.0
            if path is not None and os.path.exists(path):
                born = os.path.getmtime(path)
                if self._expired(born):
                    os.remove(path)
                    self._prune_provenance(key)
                    self._bump("expirations")
                else:
                    load_from = path
            if load_from is None:
                self._bump("misses")
                return None, "miss"
        # disk I/O happens outside the lock: a slow spill promotion must not
        # stall every other thread's memory hits.  Two threads promoting the
        # same key both load; the second _admit is an idempotent replace.
        try:
            gfjs = load_gfjs(load_from)
        except FileNotFoundError:      # raced with invalidate()/expiry
            with self._lock:
                self._bump("misses")
            return None, "miss"
        with self._lock:
            if not os.path.exists(load_from):
                # invalidate() removed the file while we were loading: the
                # summary we hold is stale — do NOT resurrect it
                self._bump("misses")
                return None, "miss"
            self._bump("disk_hits")
            spills = self._admit(key, gfjs, born=born)
        self.write_spills(spills)
        return gfjs, "disk"

    def put(self, key: str, gfjs: GFJS,
            tables: Optional[Iterable[str]] = None) -> None:
        """Insert/refresh an entry; ``tables`` names the base tables it was
        built on (enables `invalidate`)."""
        with _span("cache:put", cat="cache"):
            with self._lock:
                self._bump("puts")
                if tables is not None:
                    self._tables[key] = frozenset(tables)
                spills = self._admit(key, gfjs, born=time.time())
            self.write_spills(spills)

    def refresh(self, old_key: str, new_key: str, gfjs: GFJS,
                tables: Optional[Iterable[str]] = None,
                defer_spill: bool = False) -> List[Tuple]:
        """Upgrade an entry in place: retire ``old_key``, admit ``new_key``.

        The incremental-maintenance commit point: both the retirement of
        the stale summary (resident entry, spill file, provenance) and the
        admission of the refreshed one happen under one lock acquisition,
        so a concurrent reader observes either the old-consistent or the
        new-consistent summary — never a half-spliced mix, and never a
        window where a get on the old key could resurrect stale state from
        a promotion in flight (`invalidate` races are handled identically:
        provenance for ``old_key`` is gone before the lock is released).

        With ``defer_spill=True`` the eviction spill work this admission
        may trigger is *returned* instead of written — for callers
        (``JoinService._try_refresh``) that must commit under a lock of
        their own and stage the disk I/O outside it via
        :meth:`write_spills`.  Returns the pending spill work either way
        (empty when already written).
        """
        with _span("cache:refresh", cat="cache"), self._lock:
            self._bump("refreshes")
            if old_key != new_key:
                self._entries.pop(old_key, None)
                self._nbytes.pop(old_key, None)
                self._born.pop(old_key, None)
                path = self._spill_path(old_key)
                if path is not None and os.path.exists(path):
                    os.remove(path)
                self._tables.pop(old_key, None)
            if tables is not None:
                self._tables[new_key] = frozenset(tables)
            spills = self._admit(new_key, gfjs, born=time.time())
        if defer_spill:
            return spills
        self.write_spills(spills)
        return []

    def invalidate(self, table: str) -> int:
        """Drop every entry recorded as built on ``table``.

        Covers resident entries and their spill files; returns the number
        of entries removed.  Only entries `put` with ``tables`` provenance
        in this process are discoverable — version-keyed misses already
        handle tables replaced *through* the catalog.
        """
        removed = 0
        with self._lock:
            for key, tabs in list(self._tables.items()):
                if table not in tabs:
                    continue
                hit = False
                if key in self._entries:
                    self._entries.pop(key)
                    self._nbytes.pop(key, None)
                    self._born.pop(key, None)
                    hit = True
                path = self._spill_path(key)
                if path is not None and os.path.exists(path):
                    os.remove(path)
                    hit = True
                self._tables.pop(key, None)
                if hit:                  # one logical entry, however stored
                    removed += 1
            self._bump("invalidations", removed)
        return removed

    def _admit(self, key: str, gfjs: GFJS, *, born: float) -> List[Tuple]:
        """Insert/refresh + shrink (lock held); returns deferred spill work."""
        self._entries[key] = gfjs      # replace on re-put, insert otherwise
        self._entries.move_to_end(key)
        self._nbytes[key] = gfjs.resident_nbytes()
        self._born[key] = born
        return self._shrink(keep=key)

    def _shrink(self, keep: Optional[str] = None) -> List[Tuple]:
        """Evict LRU entries until the byte budget holds (lock held).

        The entry named by ``keep`` survives even if it alone exceeds the
        budget (an oversized summary is still better served hot once).
        Spill *writes* are deferred: this returns (key, gfjs, path, born)
        work items for `write_spills` to run after the lock is released —
        serializing a large GFJS must not stall other threads' memory hits.
        """
        pending: List[Tuple] = []
        while sum(self._nbytes.values()) > self.byte_budget \
                and len(self._entries) > 1:
            victim = next(iter(self._entries))
            if victim == keep:
                # keep must stay; evict the next-oldest instead
                it = iter(self._entries)
                next(it)
                victim = next(it)
            gfjs = self._entries.pop(victim)
            self._nbytes.pop(victim)
            born = self._born.pop(victim, time.time())
            self._bump("evictions")
            path = self._spill_path(victim)
            if path is None:
                self._tables.pop(victim, None)   # nothing left to invalidate
            elif not os.path.exists(path):
                pending.append((victim, gfjs, path, born,
                                victim in self._tables))
                # provenance stays: the spill file (about to exist) needs it
        return pending

    def write_spills(self, pending: List[Tuple]) -> None:
        """Run deferred spill writes (no lock held during disk I/O).

        Writes go to a temp path and are renamed into place, so a reader
        never sees a half-written container: until `os.replace`, the final
        path simply does not exist and `get` reports a miss.
        """
        for key, gfjs, path, born, had_tables in pending:
            with self._lock:
                # invalidate() popped the provenance after eviction: this
                # summary was declared stale — do not write it back
                if had_tables and key not in self._tables:
                    continue
            with _span("cache:spill", cat="cache", key=key):
                tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
                save_gfjs(gfjs, tmp)
                os.utime(tmp, (born, born))  # spill mtime == creation time
                os.replace(tmp, path)        # atomic publish
            with self._lock:
                self._bump("spills")

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._nbytes.clear()
            self._born.clear()
            self._tables.clear()
