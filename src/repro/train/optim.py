"""Optimizer substrate: AdamW with global-norm clipping and schedules.

Implemented directly (no optax dependency): state is a pytree mirroring the
params with f32 ``m``/``v`` moments plus a scalar step.  Parameters may be
bf16 — updates are computed in f32 and cast back, the standard
mixed-precision arrangement whose memory footprint (2 + 4 + 4 bytes/param)
is what the dry-run memory analysis reports.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array                  # [] int32
    m: Any                           # pytree like params, f32
    v: Any                           # pytree like params, f32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def init_state(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: AdamWState) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    step = state.step + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
