"""The fault-tolerant training driver.

Responsibilities beyond calling train_step in a loop:

* periodic async checkpoints (params + optimizer + data-iterator state),
  resume-from-latest on start — preemption-safe by construction;
* deterministic data order across restarts (the batcher cursor is part of
  the checkpoint, so a resumed run consumes exactly the batches the dead
  run would have);
* failure injection hooks for the FT test-suite (`crash_after_step`);
* straggler mitigation at the host level: data batches are produced by a
  lookahead prefetch thread so a slow storage read never stalls the step;
  on a real fleet the same queue is fed by the GFJS range owned by the
  host, which is O(1) to re-balance when hosts change (see data/pipeline).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import TokenBatcher
from repro.models.model import LM
from repro.train.optim import AdamWConfig, init_state
from repro.train.train_step import TrainState, make_train_step


class _Prefetcher:
    """Lookahead batch producer (host-level straggler mitigation).

    Each queue item is (batch, iterator-state-after-this-batch): the trainer
    checkpoints the state of the last *consumed* batch, never the producer's
    lookahead position — that is what makes crash/resume bit-exact even with
    prefetching (tests/test_train_ft.py).
    """

    def __init__(self, make_batch: Callable[[], Dict],
                 get_state: Callable[[], Dict], depth: int = 2) -> None:
        self.make_batch = make_batch
        self.get_state = get_state
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self) -> None:
        pending = None
        while not self.stop.is_set():
            if pending is None:
                batch = self.make_batch()
                pending = (batch, dict(self.get_state()))
            try:
                self.q.put(pending, timeout=0.5)
                pending = None
            except queue.Full:
                continue

    def next(self) -> Tuple[Dict, Dict]:
        return self.q.get()

    def close(self) -> None:
        self.stop.set()


@dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    microbatches: int = 1
    crash_after_step: Optional[int] = None   # failure injection (tests)


class Trainer:
    def __init__(self, lm: LM, opt_cfg: AdamWConfig, batcher: TokenBatcher,
                 cfg: TrainerConfig) -> None:
        self.lm = lm
        self.opt_cfg = opt_cfg
        self.batcher = batcher
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.checkpoint_dir)
        self.step_fn = jax.jit(make_train_step(lm, opt_cfg,
                                               microbatches=cfg.microbatches))
        self.metrics_log: List[Dict[str, float]] = []

    def _init_state(self, seed: int = 0) -> TrainState:
        params = self.lm.init(jax.random.key(seed))
        return TrainState(params, init_state(params))

    def run(self, seed: int = 0) -> TrainState:
        cfg = self.cfg
        start_step = 0
        state = self._init_state(seed)
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, start_step, extra = self.ckpt.restore(state)
            self.batcher.load_state(extra["batcher"])

        prefetch = _Prefetcher(self.batcher.next_batch, self.batcher.state)
        consumed_state = self.batcher.state()
        try:
            for step in range(start_step, cfg.steps):
                batch, consumed_state = prefetch.next()
                state, metrics = self.step_fn(state, batch)
                if (step + 1) % cfg.log_every == 0 or step == cfg.steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step + 1
                    self.metrics_log.append(m)
                if (step + 1) % cfg.checkpoint_every == 0:
                    self.ckpt.save(step + 1, state,
                                   extra={"batcher": consumed_state})
                if cfg.crash_after_step is not None and \
                        (step + 1) == cfg.crash_after_step:
                    raise RuntimeError("injected failure (test)")
        finally:
            prefetch.close()
            self.ckpt.wait()
        return state
