from repro.train.optim import AdamWConfig, init_state, apply_updates
from repro.train.train_step import TrainState, make_train_step
