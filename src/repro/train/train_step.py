"""The jitted train step: loss -> grad -> clip -> AdamW, with optional
microbatch gradient accumulation (lax.scan) and int8 gradient compression
with error feedback for the data-parallel all-reduce.

Two distribution modes:

* **gspmd** (default): the step is a plain function jitted with
  in/out_shardings; XLA GSPMD places every collective.  This is the
  paper-faithful baseline the dry-run lowers.
* **dp_shard_map**: the gradient all-reduce is taken over explicitly with
  ``shard_map`` + :func:`compressed_psum` — int8-quantized gradient
  exchange with per-leaf scales and an error-feedback residual carried in
  the optimizer state.  This is the distributed-optimization trick
  evaluated in tests/test_dist.py on a fake 8-device mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import LM
from repro.train.optim import AdamWConfig, AdamWState, apply_updates, init_state


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_loss_fn(lm: LM) -> Callable:
    def loss_fn(params, batch):
        return lm.loss(params, batch)
    return loss_fn


def make_train_step(
    lm: LM,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict]]:
    """Returns train_step(state, batch) -> (state, metrics).

    With microbatches > 1, the batch's leading dim is split and gradients
    are accumulated in f32 across a lax.scan — memory drops by the
    microbatch factor while keeping the same global batch semantics.
    """
    loss_fn = make_loss_fn(lm)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if microbatches == 1:
            loss, grads = grads_of(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = grads_of(state.params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g)
                return (gsum, lsum + l), None

            (gsum, lsum), _ = jax.lax.scan(acc, (zero, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches

        params, opt, metrics = apply_updates(opt_cfg, state.params, grads,
                                             state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(params, opt), metrics

    return step


# ---------------------------------------------------------------------------
# gradient compression (explicit-DP mode)
# ---------------------------------------------------------------------------

def compressed_psum(g: jax.Array, axis_name: str,
                    residual: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """int8-quantized all-reduce with error feedback.

    The quantization scale is SHARED across the axis (one scalar pmax), so
    summing the int8 payloads dequantizes exactly: psum(q_i)*s = sum(q_i*s).
    Per-shard rounding error goes into the residual and is re-injected next
    step (error feedback), keeping compression unbiased over time.  Wire
    bytes drop 4x vs f32 (int8 payload + one scalar).
    """
    x = g.astype(jnp.float32)
    if residual is not None:
        x = x + residual
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_residual = x - deq
    # int8 on the wire; int32 accumulation avoids overflow at large worlds
    total = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total * scale / n
    return mean, new_residual


def make_dp_shard_map_step(
    lm: LM,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    *,
    compress: bool = True,
    axis: str = "data",
):
    """Explicit data-parallel step: per-shard grads, compressed psum,
    replicated update.  Params replicated across `axis` (pure DP)."""
    from jax.experimental.shard_map import shard_map

    loss_fn = make_loss_fn(lm)

    class DPState(NamedTuple):
        params: Any
        opt: AdamWState
        residual: Any

    def init(params):
        return DPState(params, init_state(params),
                       jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params))

    def step(state: DPState, batch):
        def shard_fn(params, opt, residual, local_batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, local_batch)
            if compress:
                flat_g, tdef = jax.tree.flatten(grads)
                flat_r = tdef.flatten_up_to(residual)
                out = [compressed_psum(g, axis, r)
                       for g, r in zip(flat_g, flat_r)]
                grads = tdef.unflatten([o[0] for o in out])
                new_res = tdef.unflatten([o[1] for o in out])
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g.astype(jnp.float32), axis), grads)
                new_res = residual
            loss = jax.lax.pmean(loss, axis)
            params, opt, metrics = apply_updates(opt_cfg, params, grads, opt)
            metrics = dict(metrics, loss=loss)
            return params, opt, new_res, metrics

        rep = P()
        pspec = jax.tree.map(lambda _: rep, state.params)
        bspec = jax.tree.map(lambda _: P(axis), batch)
        fn = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(pspec, jax.tree.map(lambda _: rep, state.opt),
                      jax.tree.map(lambda _: rep, state.residual), bspec),
            out_specs=(pspec, jax.tree.map(lambda _: rep, state.opt),
                       jax.tree.map(lambda _: rep, state.residual),
                       {"grad_norm": rep, "lr": rep, "loss": rep}),
            check_rep=False)
        params, opt, res, metrics = fn(state.params, state.opt,
                                       state.residual, batch)
        return DPState(params, opt, res), metrics

    return init, step
