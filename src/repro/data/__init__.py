"""GJ-fed data pipeline (DESIGN.md §4): relational corpus -> GFJS ->
range-sharded streaming desummarization -> token batches."""

from repro.data.pipeline import JoinCorpus, TokenBatcher

__all__ = ["JoinCorpus", "TokenBatcher"]
