"""The data pipeline where GJ is a first-class feature.

Production LM corpora are relational: documents join quality scores, topic
tags, dedup clusters, license bits.  Assembling a training mixture is an
n-way many-to-many join whose *flat* result is enormously redundant —
exactly the workload GJ targets.  The pipeline therefore:

1. runs GJ once (anywhere) and ships the tiny GFJS to every data host
   (compute-and-reuse: the paper's Table 2/3/4 scenario);
2. each host materializes ONLY its own row-range via
   ``desummarize_range`` — the beyond-paper random-access property
   (DESIGN.md §7), making the expansion embarrassingly parallel and
   deterministic under any host count (elastic re-sharding of data);
3. join rows become token streams through a stateless feature hash, so the
   whole pipeline is checkpointable by storing just (epoch, cursor).

``TokenBatcher`` holds the deterministic iteration state that the trainer
checkpoints and restores bit-exactly (tests/test_ft.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.api import GraphicalJoin
from repro.core.gfjs import GFJS, desummarize_range
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog


@dataclass
class JoinCorpus:
    """A GFJS plus the mapping from join rows to token sequences."""

    gfjs: GFJS
    vocab: int
    tokens_per_row: int = 16

    @staticmethod
    def build(catalog: Catalog, query: JoinQuery, *, vocab: int,
              tokens_per_row: int = 16) -> "JoinCorpus":
        gj = GraphicalJoin(catalog, query)
        gfjs = gj.run()
        return JoinCorpus(gfjs, vocab, tokens_per_row)

    @property
    def num_rows(self) -> int:
        return self.gfjs.join_size

    def host_range(self, host: int, num_hosts: int) -> Tuple[int, int]:
        """Contiguous row range owned by this host (balanced +-1)."""
        n = self.num_rows
        base, rem = divmod(n, num_hosts)
        lo = host * base + min(host, rem)
        hi = lo + base + (1 if host < rem else 0)
        return lo, hi

    def rows_to_tokens(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        """[rows, tokens_per_row] int32 via a stateless feature hash."""
        names = sorted(cols)
        n = len(cols[names[0]])
        acc = np.zeros(n, np.uint64)
        for i, v in enumerate(names):
            c = cols[v].astype(np.uint64)
            acc ^= (c + np.uint64(0x9E3779B97F4A7C15)) * np.uint64(2 * i + 1)
        out = np.empty((n, self.tokens_per_row), np.int32)
        state = acc
        for t in range(self.tokens_per_row):
            state = state * np.uint64(6364136223846793005) + np.uint64(1442695040888963407)
            out[:, t] = (state >> np.uint64(33)).astype(np.int64) % self.vocab
        return out

    def materialize_range(self, lo: int, hi: int) -> np.ndarray:
        cols = desummarize_range(self.gfjs, lo, hi, decode=False)
        return self.rows_to_tokens(cols)


@dataclass
class TokenBatcher:
    """Deterministic, checkpointable batch iterator over a host's range."""

    corpus: JoinCorpus
    batch: int
    seq: int
    host: int = 0
    num_hosts: int = 1
    cursor: int = 0          # rows consumed within this host's range (state)
    epoch: int = 0

    def state(self) -> Dict[str, int]:
        return {"cursor": self.cursor, "epoch": self.epoch}

    def load_state(self, state: Dict[str, int]) -> None:
        self.cursor = int(state["cursor"])
        self.epoch = int(state["epoch"])

    def next_batch(self) -> Dict[str, np.ndarray]:
        lo, hi = self.corpus.host_range(self.host, self.num_hosts)
        tokens_needed = self.batch * (self.seq + 1)
        rows_needed = -(-tokens_needed // self.corpus.tokens_per_row)
        toks: list = []
        got = 0
        while got < tokens_needed:
            start = lo + self.cursor
            take = min(rows_needed, hi - start)
            if take <= 0:
                self.cursor = 0
                self.epoch += 1
                continue
            t = self.corpus.materialize_range(start, start + take)
            self.cursor += take
            toks.append(t.reshape(-1))
            got += t.size
        flat = np.concatenate(toks)[:tokens_needed]
        arr = flat.reshape(self.batch, self.seq + 1)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}
