import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x applicable input shape x mesh) cell, jit-lower and
COMPILE the corresponding step function against ShapeDtypeStruct inputs on
the production mesh — 16x16=256 chips single-pod and (2,16,16)=512 chips
multi-pod — and record:

  * compiled.memory_analysis()  (proves the cell fits per-device HBM)
  * compiled.cost_analysis()    (HLO flops/bytes for the roofline)
  * collective bytes parsed from the compiled HLO text, by collective kind

Results land in experiments/dryrun/<arch>--<shape>--<mesh>.json; the
roofline report (launch/roofline.py) and EXPERIMENTS.md are generated from
those files.  Any sharding mismatch, compile OOM, or unsupported collective
fails the cell — those are bugs in the framework, not in the cell.

NOTE the first two lines of this file: jax fixes the device count at first
init, so the XLA_FLAGS override must precede every other import (including
repro.*), and must NOT be set globally (smoke tests/benches see 1 device).
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, skip_reason
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dtype, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand sizes of every collective op in the compiled HLO."""
    out = {k: 0 for k in COLLECTIVE_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in COLLECTIVE_KINDS:
            # match the op invocation, e.g. "= bf16[..] all-reduce(bf16[..] %x"
            marker = f" {kind}("
            if marker in s and not s.startswith("//"):
                # operand shapes: inside the call parens
                call = s.split(marker, 1)[1]
                shapes = _SHAPE_RE.findall("(" + call)
                nbytes = 0
                for dtype, dims in shapes:
                    n = 1
                    if dims:
                        for d in dims.split(","):
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[dtype]
                if nbytes == 0:  # fall back to the result shape
                    m = _SHAPE_RE.search(s)
                    nbytes = _shape_bytes(m) if m else 0
                out[kind] += nbytes
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in COLLECTIVE_KINDS)
    return out


def run_cell(arch: str, shape: str, multi_pod: bool,
             rules=None, overrides=None, preset: str = "default",
             out_dir=None, suffix: str = "") -> Dict:
    import contextlib

    from jax.sharding import PartitionSpec as P

    from repro.dist.act_sharding import use as use_act_sharding
    from repro.dist.sharding import SP_FSDP_RULES

    mesh = make_production_mesh(multi_pod=multi_pod)
    act_ctx = contextlib.nullcontext()
    if preset == "sp_fsdp":
        rules = SP_FSDP_RULES
        baxes = ("pod", "data") if multi_pod else ("data",)
        act_ctx = use_act_sharding(mesh, P(baxes if len(baxes) > 1
                                           else baxes[0], "model"))
    t0 = time.time()
    fn, args, shardings, lm, cfg, kind = build_cell(arch, shape, mesh,
                                                    rules=rules,
                                                    overrides=overrides)
    with mesh, act_ctx:
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        text = compiled.as_text()

    coll = collective_bytes(text)
    # trip-count-aware re-analysis: XLA's cost_analysis counts while bodies
    # once (scan-over-layers would be L-times under-reported)
    corrected = analyze_hlo(text)
    hlo_path = None
    if out_dir is not None:
        import zstandard
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        hlo_path = os.path.join(out_dir,
                                f"{arch}--{shape}--{mesh_name}{suffix}.hlo.zst")
        with open(hlo_path, "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(text.encode()))
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "kind": kind,
        "devices": int(mesh.devices.size),
        "seconds_to_compile": round(time.time() - t0, 1),
        "flops": corrected["flops"],
        "bytes_accessed": corrected["bytes"],
        "flops_xla_raw": float(cost.get("flops", -1.0)) if cost else -1.0,
        "bytes_xla_raw": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        "memory": {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")
        } if mem is not None else {},
        "collectives": {**{k: corrected["collectives"].get(k, 0.0)
                           for k in COLLECTIVE_KINDS},
                        "total": corrected["collectives"]["total"],
                        "count": coll["count"],
                        "uncorrected_total": coll["total"]},
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "hlo_collective_lines": coll["count"],
    }
    return result


def reanalyze(out_dir: str) -> None:
    """Recompute corrected metrics from stored HLO without recompiling."""
    import glob

    import zstandard
    d = zstandard.ZstdDecompressor()
    n = 0
    for hlo in sorted(glob.glob(os.path.join(out_dir, "*.hlo.zst"))):
        jpath = hlo[: -len(".hlo.zst")] + ".json"
        if not os.path.exists(jpath):
            continue
        with open(hlo, "rb") as f:
            text = d.decompress(f.read(), max_output_size=1 << 32).decode()
        corrected = analyze_hlo(text)
        with open(jpath) as f:
            res = json.load(f)
        res["flops"] = corrected["flops"]
        res["bytes_accessed"] = corrected["bytes"]
        res["collectives"] = {
            **{k: corrected["collectives"].get(k, 0.0)
               for k in COLLECTIVE_KINDS},
            "total": corrected["collectives"]["total"],
            "count": res["collectives"].get("count", -1),
            "uncorrected_total": res["collectives"].get("uncorrected_total", -1),
        }
        with open(jpath, "w") as f:
            json.dump(res, f, indent=1)
        n += 1
        print(f"reanalyzed {os.path.basename(jpath)}", flush=True)
    print(f"{n} cells reanalyzed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--preset", default="default",
                    choices=["default", "sp_fsdp"],
                    help="sharding preset (sp_fsdp = context parallel + "
                         "FSDP, the §Perf LM-1 configuration)")
    ap.add_argument("--suffix", default="",
                    help="suffix for output filenames (hillclimb variants)")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute metrics from stored HLO, no recompiling")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze(args.out)
        return

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    total = ok = failed = skipped = 0
    for arch in archs:
        shapes = (list(SHAPES) if args.shape == "all" else [args.shape])
        for shape in shapes:
            reason = skip_reason(arch, shape)
            if reason:
                print(f"SKIP  {arch:22s} {shape:12s} -- {reason}", flush=True)
                skipped += 1
                continue
            for mp in meshes:
                total += 1
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(
                    args.out, f"{arch}--{shape}--{mesh_name}{args.suffix}.json")
                if os.path.exists(path) and not args.force:
                    print(f"CACHED {arch:22s} {shape:12s} {mesh_name}", flush=True)
                    ok += 1
                    continue
                try:
                    res = run_cell(arch, shape, mp, preset=args.preset,
                                   out_dir=args.out, suffix=args.suffix)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    ok += 1
                    print(f"OK    {arch:22s} {shape:12s} {mesh_name} "
                          f"compile={res['seconds_to_compile']}s "
                          f"flops={res['flops']:.3g} "
                          f"coll={res['collectives']['total']:.3g}B", flush=True)
                except Exception as e:  # noqa: BLE001
                    failed += 1
                    err = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    with open(path + ".err", "w") as f:
                        json.dump(err, f, indent=1)
                    print(f"FAIL  {arch:22s} {shape:12s} {mesh_name} -- "
                          f"{type(e).__name__}: {str(e)[:200]}", flush=True)
    print(f"\ndry-run: {ok}/{total} compiled, {failed} failed, "
          f"{skipped} skipped (documented)", flush=True)


if __name__ == "__main__":
    main()
