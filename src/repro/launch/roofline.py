"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch x shape x mesh) cell, compute the three roofline terms:

  compute    = HLO_FLOPs      / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes      / (chips * 819e9  B/s HBM)
  collective = collective_B   / (chips * 50e9   B/s ICI link)

plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for train cells
(2*N*D for single forward / decode), the usefulness ratio
MODEL_FLOPS / HLO_FLOPs, the dominant term, and a one-line "what would move
it" note.  The dry-run's cost_analysis reports *per-device* numbers for the
SPMD-partitioned module, so terms divide by per-chip peaks directly.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun \
      [--markdown experiments/roofline.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12       # bf16 per chip (TPU v5e)
HBM_BW = 819e9            # B/s per chip
LINK_BW = 50e9            # B/s per ICI link

from repro.configs import SHAPES, get_config


def model_flops(arch: str, shape: str, kind: str) -> float:
    cfg = get_config(arch)
    seq, batch, _ = SHAPES[shape]
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * batch


def analyze(cell: Dict) -> Dict:
    chips = cell["devices"]
    # cost_analysis flops are per-device for the partitioned module
    flops_dev = max(cell["flops"], 0.0)
    bytes_dev = max(cell["bytes_accessed"], 0.0)
    coll_dev = cell["collectives"]["total"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cell["arch"], cell["shape"], cell["kind"])
    total_hlo_flops = flops_dev * chips
    useful = mf / total_hlo_flops if total_hlo_flops > 0 else 0.0

    bound = max(terms.values())
    # roofline fraction: useful model flops against the peak-compute bound
    # of the *critical* resource time
    frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0

    hints = {
        "compute": "reduce non-model FLOPs (remat recompute, capacity "
                   "padding) or raise MXU utilization via tile alignment",
        "memory": "fuse/keep activations in VMEM, bf16 more intermediates, "
                  "better BlockSpec tiling; check remat policy",
        "collective": "re-shard to cut all-gathers (FSDP prefetch overlap, "
                      "TP only where weights amortize), overlap with compute",
    }
    return {
        **{k: cell[k] for k in ("arch", "shape", "mesh", "kind", "devices")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": total_hlo_flops,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hint": hints[dominant],
        "collective_breakdown": cell["collectives"],
        "memory": cell.get("memory", {}),
    }


def load_cells(directory: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def to_markdown(rows: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = [analyze(c) for c in load_cells(args.dir)]
    md = to_markdown(rows)
    print(md)
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(md + "\n")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
