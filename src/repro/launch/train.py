"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --smoke \
        --steps 100 --batch 8 --seq 64 [--microbatches 2] [--resume]

Full-scale configs launch the same code path on a real TPU fleet; on this
CPU container use --smoke (reduced same-family config).  Data comes from the
GJ-fed pipeline (a synthetic relational corpus joined by GJ).
"""

from __future__ import annotations

import argparse

from repro.configs import get_config, get_smoke
from repro.data.pipeline import JoinCorpus, TokenBatcher
from repro.models.model import LM
from repro.relational.synth import lastfm_like
from repro.train.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    lm = LM(cfg)

    cat, queries = lastfm_like(n_users=500, n_artists=400,
                               artists_per_user=8, friends_per_user=4)
    corpus = JoinCorpus.build(cat, queries["lastfm_A1"], vocab=cfg.vocab)
    batcher = TokenBatcher(corpus, batch=args.batch, seq=args.seq)

    trainer = Trainer(
        lm,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps),
        batcher,
        TrainerConfig(steps=args.steps, checkpoint_every=args.checkpoint_every,
                      checkpoint_dir=args.checkpoint_dir,
                      log_every=max(args.steps // 10, 1),
                      microbatches=args.microbatches),
    )
    trainer.run()
    for m in trainer.metrics_log:
        print(f"step {m['step']:>5d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}")


if __name__ == "__main__":
    main()
