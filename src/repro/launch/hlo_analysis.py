"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model under-reports flops/bytes by the layer count (we
measured 8x on an 8-step scan microtest).  This analyzer re-derives the three
roofline inputs directly from the compiled HLO text:

  * flops            — every ``dot`` op: 2 * |result| * |contraction dims|,
  * memory bytes     — per top-level op: operand + result bytes.  Compiled
                       HLO is fused, so call-site traffic of fusion ops is a
                       faithful HBM model (fusion internals stay on-chip),
  * collective bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,

each multiplied by the product of enclosing while trip counts, which the
CPU/TPU backends conveniently record as ``backend_config=
{"known_trip_count":{"n":...}}``.  Validated against an unrolled-vs-scanned
matmul (tests/test_roofline.py): both report identical flops.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    result_type: str
    kind: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symtab: Dict[str, str] = field(default_factory=dict)  # %name -> type str


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_OP_KIND = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}\- ])*?)\s*([\w\-]+)\(")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        if not raw:
            continue
        if not raw.startswith(" "):
            m = _COMP_HEADER.match(raw)
            if m and raw.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if raw.startswith("ENTRY"):
                    entry = cur.name
                # header params: "name: TYPE, name2: TYPE2"
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\)|[^,)]+))",
                                      m.group(2)):
                    cur.symtab[pm.group(1)] = pm.group(2)
            elif raw.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(raw)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        km = _OP_KIND.match(rest)
        if not km:
            cur.symtab[name] = rest
            continue
        result_type, kind = km.group(1).strip(), km.group(2)
        # operand span: between the first '(' after kind and its match
        start = rest.index(kind + "(") + len(kind) + 1
        depth, i = 1, start
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_span = rest[start:i - 1]
        attrs = rest[i:]
        operands = re.findall(r"%([\w.\-]+)", operand_span)
        op = Op(name, result_type, kind, operands, attrs)
        cur.ops.append(op)
        cur.symtab[name] = result_type
    return comps, entry


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLED_ONE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CALLED_LIST = re.compile(r"(?:branch_computations|called_computations)="
                          r"\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def extract_called(attrs: str) -> List[str]:
    out = [m.group(1) for m in _CALLED_ONE.finditer(attrs)]
    for m in _CALLED_LIST.finditer(attrs):
        out.extend(c.strip().lstrip("%") for c in m.group(1).split(",") if c.strip())
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    res = _shape_dims(op.result_type)
    if not res:
        return 0.0
    relems = 1
    for d in res[0][1]:
        relems *= d
    cm = _CONTRACT.search(op.attrs)
    contraction = 1
    if cm and op.operands:
        lhs_type = comp.symtab.get(op.operands[0], "")
        lshape = _shape_dims(lhs_type)
        if lshape:
            dims = lshape[0][1]
            for ci in (int(c) for c in cm.group(1).split(",") if c):
                if ci < len(dims):
                    contraction *= dims[ci]
    return 2.0 * relems * contraction


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota"}

# ops that only touch the bytes they produce/consume locally, NOT their full
# operands (a dynamic-slice of a stacked [L, ...] parameter inside a scan
# reads one slice per step, not the whole stack)
_SLICE_LIKE = {"dynamic-slice", "slice", "gather", "broadcast", "reshape",
               "transpose", "reverse", "pad"}
_UPDATE_LIKE = {"dynamic-update-slice", "scatter", "select-and-scatter"}


def _op_bytes(op: "Op", comp: "Computation") -> float:
    if op.kind in _SLICE_LIKE:
        # read what you produce + (tiny) indices
        return 2.0 * _nbytes(op.result_type)
    if op.kind in _UPDATE_LIKE:
        # read + write the update region (the big operand is aliased)
        upd = _nbytes(comp.symtab.get(op.operands[1], ""))             if len(op.operands) > 1 else 0
        return 2.0 * upd + _nbytes(op.result_type) * 0.0 if upd else             2.0 * _nbytes(op.result_type)
    if op.kind in ("while", "call"):
        return 0.0          # pass-through: the callee's traffic is counted
    b = _nbytes(op.result_type)
    for o in op.operands:
        b += _nbytes(comp.symtab.get(o, ""))
    return float(b)


def analyze_hlo(text: str) -> Dict[str, float]:
    comps, entry = parse_module(text)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}}

    memo: Dict[str, Dict[str, float]] = {}

    def walk(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        acc = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
        coll: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
        if comp is None:
            memo[name] = {**acc, "per_kind": coll}
            return memo[name]
        memo[name] = {**acc, "per_kind": coll}   # break cycles
        for op in comp.ops:
            if op.kind == "dot":
                acc["flops"] += _dot_flops(op, comp)
            if op.kind not in _SKIP_BYTES:
                acc["bytes"] += _op_bytes(op, comp)
            if op.kind in COLLECTIVE_KINDS:
                b = sum(_nbytes(comp.symtab.get(o, "")) for o in op.operands)
                if b == 0:
                    b = _nbytes(op.result_type)
                acc["collective_bytes"] += b
                coll[op.kind] += b
            # recurse into called computations
            called = extract_called(op.attrs)
            if op.kind == "fusion":
                called = []          # fusion internals stay on-chip
            if op.kind == "while":
                tm = _TRIP_RE.search(op.attrs)
                trip = float(tm.group(1)) if tm else 1.0
                body_cond = extract_called(op.attrs)
                for c in body_cond:
                    sub = walk(c)
                    for k in acc:
                        acc[k] += trip * sub[k]
                    for k in COLLECTIVE_KINDS:
                        coll[k] += trip * sub["per_kind"][k]
                called = []
            for c in called:
                sub = walk(c)
                for k in acc:
                    acc[k] += sub[k]
                for k in COLLECTIVE_KINDS:
                    coll[k] += sub["per_kind"][k]
        memo[name] = {**acc, "per_kind": coll}
        return memo[name]

    res = walk(entry)
    out = {"flops": res["flops"], "bytes": res["bytes"],
           "collective_bytes": res["collective_bytes"],
           "collectives": dict(res["per_kind"])}
    out["collectives"]["total"] = res["collective_bytes"]
    return out
