import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Diagnose one dry-run cell: top collectives / dots by amplified bytes.

  PYTHONPATH=src python -m repro.launch.inspect_cell --arch starcoder2_3b \
      --shape train_4k [--multi-pod] [--top 15]

Prints each hot op with its enclosing while amplification, shapes, and the
jax op_name metadata — the evidence §Perf hypotheses are built from.
"""

import argparse
import re
from typing import Dict, List, Tuple

import jax

from repro.launch.hlo_analysis import (COLLECTIVE_KINDS, _TRIP_RE, _nbytes,
                                       extract_called, parse_module)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

_META = re.compile(r'op_name="([^"]+)"')


def collect_hot_ops(text: str, *, kinds=COLLECTIVE_KINDS) -> List[Dict]:
    comps, entry = parse_module(text)

    # amplification per computation: product of trip counts on the path
    amp: Dict[str, float] = {entry: 1.0}
    stack = [entry]
    while stack:
        name = stack.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        for op in comp.ops:
            called = extract_called(op.attrs)
            if op.kind == "fusion":
                continue
            mult = 1.0
            if op.kind == "while":
                tm = _TRIP_RE.search(op.attrs)
                mult = float(tm.group(1)) if tm else 1.0
            for c in called:
                a = amp[name] * mult
                if amp.get(c, 0) < a:
                    amp[c] = a
                    stack.append(c)

    out = []
    for cname, comp in comps.items():
        a = amp.get(cname, 0.0)
        if a == 0:
            continue
        for op in comp.ops:
            if op.kind not in kinds:
                continue
            b = sum(_nbytes(comp.symtab.get(o, "")) for o in op.operands) \
                or _nbytes(op.result_type)
            meta = _META.search(op.attrs)
            out.append({
                "kind": op.kind, "bytes": b, "amp": a,
                "total": b * a, "comp": cname,
                "result": op.result_type[:60],
                "op_name": meta.group(1) if meta else "?",
            })
    out.sort(key=lambda d: -d["total"])
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--kinds", default="collectives",
                    choices=["collectives", "dot"])
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, cargs, shardings, lm, cfg, kind = build_cell(args.arch, args.shape, mesh)
    with mesh:
        text = jax.jit(fn, in_shardings=shardings).lower(*cargs) \
            .compile().as_text()
    kinds = COLLECTIVE_KINDS if args.kinds == "collectives" else ("dot",)
    rows = collect_hot_ops(text, kinds=kinds)
    total = sum(r["total"] for r in rows)
    print(f"total {args.kinds} bytes (amplified): {total:.3e}")
    for r in rows[:args.top]:
        print(f"{r['total']:.3e}B  {r['kind']:18s} amp={r['amp']:<6.0f} "
              f"per={r['bytes']:.2e}B  {r['result']:30s} {r['op_name'][:90]}")


if __name__ == "__main__":
    main()
