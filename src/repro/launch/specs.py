"""Abstract input specs + shardings for every (arch x shape x mesh) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — and
``cell_functions`` builds the function the dry-run lowers for each shape
kind (train_step / prefill or encode / decode).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.dist.sharding import DEFAULT_RULES, ShardingRules, param_specs
from repro.models.config import ModelConfig
from repro.models.model import LM
from repro.train.optim import AdamWConfig, init_state
from repro.train.train_step import TrainState, make_train_step


def arch_rules(cfg: ModelConfig, mesh: Mesh,
               base: ShardingRules = DEFAULT_RULES) -> ShardingRules:
    """Per-arch rule adjustments for divisibility: if heads don't divide the
    model axis, shard head_dim instead (gemma3: 8 heads on a 16-way axis)."""
    model_size = mesh.shape.get("model", 1)
    rules = base
    if cfg.num_heads % model_size != 0:
        rules = rules.with_overrides(heads=None, kv_heads=None,
                                     head=("model",))
    elif cfg.num_kv_heads % model_size != 0:
        rules = rules.with_overrides(kv_heads=None)
    return rules


def batch_struct(cfg: ModelConfig, batch: int, seq: int,
                 *, labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct((batch, seq, 512), jnp.float32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if labels:
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.family == "vlm":
        out["vision"] = jax.ShapeDtypeStruct(
            (batch, cfg.vlm.num_image_tokens, cfg.vlm.vision_dim), jnp.float32)
    return out


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch: int):
    baxes = [a for a in ("pod", "data") if a in mesh.axis_names]
    bsz = 1
    for a in baxes:
        bsz *= mesh.shape[a]
    bspec = tuple(baxes) if (baxes and batch % bsz == 0 and batch > 1) else None
    if bspec is not None and len(bspec) == 1:
        bspec = bspec[0]

    def spec_of(s: jax.ShapeDtypeStruct):
        parts = [bspec] + [None] * (len(s.shape) - 1)
        return NamedSharding(mesh, P(*parts))

    return spec_of


def cache_shardings(mesh: Mesh, batch: int):
    """Heuristic cache specs: leading dim = stacked layers (never sharded),
    dim1 = batch (shard over data axes if divisible), then the largest
    remaining dim sharded over 'model' if divisible."""
    baxes = [a for a in ("pod", "data") if a in mesh.axis_names]
    bsz = 1
    for a in baxes:
        bsz *= mesh.shape[a]
    model = mesh.shape.get("model", 1)

    def spec_of(leaf: jax.ShapeDtypeStruct):
        shape = leaf.shape
        parts: list = [None] * len(shape)
        if len(shape) >= 3:
            if shape[1] == batch and batch % bsz == 0 and batch > 1 and baxes:
                parts[1] = tuple(baxes) if len(baxes) > 1 else baxes[0]
            # largest remaining dim onto 'model'
            cand = [(shape[i], i) for i in range(2, len(shape))
                    if shape[i] % model == 0 and shape[i] >= model]
            if cand and model > 1:
                _, i = max(cand)
                parts[i] = "model"
        return NamedSharding(mesh, P(*parts))

    return spec_of


def abstract_state(lm: LM) -> TrainState:
    params = lm.abstract_params()
    opt = jax.eval_shape(init_state, params)
    return TrainState(params, opt)


def abstract_caches(lm: LM, batch: int, s_max: int):
    return jax.eval_shape(lambda: lm.init_caches(batch, s_max))


def state_shardings(lm: LM, mesh: Mesh, rules: ShardingRules):
    specs = param_specs(lm.logical_axes(), mesh, rules)
    pshard = {k: NamedSharding(mesh, s) for k, s in specs.items()}
    rep = NamedSharding(mesh, P())
    opt = jax.eval_shape(init_state, lm.abstract_params())
    mshard = {k: NamedSharding(mesh, specs[k]) for k in opt.m}
    vshard = {k: NamedSharding(mesh, specs[k]) for k in opt.v}
    from repro.train.optim import AdamWState
    return TrainState(pshard, AdamWState(rep, mshard, vshard))


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               rules: Optional[ShardingRules] = None,
               overrides: Optional[dict] = None):
    """Returns (fn, args, in_shardings, lm, cfg, kind) for one grid cell."""
    seq, batch, kind = SHAPES[shape_name]
    cfg = get_config(arch)
    if cfg.family == "hybrid" and cfg.ssm is not None and kind != "decode":
        pass
    cfg = cfg.scaled(max_seq=max(cfg.max_seq, seq))
    if overrides:
        cfg = cfg.scaled(**overrides)
    lm = LM(cfg)
    rules = rules or arch_rules(cfg, mesh)

    if kind == "train":
        step = make_train_step(lm, AdamWConfig())
        state = abstract_state(lm)
        batch_s = batch_struct(cfg, batch, seq, labels=True)
        st_sh = state_shardings(lm, mesh, rules)
        b_sh = jax.tree.map(batch_shardings(cfg, mesh, batch), batch_s)
        return step, (state, batch_s), (st_sh, b_sh), lm, cfg, kind

    if kind == "prefill":
        if cfg.is_encoder_only or cfg.family == "audio":
            def encode(params, b):
                return lm.forward(params, b)
            fn = encode
        else:
            def fn(params, b):
                return lm.prefill(params, b, s_max=seq)
        params = lm.abstract_params()
        batch_s = batch_struct(cfg, batch, seq, labels=False)
        specs = param_specs(lm.logical_axes(), mesh, rules)
        p_sh = {k: NamedSharding(mesh, s) for k, s in specs.items()}
        b_sh = jax.tree.map(batch_shardings(cfg, mesh, batch), batch_s)
        return fn, (params, batch_s), (p_sh, b_sh), lm, cfg, kind

    if kind == "decode":
        def fn(params, tokens, caches, **kw):
            return lm.decode_step(params, tokens, caches, **kw)
        params = lm.abstract_params()
        tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        caches = abstract_caches(lm, batch, seq)
        specs = param_specs(lm.logical_axes(), mesh, rules)
        p_sh = {k: NamedSharding(mesh, s) for k, s in specs.items()}
        t_sh = batch_shardings(cfg, mesh, batch)(tokens)
        c_sh = jax.tree.map(cache_shardings(mesh, batch), caches)
        args = (params, tokens, caches)
        shardings = (p_sh, t_sh, c_sh)
        if cfg.family == "vlm":
            vis = jax.ShapeDtypeStruct(
                (batch, cfg.vlm.num_image_tokens, cfg.vlm.vision_dim),
                jnp.float32)
            def fn(params, tokens, caches, vision):
                return lm.decode_step(params, tokens, caches, vision=vision)
            args = (params, tokens, caches, vis)
            shardings = (p_sh, t_sh, c_sh, batch_shardings(cfg, mesh, batch)(vis))
        return fn, args, shardings, lm, cfg, kind

    raise ValueError(kind)
