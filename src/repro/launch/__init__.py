"""Launchers: mesh construction, the multi-pod dry-run, train/serve CLIs."""
