"""Serving launcher: batched generation with the KV-cache engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_8b --smoke \
        --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models.model import LM
from repro.serve.engine import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only; no decode path")
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.vlm.num_image_tokens,
                             cfg.vlm.vision_dim)), jnp.float32)

    engine = ServeEngine(
        lm, params, ServeConfig(max_seq=args.prompt_len + args.max_new,
                                temperature=args.temperature))
    out = engine.generate(batch, max_new=args.max_new, seed=1)
    for i, row in enumerate(out):
        print(f"request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
