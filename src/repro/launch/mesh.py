"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
forces 512 host devices via XLA_FLAGS before any jax import, while smoke
tests and benchmarks must keep seeing 1 CPU device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (hillclimb sharding experiments use this)."""
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever devices exist locally, as ('data', 'model')."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
