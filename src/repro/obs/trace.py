"""Process-wide span tracing for the GJ pipeline (DESIGN.md §16).

One :class:`Tracer` collects nested, thread-safe spans across every
pipeline stage — plan search, model build, per-step elimination, GFJS
generation levels, kernel launches, cache traffic, shard pipelines — and
exports them as Chrome trace-event JSON (load the file at
https://ui.perfetto.dev or chrome://tracing).

Two ways into a span:

* **Handle** — a component holding a tracer calls ``tracer.span(name)``.
  Entering the span installs it as the *ambient* span for the dynamic
  extent, so nested code needs no plumbing.
* **Ambient** — library code (core elimination, kernels, cache) calls the
  module-level :func:`span`.  When no tracer is active this returns a
  shared no-op context whose entire cost is one ``ContextVar.get`` — the
  near-zero-overhead short-circuit that keeps untraced runs at untraced
  speed.

Ambient context does NOT cross thread boundaries (each worker thread of a
pool starts with no active span): cross-thread nesting is an **explicit
parent handoff** — the coordinator captures its span object and workers
open their spans with ``tracer.span(name, parent=that_span)``.  The
sharded-build pool in ``plan/executor.py`` is the canonical example.

Spans opened with ``device=True`` additionally enter a
``jax.profiler.TraceAnnotation`` of the same name *if jax is already
imported* (never importing it — planning stays jax-free), so host spans
line up with device traces captured by the jax profiler.
"""

from __future__ import annotations

import contextvars
import itertools
import json
import os
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# (tracer, span) of the innermost active span in this context; None when
# tracing is off — the single check every no-op span call pays
_STATE: "contextvars.ContextVar[Optional[Tuple[Tracer, Span]]]" = \
    contextvars.ContextVar("repro_obs_state", default=None)

_IDS = itertools.count(1)          # CPython-atomic span id source


@dataclass
class Span:
    """One timed region.  ``args`` may be annotated until export."""

    name: str
    cat: str
    span_id: int
    parent_id: Optional[int]
    tid: int
    t0: float = 0.0                # perf_counter seconds
    t1: float = 0.0
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def seconds(self) -> float:
        return self.t1 - self.t0

    def set(self, **kw: Any) -> "Span":
        """Attach attributes (drift, product sizes, shard ids, ...)."""
        self.args.update(kw)
        return self


class _NullSpan:
    """Shared do-nothing span + context manager (tracing disabled)."""

    __slots__ = ()
    name = cat = ""
    span_id = None
    parent_id = None
    seconds = 0.0

    def set(self, **kw: Any) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


NULL_SPAN = _NullSpan()

_AMBIENT = object()                # sentinel: resolve parent from context


class _SpanCtx:
    """Context manager that opens/closes one span on a tracer."""

    __slots__ = ("_tracer", "_span", "_token", "_device", "_annot")

    def __init__(self, tracer: "Tracer", span: Span, device: bool) -> None:
        self._tracer = tracer
        self._span = span
        self._token = None
        self._device = device
        self._annot = None

    def __enter__(self) -> Span:
        sp = self._span
        sp.tid = threading.get_ident()
        self._token = _STATE.set((self._tracer, sp))
        if self._device:
            annot = _device_annotation(sp.name)
            if annot is not None:
                annot.__enter__()
                self._annot = annot
        sp.t0 = self._tracer.clock()
        return sp

    def __exit__(self, *exc) -> None:
        sp = self._span
        sp.t1 = self._tracer.clock()
        if self._annot is not None:
            self._annot.__exit__(*exc)
            self._annot = None
        _STATE.reset(self._token)
        self._tracer._record(sp)


def _device_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` if jax is already loaded.

    Deliberately ``sys.modules``-gated: tracing a numpy-only run must not
    drag the jax import in (tests pin that planning stays jax-free).
    """
    jx = sys.modules.get("jax")
    if jx is None:
        return None
    try:
        return jx.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover - partially initialized jax
        return None


class Tracer:
    """Collects finished spans; thread-safe; exports Chrome trace JSON."""

    def __init__(self, clock=time.perf_counter) -> None:
        self.clock = clock
        self.epoch = clock()
        self._lock = threading.Lock()
        self._spans: List[Span] = []

    # -- span lifecycle ----------------------------------------------------
    def span(self, name: str, *, cat: str = "op", parent: Any = _AMBIENT,
             device: bool = False, **args: Any) -> _SpanCtx:
        """Open a span (use as a context manager).

        ``parent`` defaults to the ambient span of *this* tracer in the
        current context; pass a :class:`Span` explicitly to hand a parent
        across a thread boundary (shard pools), or ``None`` to force a
        root span.
        """
        if parent is _AMBIENT:
            state = _STATE.get()
            parent = state[1] if state is not None and state[0] is self \
                else None
        pid = parent.span_id if isinstance(parent, Span) else None
        sp = Span(name=name, cat=cat, span_id=next(_IDS), parent_id=pid,
                  tid=0, args=dict(args))
        return _SpanCtx(self, sp, device)

    def _record(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)

    # -- introspection -----------------------------------------------------
    @property
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def find(self, name: str) -> List[Span]:
        """Finished spans whose name equals ``name`` or starts with
        ``name`` up to a ``:`` separator (``find("shard")`` -> shard:0...)."""
        return [s for s in self.spans
                if s.name == name or s.name.startswith(name + ":")]

    # -- export ------------------------------------------------------------
    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (complete "X" events, us timestamps).

        Spans nest visually in Perfetto by time containment per (pid,
        tid) track; parent/child identity additionally rides in ``args``
        (``span_id`` / ``parent_id``) for programmatic consumers.
        """
        pid = os.getpid()
        spans = self.spans
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "graphical-join"},
        }]
        for tid in sorted({s.tid for s in spans}):
            events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": f"thread-{tid}"},
            })
        for s in sorted(spans, key=lambda s: s.t0):
            args = {k: _jsonable(v) for k, v in s.args.items()}
            args["span_id"] = s.span_id
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": (s.t0 - self.epoch) * 1e6,
                "dur": max((s.t1 - s.t0) * 1e6, 0.0),
                "pid": pid, "tid": s.tid, "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path

    # -- cross-process stitching -------------------------------------------
    def records(self) -> List[Dict[str, Any]]:
        """Finished spans as plain JSON-able dicts (the shard-action wire
        format's span payload).  Times are this tracer's ``perf_counter``
        values — meaningless in another process until :meth:`graft`
        rebases them."""
        return [{"name": s.name, "cat": s.cat, "span_id": s.span_id,
                 "parent_id": s.parent_id, "tid": s.tid,
                 "t0": s.t0, "t1": s.t1,
                 "args": {k: _jsonable(v) for k, v in s.args.items()}}
                for s in self.spans]

    def graft(self, records: List[Dict[str, Any]], *, parent: Any = None,
              offset: float = 0.0) -> List[Span]:
        """Re-home span records from another process under ``parent``.

        Every record gets a fresh span id from this process's counter;
        parent links *within* the record set are remapped, records whose
        parent is unknown (the worker's root) attach to ``parent``
        (a :class:`Span`, or None for top-level).  ``offset`` is added to
        every timestamp — the coordinator computes it so the worker's
        clock lands inside the observed dispatch window (the two
        ``perf_counter`` epochs are otherwise incomparable).

        Returns the grafted spans in record order (callers typically keep
        the worker's root to annotate wall/straggler facts onto).
        """
        base = parent.span_id if isinstance(parent, Span) else None
        idmap: Dict[int, int] = {}
        out: List[Span] = []
        for r in records:
            sp = Span(name=r["name"], cat=r.get("cat", "op"),
                      span_id=next(_IDS), parent_id=None,
                      tid=int(r.get("tid", 0)),
                      t0=float(r["t0"]) + offset, t1=float(r["t1"]) + offset,
                      args=dict(r.get("args", {})))
            if r.get("span_id") is not None:
                idmap[r["span_id"]] = sp.span_id
            out.append(sp)
        for r, sp in zip(records, out):
            pid = r.get("parent_id")
            sp.parent_id = idmap.get(pid, base) if pid is not None else base
            self._record(sp)
        return out


def _jsonable(v: Any) -> Any:
    """Coerce numpy scalars etc. so ``json.dump`` never chokes on args."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:  # pragma: no cover - non-scalar .item()
            pass
    return str(v)


# ---------------------------------------------------------------------------
# Ambient API — what library code calls.
# ---------------------------------------------------------------------------

def span(name: str, *, cat: str = "op", device: bool = False, **args: Any):
    """A span on the ambient tracer; the shared no-op when tracing is off."""
    state = _STATE.get()
    if state is None:
        return NULL_SPAN
    return state[0].span(name, cat=cat, device=device, **args)


def current_span() -> Optional[Span]:
    """The innermost active span (for explicit cross-thread handoff)."""
    state = _STATE.get()
    return state[1] if state is not None else None


def ambient_tracer() -> Optional["Tracer"]:
    """The active tracer, if any (components capture it at entry so
    worker threads — which see no ambient context — can still open
    spans with an explicit parent)."""
    state = _STATE.get()
    return state[0] if state is not None else None


def span_in(tracer: Optional["Tracer"], parent: Any, name: str, *,
            cat: str = "op", device: bool = False, **args: Any):
    """``tracer.span`` with an explicit parent, or the no-op when
    ``tracer`` is None — the one-liner worker threads use."""
    if tracer is None:
        return NULL_SPAN
    if isinstance(parent, _NullSpan):
        parent = None
    return tracer.span(name, cat=cat, parent=parent, device=device, **args)
