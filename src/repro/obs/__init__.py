"""Unified tracing + metrics for the GJ pipeline (DESIGN.md §16).

Spans (:mod:`repro.obs.trace`) answer "where did this query's time go"
with a Perfetto-loadable timeline; metrics (:mod:`repro.obs.metrics`)
accumulate the counters and latency distributions that the serving and
plan-feedback layers consume.  Both are stdlib-only and off by default:
without an active :class:`Tracer` the ambient :func:`span` call is a
single ContextVar read returning a shared no-op.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               REGISTRY, TimingsView)
from repro.obs.trace import (NULL_SPAN, Span, Tracer, ambient_tracer,
                             current_span, span, span_in)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "TimingsView", "NULL_SPAN", "Span", "Tracer", "ambient_tracer",
    "current_span", "span", "span_in",
]
