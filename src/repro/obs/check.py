"""Validate an emitted Chrome trace file (CI smoke gate).

    python -m repro.obs.check BENCH_dist.trace.json [--expect-shards]
    python -m repro.obs.check BENCH_serve.trace.json --expect-server
    python -m repro.obs.check BENCH_workload.trace.json --expect-msgcache

Asserts the file parses as Chrome trace-event JSON and contains one span
per executor phase, at least one per-step elimination span carrying
product/drift annotations, and (with ``--expect-shards``) per-shard
spans whose parent is the summarize phase span.  With
``--expect-server`` the trace must additionally profile the serving
front-end: ``server:request`` spans each carrying a ``source``
annotation, and collapsed requests carrying a ``build_span_id`` that
resolves to a real ``server:build`` span — the span-level record of the
latch handoff (DESIGN.md §18).  With ``--expect-msgcache`` the trace
must profile elimination-message reuse (DESIGN.md §20): ``msg:<fp>``
probe spans each carrying ``var`` and ``hit`` annotations, at least one
of them a hit — the span-level proof that a warm build actually skipped
a product.  Exit 0 on success, non-zero with a message on any violation.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

#: Executor phases every traced pipeline run must produce.  Partitioned
#: runs build generators per shard (inside shard spans) and add a
#: partition phase instead of a monolithic build_generator.
REQUIRED_PHASES = ("build_model", "plan", "build_generator", "summarize")
REQUIRED_PHASES_SHARDED = ("build_model", "plan", "partition", "summarize")


def validate(doc: Any, *, expect_shards: bool = False,
             expect_server: bool = False,
             expect_msgcache: bool = False) -> List[str]:
    """Return a list of violations (empty == valid)."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["not a Chrome trace object (missing 'traceEvents')"]
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' is empty"]

    complete = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event[{i}] is not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            errs.append(f"event[{i}] has unsupported ph={ph!r}")
            continue
        if ph == "X":
            for key in ("name", "ts", "dur", "pid", "tid"):
                if key not in ev:
                    errs.append(f"event[{i}] ({ev.get('name')!r}) missing {key!r}")
            if ev.get("dur", 0) < 0:
                errs.append(f"event[{i}] ({ev.get('name')!r}) has negative dur")
            complete.append(ev)

    names = [ev["name"] for ev in complete if "name" in ev]
    required = REQUIRED_PHASES_SHARDED if expect_shards else REQUIRED_PHASES
    for phase in required:
        if f"phase:{phase}" not in names:
            errs.append(f"missing executor phase span 'phase:{phase}'")

    elim = [ev for ev in complete if ev["name"].startswith("eliminate:")]
    if not elim:
        errs.append("no elimination-step spans ('eliminate:<var>')")
    for ev in elim:
        args = ev.get("args", {})
        if "product" not in args:
            errs.append(f"{ev['name']} span missing 'product' annotation")
        if "est" in args and "drift" not in args:
            errs.append(f"{ev['name']} span has est but no 'drift'")

    if expect_shards:
        by_id = {ev.get("args", {}).get("span_id"): ev for ev in complete}
        shards = [ev for ev in complete if ev["name"].startswith("shard:")]
        if not shards:
            errs.append("no per-shard spans ('shard:<i>')")
        for ev in shards:
            pid = ev.get("args", {}).get("parent_id")
            parent = by_id.get(pid)
            if parent is None or parent["name"] != "phase:summarize":
                errs.append(f"{ev['name']} is not parented to phase:summarize")

    if expect_server:
        by_id = {ev.get("args", {}).get("span_id"): ev for ev in complete}
        reqs = [ev for ev in complete if ev["name"] == "server:request"]
        builds = [ev for ev in complete if ev["name"] == "server:build"]
        if not reqs:
            errs.append("no serving spans ('server:request')")
        for ev in reqs:
            if "source" not in ev.get("args", {}):
                errs.append("server:request span missing 'source' annotation")
                break
        collapsed = [ev for ev in reqs
                     if ev.get("args", {}).get("collapsed")]
        if collapsed and not builds:
            errs.append("collapsed server:request spans but no "
                        "'server:build' span")
        for ev in collapsed:
            bid = ev.get("args", {}).get("build_span_id")
            if bid is None:
                continue            # leader ran untraced (null span id)
            build = by_id.get(bid)
            if build is None or build["name"] != "server:build":
                errs.append("collapsed server:request carries build_span_id "
                            f"{bid!r} that is not a server:build span")

    if expect_msgcache:
        probes = [ev for ev in complete if ev["name"].startswith("msg:")]
        if not probes:
            errs.append("no message-cache probe spans ('msg:<fingerprint>')")
        for ev in probes:
            args = ev.get("args", {})
            if "var" not in args:
                errs.append(f"{ev['name']} span missing 'var' annotation")
            if "hit" not in args:
                errs.append(f"{ev['name']} span missing 'hit' annotation")
        if probes and not any(ev.get("args", {}).get("hit")
                              for ev in probes):
            errs.append("msg: probe spans present but none is a hit — "
                        "the warm run never reused a message")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="trace file to validate")
    ap.add_argument("--expect-shards", action="store_true",
                    help="require per-shard spans parented to summarize")
    ap.add_argument("--expect-server", action="store_true",
                    help="require server:request spans with source "
                         "annotations and latch-handoff build links")
    ap.add_argument("--expect-msgcache", action="store_true",
                    help="require msg:<fp> probe spans with var/hit "
                         "annotations and at least one hit")
    ns = ap.parse_args(argv)
    try:
        with open(ns.path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL {ns.path}: {e}")
        return 2
    errs = validate(doc, expect_shards=ns.expect_shards,
                    expect_server=ns.expect_server,
                    expect_msgcache=ns.expect_msgcache)
    if errs:
        for e in errs:
            print(f"FAIL {ns.path}: {e}")
        return 1
    n = sum(1 for ev in doc["traceEvents"] if ev.get("ph") == "X")
    print(f"OK {ns.path}: {n} spans, all executor phases present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
