"""Counters, gauges, histograms — the metrics half of `repro.obs`.

A :class:`MetricsRegistry` replaces the scattered stat dicts
(``Executor.timings``, ``CacheStats`` increments, per-bench derived
numbers) as the substrate: components bump named instruments, and
``snapshot()`` returns one JSON-able dict for benchmarks, the service
``stats()`` endpoint, and ``explain(analyze=True)``.

Legacy surfaces stay intact: :class:`TimingsView` is a real ``dict``
subclass that mirrors phase timings into the registry's histograms, so
``Executor.timings["summarize"]`` keeps working unchanged while the same
number lands in ``executor.phase_seconds.summarize``.

Everything here is stdlib-only (the planning path must stay jax-free)
and thread-safe (the sharded build pool bumps counters concurrently).
"""

from __future__ import annotations

import math
import threading
from typing import Any, Dict, Optional


class Counter:
    """Monotonic count (events, bytes)."""

    __slots__ = ("name", "unit", "_value", "_lock")

    def __init__(self, name: str, unit: str = "") -> None:
        self.name = name
        self.unit = unit
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "unit": self.unit, "value": self._value}


class Gauge:
    """Last-written value (skew ratio, resident bytes)."""

    __slots__ = ("name", "unit", "_value", "_lock")

    def __init__(self, name: str, unit: str = "") -> None:
        self.name = name
        self.unit = unit
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "unit": self.unit, "value": self._value}


class Histogram:
    """Power-of-two exponential buckets, stored sparsely.

    Bucket ``i`` counts observations in ``(2^(i-1), 2^i]`` (bucket 0
    holds everything ``<= 1`` ulp above zero's bucket floor); fine
    enough to separate a 2ms kernel from a 200ms shard wall without
    preconfiguring bounds per metric.
    """

    __slots__ = ("name", "unit", "count", "sum", "min", "max",
                 "_buckets", "_lock")

    def __init__(self, name: str, unit: str = "") -> None:
        self.name = name
        self.unit = unit
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: Dict[int, int] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _bucket(v: float) -> int:
        if v <= 0.0:
            return -1075          # below the smallest positive double
        return math.frexp(v)[1]   # exponent e with v in (2^(e-1), 2^e]

    def observe(self, v: float) -> None:
        v = float(v)
        b = self._bucket(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            self._buckets[b] = self._buckets.get(b, 0) + 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "type": "histogram", "unit": self.unit,
                "count": self.count, "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "buckets": {str(k): v for k, v in sorted(self._buckets.items())},
            }


class MetricsRegistry:
    """Named get-or-create home for instruments + JSON snapshot API."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}

    def _get(self, cls, name: str, unit: str):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, unit)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str, unit: str = "") -> Counter:
        return self._get(Counter, name, unit)

    def gauge(self, name: str, unit: str = "") -> Gauge:
        return self._get(Gauge, name, unit)

    def histogram(self, name: str, unit: str = "") -> Histogram:
        return self._get(Histogram, name, unit)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            instruments = dict(self._instruments)
        return {name: inst.snapshot() for name, inst in sorted(instruments.items())}

    @staticmethod
    def from_snapshot(snap: Dict[str, Dict[str, Any]]) -> "MetricsRegistry":
        """Rebuild a registry from ``snapshot()`` output (round-trip for
        persistence / cross-process aggregation of bench runs)."""
        reg = MetricsRegistry()
        for name, s in snap.items():
            kind = s.get("type")
            if kind == "counter":
                reg.counter(name, s.get("unit", "")).inc(s["value"])
            elif kind == "gauge":
                reg.gauge(name, s.get("unit", "")).set(s["value"])
            elif kind == "histogram":
                h = reg.histogram(name, s.get("unit", ""))
                h.count = s["count"]
                h.sum = s["sum"]
                h.min = s["min"] if s["min"] is not None else math.inf
                h.max = s["max"] if s["max"] is not None else -math.inf
                h._buckets = {int(k): v for k, v in s["buckets"].items()}
            else:
                raise ValueError(f"unknown instrument type {kind!r} for {name!r}")
        return reg

    def merge(self, snap: Dict[str, Dict[str, Any]]) -> None:
        """Fold another registry's ``snapshot()`` into this one.

        The cross-process half of observability: shard workers snapshot
        their (freshly reset) registry and the coordinator merges every
        reply, so ``kernels.*`` / ``gfjs.*`` numbers look the same whether
        shards ran on threads or processes.  Counters add, gauges take the
        incoming value (last writer wins, same as ``set``), histograms
        merge bucket-wise.
        """
        for name, s in snap.items():
            kind = s.get("type")
            if kind == "counter":
                self.counter(name, s.get("unit", "")).inc(s["value"])
            elif kind == "gauge":
                self.gauge(name, s.get("unit", "")).set(s["value"])
            elif kind == "histogram":
                h = self.histogram(name, s.get("unit", ""))
                with h._lock:
                    h.count += s["count"]
                    h.sum += s["sum"]
                    if s["min"] is not None and s["min"] < h.min:
                        h.min = s["min"]
                    if s["max"] is not None and s["max"] > h.max:
                        h.max = s["max"]
                    for b, n in s["buckets"].items():
                        b = int(b)
                        h._buckets[b] = h._buckets.get(b, 0) + n
            else:
                raise ValueError(
                    f"unknown instrument type {kind!r} for {name!r}")

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


#: Process-wide default registry.  Components take an optional
#: ``metrics=`` override but fall back here, so a bare
#: ``GraphicalJoin(...).run()`` is still observable after the fact.
REGISTRY = MetricsRegistry()


class TimingsView(dict):
    """``Executor.timings`` compatible dict that mirrors writes into
    per-phase latency histograms (``executor.phase_seconds.<phase>``).

    Subclassing ``dict`` keeps every legacy access pattern — key tests,
    ``.get``, external mutation like ``gj.timings["aggregate"] = dt`` —
    byte-for-byte identical while the measurement substrate moves to the
    registry.  A fresh view is assigned wherever the old code assigned a
    fresh ``{}`` so reset semantics are unchanged.
    """

    __slots__ = ("_registry", "_prefix")

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 prefix: str = "executor.phase_seconds", *args, **kw):
        super().__init__(*args, **kw)
        self._registry = registry if registry is not None else REGISTRY
        self._prefix = prefix

    def __setitem__(self, key: str, value: float) -> None:
        super().__setitem__(key, value)
        try:
            v = float(value)
        except (TypeError, ValueError):
            return  # non-numeric write: keep dict semantics, skip the mirror
        self._registry.histogram(f"{self._prefix}.{key}", unit="s").observe(v)
