"""Pytest configuration: test tiers (DESIGN.md §13).

Two markers split the suite:

* ``slow`` — hypothesis/property sweeps and jax-compile-heavy model
  suites; minutes-scale, the depth tier.
* unmarked — the fast tier; seconds-scale, the inner loop for pipeline
  work: ``pytest -m "not slow"``.

CI and the tier-1 verify command run everything (bare ``pytest``).
Files opt in at module level with ``pytestmark = pytest.mark.slow``.
"""


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: property sweeps and jax-compile-heavy suites; "
        "deselect with -m \"not slow\" for the fast inner loop")
