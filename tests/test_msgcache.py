"""Elimination-message cache (DESIGN.md §20).

The load-bearing oracle is *differential*: a warm build (messages injected
from the cache) must be indistinguishable — level-for-level and as a row
multiset — from a cache-disabled cold build of the same query.  Around it:
append-invalidation (a grown table must never be served a stale message),
eviction mid-suite under a tiny byte budget, spill round-trips, and the
canonical-fingerprint satellite (alias/order-insensitive subtree identity).
"""

import os

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.core.gfjs import desummarize
from repro.plan.ir import step_fingerprints
from repro.relational.encoding import encode_query
from repro.relational.query import JoinQuery, QueryTable
from repro.relational.table import Catalog, Table
from repro.summary.msgcache import (CachedMessage, MessageCache,
                                    _entry_from_bytes, _entry_to_bytes)


# ---------------------------------------------------------------------------
# suite construction: overlapping snowflake chains under several facts —
# the forced-shared-subtree shape the cache exists for
# ---------------------------------------------------------------------------

def snowflake_catalog(*, n_chains=3, n_dim=200, n_sub=12, n_rows=800,
                      n_facts=2, seed=0) -> Catalog:
    rng = np.random.default_rng(seed)
    cat = Catalog()
    for c in range(n_chains):
        cat.add(Table(f"dim{c}", {"id": np.arange(n_dim),
                                  "sub": rng.integers(0, n_sub, n_dim)}))
        cat.add(Table(f"sub{c}", {"id": np.arange(n_sub),
                                  "val": rng.integers(0, 5, n_sub)}))
    for f in range(n_facts):
        cols = {"u": rng.integers(0, 10, n_rows)}
        for c in range(n_chains):
            cols[f"d{c}"] = rng.integers(0, n_dim, n_rows)
        cat.add(Table(f"fact{f}", cols))
    return cat


def snowflake_query(name, fact, chains, output=("U",)) -> JoinQuery:
    vmap = {"u": "U"}
    vmap.update({f"d{c}": f"D{c}" for c in chains})
    tabs = [QueryTable.of(fact, vmap)]
    for c in chains:
        tabs.append(QueryTable.of(f"dim{c}", {"id": f"D{c}", "sub": f"S{c}"}))
        tabs.append(QueryTable.of(f"sub{c}", {"id": f"S{c}", "val": f"V{c}"}))
    return JoinQuery(name, tabs, output=tuple(output))


def triangle_catalog(m=300, seed=0) -> Catalog:
    rng = np.random.default_rng(seed)
    return Catalog.of(
        Table("R", {"a": rng.integers(0, 40, m),
                    "b": rng.integers(0, 40, m)}),
        Table("S", {"b": rng.integers(0, 40, m),
                    "c": rng.integers(0, 40, m)}),
        Table("T", {"c": rng.integers(0, 40, m),
                    "a": rng.integers(0, 40, m)}))


def triangle_query(name="tri") -> JoinQuery:
    return JoinQuery(name, (
        QueryTable.of("R", {"a": "A", "b": "B"}),
        QueryTable.of("S", {"b": "B", "c": "C"}),
        QueryTable.of("T", {"c": "C", "a": "A"})), output=("A",))


def assert_same_gfjs(a, b, *, require_levels=True):
    assert a.join_size == b.join_size
    if tuple(a.column_order) == tuple(b.column_order):
        assert len(a.levels) == len(b.levels)
        for la, lb in zip(a.levels, b.levels):
            assert tuple(la.vars) == tuple(lb.vars)
            np.testing.assert_array_equal(la.freq, lb.freq)
            assert set(la.key_cols) == set(lb.key_cols)
            for k in la.key_cols:
                np.testing.assert_array_equal(la.key_cols[k], lb.key_cols[k])
        return
    assert not require_levels, "plans diverged where they must not"
    ca, cb = desummarize(a, decode=False), desummarize(b, decode=False)
    assert set(ca) == set(cb)
    ma = np.stack([np.asarray(ca[v]) for v in sorted(ca)])
    mb = np.stack([np.asarray(cb[v]) for v in sorted(cb)])
    np.testing.assert_array_equal(
        ma[:, np.lexsort(ma[::-1])], mb[:, np.lexsort(mb[::-1])])


# ---------------------------------------------------------------------------
# differential oracle: warm == cache-disabled cold
# ---------------------------------------------------------------------------

def test_warm_equals_cold_acyclic_suite():
    """Random overlapping acyclic suites: every warm build level-identical
    to the cache-disabled cold build of the same query."""
    for seed in range(3):
        cat = snowflake_catalog(seed=seed)
        suite = [snowflake_query(f"q{f}{i}", f"fact{f}", chains)
                 for f in range(2)
                 for i, chains in enumerate([(0, 1), (1, 2), (0, 2)])]
        mc = MessageCache()
        for q in suite:                        # prime: cross-query sharing
            GraphicalJoin(cat, q, message_cache=mc).run()
        assert mc.stats.hits > 0, "no cross-query sharing in shared chains"
        for q in suite:
            gj_w = GraphicalJoin(cat, q, message_cache=mc)
            warm = gj_w.run()
            assert gj_w._executor.cached_steps, q.name
            cold = GraphicalJoin(cat, q).run()
            # pin nothing: both planned independently; orders may differ
            assert_same_gfjs(warm, cold, require_levels=False)


def test_warm_equals_cold_cyclic_pure_gj():
    cat = triangle_catalog()
    q = triangle_query()
    mc = MessageCache()
    cold = GraphicalJoin(cat, q, hybrid=False).run()
    GraphicalJoin(cat, q, hybrid=False, message_cache=mc).run()
    gj = GraphicalJoin(cat, q, hybrid=False, message_cache=mc)
    warm = gj.run()
    assert gj._executor.cached_steps
    assert_same_gfjs(warm, cold, require_levels=False)


def test_bagged_plans_refuse_reuse():
    """Hybrid (bagged) plans bypass the cache entirely — no probes, no puts."""
    from repro.relational.synth import cyclic_pattern_like
    cat, q = cyclic_pattern_like("triangle", m=400, hub_frac=1.0, seed=0)
    mc = MessageCache()
    gj = GraphicalJoin(cat, q, hybrid=True, message_cache=mc)
    gj.run()
    st = mc.stats
    assert st.hits + st.misses + st.puts == 0
    assert gj._executor.cached_steps == ()


def test_record_trace_refuses_reuse():
    cat = snowflake_catalog()
    q = snowflake_query("q", "fact0", (0, 1))
    mc = MessageCache()
    GraphicalJoin(cat, q, message_cache=mc).run()          # populate
    gj = GraphicalJoin(cat, q, record_trace=True, message_cache=mc)
    gj.run()
    assert gj._executor.cached_steps == ()
    assert mc.stats.hits == 0                              # never probed


# ---------------------------------------------------------------------------
# append invalidation: version-keyed fingerprints can never serve stale
# ---------------------------------------------------------------------------

def test_append_never_serves_stale_message():
    cat = snowflake_catalog()
    q = snowflake_query("q", "fact0", (0, 1))
    mc = MessageCache()
    GraphicalJoin(cat, q, message_cache=mc).run()          # warm the chains

    rng = np.random.default_rng(99)
    delta = cat["dim0"].append(
        {"id": np.arange(200, 260),
         "sub": rng.integers(0, 12, 60)})
    cat.add(delta.new_table)

    gj = GraphicalJoin(cat, q, message_cache=mc)
    warm = gj.run()
    fresh = GraphicalJoin(cat, q).run()
    assert_same_gfjs(warm, fresh, require_levels=False)
    # the untouched chain (sub1/dim1 subtree) still hits; dim0's closure
    # re-fingerprints and recomputes
    enc = encode_query(cat, q)
    plan = gj.plan()
    versions = {qt.table: cat[qt.table].version() for qt in q.tables}
    fps, _ = step_fingerprints(enc, plan.order, q.output_variables, versions)
    resident = mc.resident_keys()
    assert fps["V1"] in resident and fps["S1"] in resident


def test_table_append_changes_fingerprints():
    """The tentpole invariant, stated directly on the fingerprint layer:
    appending to a table changes the fingerprint of every step whose
    closure contains it, and only those."""
    cat = snowflake_catalog()
    q = snowflake_query("q", "fact0", (0, 1))
    gj = GraphicalJoin(cat, q)
    gj.run()
    plan = gj.plan()
    enc = gj.enc
    versions = {qt.table: cat[qt.table].version() for qt in q.tables}
    before, srcs = step_fingerprints(enc, plan.order, q.output_variables,
                                     versions)
    versions2 = dict(versions)
    versions2["dim0"] = "v-after-append"
    after, _ = step_fingerprints(enc, plan.order, q.output_variables,
                                 versions2)
    for v in before:
        if "dim0" in srcs[v]:
            assert before[v] != after[v], v
        else:
            assert before[v] == after[v], v


# ---------------------------------------------------------------------------
# budget / eviction / spill
# ---------------------------------------------------------------------------

def test_eviction_mid_suite_budget_respected():
    cat = snowflake_catalog(n_rows=2000)
    suite = [snowflake_query(f"q{f}{i}", f"fact{f}", chains)
             for f in range(2) for i, chains in enumerate([(0, 1), (1, 2)])]
    budget = 64 << 10                       # tiny: forces mid-build evictions
    mc = MessageCache(byte_budget=budget)
    colds = [GraphicalJoin(cat, q).run() for q in suite]
    for _ in range(2):
        for q, cold in zip(suite, colds):
            warm = GraphicalJoin(cat, q, message_cache=mc).run()
            assert_same_gfjs(warm, cold, require_levels=False)
    assert mc.stats.evictions > 0
    # the byte budget holds (single oversized keep-entry is the only
    # documented excursion; these messages are far smaller than 64K)
    assert mc.resident_bytes <= budget


def test_spill_roundtrip_disk_hit(tmp_path):
    cat = snowflake_catalog()
    q1 = snowflake_query("q1", "fact0", (0, 1))
    q2 = snowflake_query("q2", "fact1", (0, 1))
    mc = MessageCache(byte_budget=1 << 10, spill_dir=str(tmp_path))
    GraphicalJoin(cat, q1, message_cache=mc).run()
    assert mc.stats.spills > 0
    assert any(n.endswith(".gjm") for n in os.listdir(tmp_path))
    cold = GraphicalJoin(cat, q2).run()
    warm = GraphicalJoin(cat, q2, message_cache=mc).run()
    assert mc.stats.disk_hits > 0
    assert_same_gfjs(warm, cold, require_levels=False)


def test_entry_serialization_roundtrip():
    msg = __import__("repro.core.potentials", fromlist=["Factor"]).Factor(
        ("X", "Y"), np.array([[0, 1], [2, 3]]), np.array([1, 2]),
        np.array([3, 4]), (5, 7))
    entry = CachedMessage(message=msg, psi=None)
    back = _entry_from_bytes(_entry_to_bytes(entry))
    np.testing.assert_array_equal(back.message.keys, msg.keys)
    np.testing.assert_array_equal(back.message.bucket, msg.bucket)
    np.testing.assert_array_equal(back.message.fac, msg.fac)
    assert back.message.sizes == msg.sizes and back.psi is None
    psi2, renamed = MessageCache.adopt(back, "C", ("P", "Q"))
    assert renamed.vars == ("P", "Q") and psi2 is None
    with pytest.raises(ValueError):
        MessageCache.adopt(back, "C", ("P",))


def test_invalidate_by_table():
    cat = snowflake_catalog()
    q = snowflake_query("q", "fact0", (0, 1))
    mc = MessageCache()
    GraphicalJoin(cat, q, message_cache=mc).run()
    n = len(mc)
    assert n > 0
    removed = mc.invalidate("sub0")
    assert removed >= 1 and len(mc) == n - removed
    assert mc.stats.invalidations == removed
    # untouched-chain entries survive
    assert len(mc) > 0


# ---------------------------------------------------------------------------
# satellite 1: canonical query fingerprints
# ---------------------------------------------------------------------------

def test_fingerprint_alias_and_order_insensitive():
    base = snowflake_query("a", "fact0", (0, 1))
    # same join, tables listed backwards, internal vars renamed
    vmap = {"u": "U", "d0": "K0", "d1": "K1"}
    renamed = JoinQuery("b", (
        QueryTable.of("sub1", {"id": "Z1", "val": "W1"}),
        QueryTable.of("dim1", {"id": "K1", "sub": "Z1"}),
        QueryTable.of("sub0", {"id": "Z0", "val": "W0"}),
        QueryTable.of("dim0", {"id": "K0", "sub": "Z0"}),
        QueryTable.of("fact0", vmap)), output=("U",))
    assert base.fingerprint() == renamed.fingerprint()
    # literal keys (plan/state caches) must distinguish the rename
    assert base.fingerprint(literal=True) != renamed.fingerprint(literal=True)
    # output renames are always distinct: the column name is the contract
    out_renamed = snowflake_query("c", "fact0", (0, 1), output=("U",))
    out_renamed = JoinQuery("c", tuple(
        QueryTable(qt.table, tuple((c, "UU" if v == "U" else v)
                                   for c, v in qt.var_map))
        for qt in out_renamed.tables), output=("UU",))
    assert base.fingerprint() != out_renamed.fingerprint()


def test_fingerprint_symmetric_selfjoin_falls_back_to_literal():
    """Two structurally indistinguishable internal vars (symmetric
    self-join) must NOT be conflated — labels fall back to literal names,
    so the two orientations key differently (conservative, never wrong)."""
    q1 = JoinQuery("s1", (
        QueryTable.of("E", {"a": "X", "b": "Y"}),
        QueryTable.of("E", {"a": "Y", "b": "X"})), output=())
    labels = q1.canonical_labels()
    assert labels["X"] == "X" and labels["Y"] == "Y"


def test_fingerprint_plan_folding_maps_labels():
    """plan.signature(labels=...) canonicalizes the embedded order: an
    alias-renamed twin pinned to the *mapped* elimination order shares the
    (query, plan) summary key; a genuinely different order does not."""
    cat = snowflake_catalog()
    q = snowflake_query("a", "fact0", (0, 1))
    rename = {"S0": "ZS0", "S1": "ZS1", "V0": "WV0", "V1": "WV1",
              "D0": "KD0", "D1": "KD1"}
    ren = JoinQuery("b", tuple(
        QueryTable(qt.table, tuple((c, rename.get(v, v))
                                   for c, v in qt.var_map))
        for qt in q.tables), output=("U",))
    p1 = GraphicalJoin(cat, q).plan()
    p2 = GraphicalJoin(
        cat, ren,
        elimination_order=[rename.get(v, v) for v in p1.order]).plan()
    assert q.fingerprint(plan=p1) == ren.fingerprint(plan=p2)
    # the planner's own (name-tie-broken) choice for the twin may differ —
    # and a different order is a different summary, so keys must differ too
    p3 = GraphicalJoin(cat, ren).plan()
    if tuple(p3.order) != tuple(p2.order):
        assert ren.fingerprint(plan=p3) != ren.fingerprint(plan=p2)
