"""Property-based tests (hypothesis) for the GJ core invariants.

Invariants, on randomized schemas/data covering chains, stars, trees,
self-joins, triangles and 4-cycles (the JT path):

  P1  desummarize(GFJS) == join result sorted by the GFJS column order
  P2  GFJS == grouped per-level RLE of that sorted result (Definition 1)
  P3  every level's run lengths sum to |Q|
  P4  |Q| from the root marginal == true join size
  P5  GJ == leapfrog (WCOJ baseline) == binary plan, as multisets
  P6  consecutive-level consistency: child runs under a parent run sum to it
"""

from typing import Dict, List, Tuple

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; absent in minimal envs
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core.api import GraphicalJoin
from repro.core.baselines import binary_join_plan, leapfrog_join
from repro.core.oracle import grouped_rle, oracle_join, sort_rows
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog, Table

# depth tier (DESIGN.md §13): deselect with -m "not slow"
pytestmark = pytest.mark.slow

# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

SHAPES = ["chain2", "chain3", "chain4", "star3", "selfjoin", "triangle",
          "cycle4", "bowtie", "wide_table"]


def _mk_query(shape: str) -> Tuple[List[Tuple[str, Dict[str, str], int]], JoinQuery]:
    """Returns ([(table, var_map, arity)], query). arity = #cols."""
    if shape == "chain2":
        spec = [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"})]
    elif shape == "chain3":
        spec = [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
                ("t2", {"x0": "C", "x1": "D"})]
    elif shape == "chain4":
        spec = [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
                ("t2", {"x0": "C", "x1": "D"}), ("t3", {"x0": "D", "x1": "E"})]
    elif shape == "star3":
        spec = [("t0", {"x0": "M", "x1": "A"}), ("t1", {"x0": "M", "x1": "B"}),
                ("t2", {"x0": "M", "x1": "C"})]
    elif shape == "selfjoin":
        spec = [("t0", {"x0": "A", "x1": "B"}), ("t0", {"x0": "B", "x1": "C"})]
    elif shape == "triangle":
        spec = [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
                ("t2", {"x0": "C", "x1": "A"})]
    elif shape == "cycle4":
        spec = [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
                ("t2", {"x0": "C", "x1": "D"}), ("t3", {"x0": "D", "x1": "A"})]
    elif shape == "bowtie":  # two triangles sharing a vertex
        spec = [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
                ("t2", {"x0": "C", "x1": "A"}), ("t3", {"x0": "C", "x1": "D"}),
                ("t4", {"x0": "D", "x1": "E"}), ("t5", {"x0": "E", "x1": "C"})]
    elif shape == "wide_table":  # 3-attr hyperedges
        spec = [("t0", {"x0": "A", "x1": "B", "x2": "C"}),
                ("t1", {"x0": "B", "x1": "C", "x2": "D"})]
    else:
        raise ValueError(shape)
    tables = [(t, vm, len(vm)) for t, vm in spec]
    query = JoinQuery.of(shape, [(t, vm) for t, vm in spec])
    return tables, query


@st.composite
def join_instances(draw):
    shape = draw(st.sampled_from(SHAPES))
    tables, query = _mk_query(shape)
    domain = draw(st.integers(min_value=1, max_value=5))
    cat = Catalog()
    seen = set()
    for tname, vm, arity in tables:
        if tname in seen:
            continue
        seen.add(tname)
        nrows = draw(st.integers(min_value=0, max_value=24))
        cols = {}
        for j in range(arity):
            cols[f"x{j}"] = draw(
                st.lists(st.integers(min_value=0, max_value=domain - 1),
                         min_size=nrows, max_size=nrows))
        cat.add(Table(tname, {k: np.asarray(v, dtype=np.int64) for k, v in cols.items()}))
    return cat, query


COMMON = dict(deadline=None,
              suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])


@settings(max_examples=120, **COMMON)
@given(join_instances())
def test_gj_equals_oracle(inst):
    cat, query = inst
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    res = gj.desummarize(gfjs, decode=False)
    oc = oracle_join(gj.enc)
    o = sort_rows(oc, gfjs.column_order)
    g = (np.stack([res[v] for v in gfjs.column_order], axis=1)
         if gfjs.join_size else np.zeros((0, len(gfjs.column_order)), np.int64))
    # P4
    assert gj.join_size() == len(o)
    # P1
    assert np.array_equal(o, g)
    # P2
    groups = [len(l.vars) for l in gfjs.levels]
    for lvl, (vals, freqs) in zip(gfjs.levels, grouped_rle(o, groups)):
        got = np.stack([lvl.key_cols[v] for v in lvl.vars], axis=1) \
            if lvl.num_runs else np.zeros((0, len(lvl.vars)), np.int64)
        assert np.array_equal(got, vals) and np.array_equal(lvl.freq, freqs)
    # P3
    for lvl in gfjs.levels:
        assert int(lvl.freq.sum()) == gfjs.join_size


@settings(max_examples=60, **COMMON)
@given(join_instances())
def test_baselines_agree(inst):
    cat, query = inst
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    res = gj.desummarize(gfjs, decode=False)
    lf = leapfrog_join(gj.enc)
    bp = binary_join_plan(gj.enc)
    assert lf.rows == bp.rows == gfjs.join_size
    cols = gfjs.column_order
    g = np.stack([res[v] for v in cols], axis=1) if gfjs.join_size else \
        np.zeros((0, len(cols)), np.int64)
    for run in (lf, bp):
        m = np.stack([run.columns[v] for v in cols], axis=1) if run.rows else \
            np.zeros((0, len(cols)), np.int64)
        m = m[np.lexsort(m.T[::-1])]
        assert np.array_equal(g, m)


@settings(max_examples=60, **COMMON)
@given(join_instances())
def test_level_consistency(inst):
    """P6: expanding level i's runs refines level i-1's runs exactly."""
    cat, query = inst
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    for a, b in zip(gfjs.levels[:-1], gfjs.levels[1:]):
        ca = np.concatenate([[0], np.cumsum(a.freq)])
        cb = np.concatenate([[0], np.cumsum(b.freq)])
        # every parent boundary must appear among child boundaries
        assert np.all(np.isin(ca, cb))


@settings(max_examples=40, **COMMON)
@given(join_instances(), st.integers(min_value=0, max_value=10_000))
def test_range_desummarize(inst, raw_lo):
    from repro.core.gfjs import desummarize_range
    cat, query = inst
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    if gfjs.join_size == 0:
        return
    lo = raw_lo % gfjs.join_size
    hi = min(lo + 7, gfjs.join_size)
    full = gj.desummarize(gfjs, decode=False)
    part = desummarize_range(gfjs, lo, hi, decode=False)
    for v in gfjs.column_order:
        assert np.array_equal(full[v][lo:hi], part[v])
