"""Serving engine tests: batched generation, greedy determinism, vlm path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.model import LM
from repro.serve.engine import ServeConfig, ServeEngine

# depth tier (DESIGN.md §13): deselect with -m "not slow"
pytestmark = pytest.mark.slow


def _engine(arch, temperature=0.0, extra=None):
    cfg = get_smoke(arch).scaled(num_layers=2, **(extra or {}))
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    return cfg, lm, ServeEngine(lm, params, ServeConfig(max_seq=48,
                                                        temperature=temperature))


def test_greedy_generation_is_deterministic():
    cfg, lm, eng = _engine("qwen3_8b")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (3, 16)), jnp.int32)}
    a = eng.generate(batch, max_new=8, seed=1)
    b = eng.generate(batch, max_new=8, seed=2)   # greedy: seed-independent
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 8)


def test_sampled_generation_varies_with_seed():
    cfg, lm, eng = _engine("qwen3_8b", temperature=1.0)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
    a = eng.generate(batch, max_new=8, seed=1)
    b = eng.generate(batch, max_new=8, seed=2)
    assert not np.array_equal(a, b)


def test_vlm_generation_uses_vision_context():
    """Different images must change the model's distribution (logit-level
    check: token argmax can coincide at random init)."""
    cfg, lm, eng = _engine("llama32_vision_11b")
    rng = np.random.default_rng(0)
    params = lm.init(jax.random.key(0))
    base = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
    shape = (2, cfg.vlm.num_image_tokens, cfg.vlm.vision_dim)
    l1, _ = lm.prefill(params, dict(base, vision=jnp.asarray(
        rng.normal(size=shape), jnp.float32)), s_max=32)
    l2, _ = lm.prefill(params, dict(base, vision=jnp.asarray(
        rng.normal(size=shape) * 3, jnp.float32)), s_max=32)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3
    out = eng.generate(dict(base, vision=jnp.asarray(
        rng.normal(size=shape), jnp.float32)), max_new=4, seed=0)
    assert out.shape == (2, 4)


def test_hybrid_and_ssm_generate():
    for arch in ("zamba2_2p7b", "xlstm_350m"):
        cfg, lm, eng = _engine(arch)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                       jnp.int32)}
        out = eng.generate(batch, max_new=4, seed=0)
        assert out.shape == (2, 4)
        assert (out >= 0).all() and (out < lm.vocab_padded).all()
