"""JoinService + SummaryCache: compute-and-reuse as a service."""

import os

import numpy as np
import pytest

from repro.relational.query import JoinQuery
from repro.relational.synth import lastfm_like
from repro.relational.table import Catalog, Table
from repro.summary.cache import SummaryCache, cache_key
from repro.summary.service import JoinService


@pytest.fixture(scope="module")
def lastfm():
    return lastfm_like(n_users=60, n_artists=50, artists_per_user=4,
                       friends_per_user=3)


def test_cache_hit_skips_build_phases(lastfm):
    cat, qs = lastfm
    svc = JoinService(cat)
    first = svc.frame(qs["lastfm_A1"])
    assert first.source == "computed"
    # the miss ran the full pipeline
    assert {"build_model", "build_generator", "summarize"} <= set(first.timings)

    second = svc.frame(qs["lastfm_A1"])
    assert second.cache_hit and second.source == "memory"
    # the hit never touched GraphicalJoin: no build-phase timings at all
    assert "build_model" not in second.timings
    assert "build_generator" not in second.timings
    assert second.frame.count() == first.frame.count()
    st = svc.stats()
    assert st["hits"] == 1 and st["misses"] == 1 and st["puts"] == 1


def test_canonical_fingerprint_shares_cache_entries(lastfm):
    cat, qs = lastfm
    q = qs["lastfm_A1"]
    svc = JoinService(cat)
    svc.frame(q)
    # same join, different display name + table order + var_map insertion order
    permuted = JoinQuery(name="renamed", tables=tuple(reversed(q.tables)),
                         output=None)
    assert permuted.fingerprint() == q.fingerprint()
    assert svc.frame(permuted).cache_hit

    # a different projection is a different summary
    projected = JoinQuery(q.name, q.tables, output=("A1", "A2"))
    assert projected.fingerprint() != q.fingerprint()
    assert not svc.frame(projected).cache_hit


def test_table_version_invalidates(lastfm):
    cat, qs = lastfm
    q = qs["lastfm_A1"]
    cache = SummaryCache()
    JoinService(cat, cache=cache).frame(q)

    # same schema, one row dropped: new content version => cache miss
    ua = cat["user_artists"]
    cat2 = Catalog.of(
        Table("user_artists", {k: v[:-1] for k, v in ua.columns.items()}),
        cat["user_friends"])
    assert cache_key(q, cat2) != cache_key(q, cat)
    reply = JoinService(cat2, cache=cache).frame(q)
    assert reply.source == "computed"


def test_eviction_and_disk_spill(tmp_path, lastfm):
    cat, qs = lastfm
    spill = str(tmp_path / "spill")
    svc = JoinService(cat, byte_budget=1024, spill_dir=spill)
    svc.frame(qs["lastfm_A1"])
    svc.frame(qs["lastfm_B"])        # evicts A1 (budget is tiny)
    st = svc.stats()
    assert st["evictions"] >= 1 and st["spills"] >= 1
    assert len(os.listdir(spill)) >= 1

    reply = svc.frame(qs["lastfm_A1"])   # comes back from disk, not a re-join
    assert reply.source == "disk"
    assert "build_model" not in reply.timings


def test_service_aggregates_match_summary_frame(lastfm):
    cat, qs = lastfm
    q = qs["lastfm_A1"]
    svc = JoinService(cat)
    base = svc.frame(q).frame
    assert svc.count(q) == base.count()
    assert svc.sum(q, "A2") == base.sum("A2")
    assert svc.mean(q, "A2") == base.mean("A2")
    assert svc.min(q, "U1") == base.min("U1")
    assert svc.max(q, "U1") == base.max("U1")
    assert np.array_equal(svc.distinct(q, "A1"), base.distinct("A1"))

    got = svc.group_by(q, "U1", where={"U2": lambda u: u < 10},
                       total=("sum", "A2"))
    want = base.filter(U2=lambda u: u < 10).group_by("U1", total=("sum", "A2"))
    assert np.array_equal(got["U1"], want["U1"])
    assert np.array_equal(got["total"], want["total"])


def test_lru_order_and_budget():
    rng = np.random.default_rng(0)
    cat = Catalog.of(
        Table("t0", {"x0": rng.integers(0, 5, 30), "x1": rng.integers(0, 5, 30)}),
        Table("t1", {"x0": rng.integers(0, 5, 30), "x1": rng.integers(0, 5, 30)}),
        Table("t2", {"x0": rng.integers(0, 5, 30), "x1": rng.integers(0, 5, 30)}))
    queries = [
        JoinQuery.of("q01", [("t0", {"x0": "A", "x1": "B"}),
                             ("t1", {"x0": "B", "x1": "C"})]),
        JoinQuery.of("q12", [("t1", {"x0": "A", "x1": "B"}),
                             ("t2", {"x0": "B", "x1": "C"})]),
        JoinQuery.of("q02", [("t0", {"x0": "A", "x1": "B"}),
                             ("t2", {"x0": "B", "x1": "C"})]),
    ]
    svc = JoinService(cat, byte_budget=1)  # at most one resident entry
    for q in queries:
        svc.frame(q)
    st = svc.stats()
    assert st["resident_entries"] == 1
    assert st["evictions"] == 2
    # no spill dir: evicted entries are recomputed on demand
    assert svc.frame(queries[0]).source == "computed"
    assert svc.frame(queries[0]).source == "memory"


def test_aggregate_convenience_on_graphical_join(lastfm):
    from repro.core.api import GraphicalJoin
    cat, qs = lastfm
    gj = GraphicalJoin(cat, qs["lastfm_A1"])
    gfjs = gj.run()
    flat = gj.desummarize(gfjs, decode=True)

    assert gj.aggregate("count", gfjs=gfjs) == len(flat["A1"])
    assert gj.aggregate("sum", "A2", gfjs=gfjs) == int(flat["A2"].sum())
    assert "aggregate" in gj.timings
    g = gj.aggregate("sum", "A2", by=["U1"], gfjs=gfjs)
    want_keys = np.unique(flat["U1"])
    assert np.array_equal(g["U1"], want_keys)
    mask0 = flat["U1"] == want_keys[0]
    assert int(g["sum"][0]) == int(flat["A2"][mask0].sum())
    n1 = gj.aggregate("count", where={"U2": lambda u: u < 10}, gfjs=gfjs)
    assert n1 == int((flat["U2"] < 10).sum())


# ---------------------------------------------------------------------------
# PR 10: message reuse + calibration sidecar at the service layer
# ---------------------------------------------------------------------------

def _chain_catalog(n_facts=2, seed=0):
    rng = np.random.default_rng(seed)
    cat = Catalog.of(
        Table("dim", {"id": np.arange(100),
                      "sub": rng.integers(0, 9, 100)}),
        Table("sub", {"id": np.arange(9), "val": rng.integers(0, 4, 9)}))
    for f in range(n_facts):
        cat.add(Table(f"fact{f}", {"u": rng.integers(0, 7, 400),
                                   "d": rng.integers(0, 100, 400)}))
    return cat


def _chain_query(f):
    return JoinQuery.of(f"cq{f}", [
        (f"fact{f}", {"u": "U", "d": "D"}),
        ("dim", {"id": "D", "sub": "S"}),
        ("sub", {"id": "S", "val": "V"})], output=["U"])


def test_service_shares_messages_across_queries():
    """Two cold queries over the same dimension chain: the second build
    hits the service's message cache (incremental off => untraced)."""
    cat = _chain_catalog()
    svc = JoinService(cat, incremental=False)
    svc.frame(_chain_query(0))
    st0 = svc.stats()
    svc.frame(_chain_query(1))
    st1 = svc.stats()
    assert st1["msgcache_hits"] > st0["msgcache_hits"]
    # truth: an isolated no-reuse service answers the same
    lone = JoinService(Catalog(dict(cat.tables)), incremental=False,
                       message_reuse=False)
    assert svc.count(_chain_query(1)) == lone.count(_chain_query(1))


def test_service_append_drops_dead_messages():
    cat = _chain_catalog()
    svc = JoinService(cat, incremental=False)
    svc.frame(_chain_query(0))
    assert len(svc.message_cache) > 0
    before = svc.stats()["msgcache_invalidations"]
    svc.append("dim", {"id": np.arange(100, 110),
                       "sub": np.zeros(10, np.int64)})
    assert svc.stats()["msgcache_invalidations"] > before
    # and the refreshed catalog still answers correctly
    lone = JoinService(Catalog(dict(svc.catalog.tables)),
                       incremental=False, message_reuse=False)
    assert svc.count(_chain_query(0)) == lone.count(_chain_query(0))


def test_calibration_sidecar_persists_across_services(tmp_path):
    """A computed build writes drift corrections to the spill-dir sidecar;
    a fresh service (new process stand-in) loads them and prices its
    plans with them (explain renders calib(loaded)=)."""
    from repro.core.api import GraphicalJoin
    cat = _chain_catalog()
    svc = JoinService(cat, spill_dir=str(tmp_path))
    assert svc.frame(_chain_query(0)).source == "computed"
    path = os.path.join(str(tmp_path), "calibration.json")
    assert os.path.exists(path)

    svc2 = JoinService(Catalog(dict(cat.tables)), spill_dir=str(tmp_path))
    corr = svc2._load_corrections()
    assert corr and "eliminate" in corr
    gj = GraphicalJoin(cat, _chain_query(0), corrections=corr)
    gj.plan()
    assert "calib(loaded)=" in gj.explain()
    # once this session measures its own drift, the loaded tag yields
    gj.run()
    assert "calib(loaded)=" not in gj.explain()


def test_corrupt_calibration_sidecar_is_ignored(tmp_path):
    path = os.path.join(str(tmp_path), "calibration.json")
    with open(path, "w") as f:
        f.write("{not json")
    cat = _chain_catalog()
    svc = JoinService(cat, spill_dir=str(tmp_path))
    assert svc._load_corrections() is None
    assert svc.frame(_chain_query(0)).source == "computed"
