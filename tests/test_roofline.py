"""HLO analyzer correctness: trip-count amplification, dot flops, collective
byte attribution.  These guard the §Roofline numbers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, parse_module


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    def scan_fn(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=8)[0]

    def unroll_fn(x, w):
        for _ in range(8):
            x = x @ w
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    want = 2 * 256 ** 3 * 8
    a = analyze_hlo(_compiled_text(scan_fn, x, w))
    b = analyze_hlo(_compiled_text(unroll_fn, x, w))
    assert a["flops"] == want
    assert b["flops"] == want


def test_nested_scan_amplification():
    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            return jax.lax.scan(inner, c, None, length=4)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a = analyze_hlo(_compiled_text(nested, x, w))
    assert a["flops"] == 2 * 128 ** 3 * 12


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    res = analyze_hlo(_compiled_text(f, a, b))
    assert res["flops"] == 2 * 4 * 64 * 32 * 16


def test_bytes_scale_with_scan_length():
    def mk(n):
        def f(x):
            def body(c, _):
                return c * 2.0 + 1.0, None
            return jax.lax.scan(body, x, None, length=n)[0]
        return f

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    b1 = analyze_hlo(_compiled_text(mk(2), x))["bytes"]
    b2 = analyze_hlo(_compiled_text(mk(8), x))["bytes"]
    # 6 extra iterations x (read 4MB + write 4MB) on top of constant
    # entry-computation traffic
    per_iter = 1024 * 1024 * 4 * 2
    assert abs((b2 - b1) - 6 * per_iter) < per_iter


def test_parse_module_structure():
    def f(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=3)[0]
    text = _compiled_text(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    comps, entry = parse_module(text)
    assert entry is not None and entry in comps
    kinds = {op.kind for c in comps.values() for op in c.ops}
    assert "while" in kinds and "dot" in kinds
