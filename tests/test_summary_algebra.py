"""Summary-side algebra vs the oracle join — the subsystem's ground truth.

Every aggregate the SummaryFrame computes in O(runs) must equal the same
aggregate over the fully materialized (oracle) join result, on randomized
acyclic AND cyclic queries.  Randomization uses plain numpy RNG so these
run in minimal environments (no hypothesis dependency).
"""

import collections

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.core.gfjs import desummarize
from repro.core.oracle import oracle_join
from repro.relational.query import JoinQuery
from repro.relational.synth import figure1, lastfm_like
from repro.relational.table import Catalog, Table
from repro.summary.algebra import SummaryFrame

SHAPES = {
    "chain3": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
               ("t2", {"x0": "C", "x1": "D"})],
    "star3": [("t0", {"x0": "M", "x1": "A"}), ("t1", {"x0": "M", "x1": "B"}),
              ("t2", {"x0": "M", "x1": "C"})],
    "selfjoin": [("t0", {"x0": "A", "x1": "B"}), ("t0", {"x0": "B", "x1": "C"})],
    "triangle": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
                 ("t2", {"x0": "C", "x1": "A"})],
    "cycle4": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
               ("t2", {"x0": "C", "x1": "D"}), ("t3", {"x0": "D", "x1": "A"})],
}


def random_instance(shape: str, seed: int):
    spec = SHAPES[shape]
    rng = np.random.default_rng(seed)
    domain = int(rng.integers(1, 6))
    cat = Catalog()
    for tname, vm in spec:
        if tname in cat:
            continue
        nrows = int(rng.integers(0, 25))
        cols = {c: rng.integers(0, domain, nrows).astype(np.int64)
                for c in vm.keys()}
        cat.add(Table(tname, cols))
    return cat, JoinQuery.of(shape, spec)


def oracle_raw(gj: GraphicalJoin):
    oc = oracle_join(gj.enc)
    return {v: gj.enc.domains[v].decode(c) for v, c in oc.items()}


CASES = [(s, seed) for s in SHAPES for seed in range(6)]


@pytest.mark.parametrize("shape,seed", CASES)
def test_scalar_aggregates_match_oracle(shape, seed):
    cat, query = random_instance(shape, seed)
    gj = GraphicalJoin(cat, query)
    frame = SummaryFrame.of(gj.run())
    raw = oracle_raw(gj)
    some_var = gj.enc.query.variables[0]
    n = len(raw[some_var])

    assert frame.count() == n
    for v in frame.gfjs.column_order:
        if n == 0:
            assert frame.sum(v) == 0
            assert frame.mean(v) is None
            assert frame.min(v) is None and frame.max(v) is None
            assert frame.count_distinct(v) == 0
        else:
            assert frame.sum(v) == int(raw[v].sum())
            assert frame.mean(v) == pytest.approx(raw[v].mean())
            assert frame.min(v) == raw[v].min()
            assert frame.max(v) == raw[v].max()
            assert frame.count_distinct(v) == len(np.unique(raw[v]))
            assert np.array_equal(frame.distinct(v), np.unique(raw[v]))


@pytest.mark.parametrize("shape,seed", CASES)
def test_group_by_matches_oracle(shape, seed):
    cat, query = random_instance(shape, seed)
    gj = GraphicalJoin(cat, query)
    frame = SummaryFrame.of(gj.run())
    raw = oracle_raw(gj)
    cols = frame.gfjs.column_order
    key, val = cols[0], cols[-1]

    got = frame.group_by(key, n="count", total=("sum", val),
                         lo=("min", val), hi=("max", val), avg=("mean", val))
    cnts = collections.Counter(raw[key])
    sums = collections.defaultdict(int)
    los, his = {}, {}
    for k, x in zip(raw[key], raw[val]):
        sums[k] += x
        los[k] = min(los.get(k, x), x)
        his[k] = max(his.get(k, x), x)
    ks = sorted(cnts)
    assert list(got[key]) == ks
    assert [int(x) for x in got["n"]] == [cnts[k] for k in ks]
    assert [int(x) for x in got["total"]] == [sums[k] for k in ks]
    assert [int(x) for x in got["lo"]] == [los[k] for k in ks]
    assert [int(x) for x in got["hi"]] == [his[k] for k in ks]
    assert np.allclose(got["avg"], [sums[k] / cnts[k] for k in ks])


@pytest.mark.parametrize("shape,seed", CASES)
def test_multi_key_group_by_matches_oracle(shape, seed):
    cat, query = random_instance(shape, seed)
    gj = GraphicalJoin(cat, query)
    frame = SummaryFrame.of(gj.run())
    raw = oracle_raw(gj)
    cols = frame.gfjs.column_order
    if len(cols) < 2:
        pytest.skip("needs two variables")
    k1, k2 = cols[0], cols[1]
    got = frame.group_by([k1, k2], n="count")
    want = collections.Counter(zip(raw[k1], raw[k2]))
    pairs = list(zip(got[k1], got[k2]))
    assert pairs == sorted(want)
    assert {p: int(c) for p, c in zip(pairs, got["n"])} == dict(want)


@pytest.mark.parametrize("shape,seed", CASES)
def test_filter_pushdown_matches_oracle(shape, seed):
    cat, query = random_instance(shape, seed)
    gj = GraphicalJoin(cat, query)
    frame = SummaryFrame.of(gj.run())
    raw = oracle_raw(gj)
    cols = frame.gfjs.column_order
    some_var = cols[0]
    n = len(raw[some_var])

    # equality predicate on the deepest variable, range on the shallowest
    deep_var = cols[-1]
    rng = np.random.default_rng(seed + 1000)
    pivot = int(rng.integers(0, 5))
    filtered = frame.filter({deep_var: pivot}, **{some_var: lambda v: v >= 1})
    mask = np.ones(n, dtype=bool)
    mask &= raw[deep_var] == pivot
    mask &= raw[some_var] >= 1

    assert filtered.count() == int(mask.sum())
    if mask.any():
        mid = cols[len(cols) // 2]
        assert filtered.sum(mid) == int(raw[mid][mask].sum())
        g = filtered.group_by(mid, n="count")
        want = collections.Counter(raw[mid][mask])
        assert {k: int(c) for k, c in zip(g[mid], g["n"])} == dict(want)

    # filters compose: two-step == one-step
    two_step = frame.filter({deep_var: pivot}).filter(
        **{some_var: lambda v: v >= 1})
    assert two_step.count() == filtered.count()

    # the filtered frame re-materializes to exactly the filtered multiset
    flat = desummarize(filtered.to_gfjs())
    assert len(flat[some_var]) == int(mask.sum())
    got_rows = sorted(zip(*(flat[v] for v in cols)))
    want_rows = sorted(zip(*(raw[v][mask] for v in cols)))
    assert got_rows == want_rows


@pytest.mark.parametrize("shape,seed", CASES)
def test_filtered_group_by_matches_oracle(shape, seed):
    """group_by + predicate pushdown COMBINED, against the oracle.

    The separate paths were covered; this closes the gap: every aggregate
    op (count/sum/min/max/mean), multi-key grouping, and a mixed predicate
    set (equality + range callable + membership) applied together.
    """
    cat, query = random_instance(shape, seed)
    gj = GraphicalJoin(cat, query)
    frame = SummaryFrame.of(gj.run())
    raw = oracle_raw(gj)
    cols = frame.gfjs.column_order
    if len(cols) < 3:
        pytest.skip("needs three variables")
    n = len(raw[cols[0]])

    rng = np.random.default_rng(seed + 2000)
    k1, k2 = cols[0], cols[1]
    fvar, val = cols[-1], cols[len(cols) // 2]
    pivot = int(rng.integers(0, 4))
    members = sorted({int(rng.integers(0, 5)) for _ in range(3)})
    preds = {fvar: lambda v: v >= pivot, k2: members}

    got = frame.filter(preds).group_by(
        [k1, k2], n="count", total=("sum", val), lo=("min", val),
        hi=("max", val), avg=("mean", val))

    mask = np.ones(n, dtype=bool)
    mask &= raw[fvar] >= pivot
    mask &= np.isin(raw[k2], members)
    want = collections.defaultdict(lambda: [0, 0, None, None])
    for a, b, x in zip(raw[k1][mask], raw[k2][mask], raw[val][mask]):
        w = want[(a, b)]
        w[0] += 1
        w[1] += x
        w[2] = x if w[2] is None else min(w[2], x)
        w[3] = x if w[3] is None else max(w[3], x)
    ks = sorted(want)
    assert list(zip(got[k1], got[k2])) == ks
    assert [int(x) for x in got["n"]] == [want[k][0] for k in ks]
    assert [int(x) for x in got["total"]] == [want[k][1] for k in ks]
    assert [int(x) for x in got["lo"]] == [want[k][2] for k in ks]
    assert [int(x) for x in got["hi"]] == [want[k][3] for k in ks]
    assert np.allclose(got["avg"],
                       [want[k][1] / want[k][0] for k in ks])

    # the same question asked through aggregate-then-filter composition:
    # grouping over the unfiltered frame restricted by the filter must
    # agree wherever groups survive
    full = frame.group_by([k1, k2], n="count")
    surviving = dict(zip(zip(full[k1], full[k2]),
                         (int(x) for x in full["n"])))
    for k in ks:
        assert want[k][0] <= surviving[k]


@pytest.mark.parametrize("shape,seed", CASES)
def test_filtered_scalar_aggregates_match_oracle(shape, seed):
    """Scalar aggregates under pushed-down predicates, against the oracle."""
    cat, query = random_instance(shape, seed)
    gj = GraphicalJoin(cat, query)
    frame = SummaryFrame.of(gj.run())
    raw = oracle_raw(gj)
    cols = frame.gfjs.column_order
    some, deep = cols[0], cols[-1]
    rng = np.random.default_rng(seed + 3000)
    pivot = int(rng.integers(0, 4))
    filtered = frame.filter({some: lambda v: v != pivot})
    mask = raw[some] != pivot
    assert filtered.count() == int(mask.sum())
    if mask.any():
        assert filtered.sum(deep) == int(raw[deep][mask].sum())
        assert filtered.min(deep) == raw[deep][mask].min()
        assert filtered.max(deep) == raw[deep][mask].max()
        assert filtered.count_distinct(deep) == \
            len(np.unique(raw[deep][mask]))
    else:
        assert filtered.min(deep) is None
        assert filtered.count_distinct(deep) == 0


def test_weights_stay_level_consistent_after_filter():
    cat, qs = lastfm_like(n_users=50, n_artists=40, artists_per_user=4,
                          friends_per_user=3)
    gj = GraphicalJoin(cat, qs["lastfm_A1"])
    frame = SummaryFrame.of(gj.run()).filter(U2=lambda u: u % 3 == 0)
    # every level's weights must sum to the same filtered count
    totals = {int(w.sum()) for w in frame.weights}
    assert totals == {frame.count()}


def test_string_domains_reject_numeric_aggregates():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    frame = SummaryFrame.of(gj.run())
    with pytest.raises(TypeError):
        frame.sum("A")
    # but counting and membership filters work on strings
    assert frame.count() == 32
    assert frame.filter(A=["a3"]).count() == frame.group_by("A")["count"][-1]


def test_unknown_variable_raises():
    cat, query = figure1()
    frame = SummaryFrame.of(GraphicalJoin(cat, query).run())
    with pytest.raises(KeyError):
        frame.count_distinct("Z")
