"""Plan IR, cost-based order search, executor, and plan-keyed caching.

Covers the planner contract:

* every admissible elimination order — min-fill, planner-chosen, forced,
  and (on small queries) *every* admissible permutation — produces the same
  ``join_size`` and the same desummarized row multiset (plan equivalence);
* the search only emits admissible orders (O' before O, output-var root);
* plan identity flows into fingerprints and cache keys;
* ``explain()`` renders order, per-step estimates, and backends;
* ``build_model`` re-entry clears downstream phase state (staleness fix);
* the serve-path feature provider pulls features through a pre-compiled
  plan and hits the summary cache on repeat calls.
"""

import itertools

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.core.oracle import oracle_join, sort_rows
from repro.plan import (CostModel, Executor, PhysicalPlan, QueryStats,
                        plan_query)
from repro.relational.encoding import encode_query
from repro.relational.query import JoinQuery
from repro.relational.synth import figure1, lastfm_like
from repro.relational.table import Catalog, Table
from repro.summary.service import JoinService


# ---------------------------------------------------------------------------
# random query instances (no hypothesis dependency: seeded numpy)
# ---------------------------------------------------------------------------

SHAPES = {
    "chain3": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
               ("t2", {"x0": "C", "x1": "D"})],
    "star3": [("t0", {"x0": "M", "x1": "A"}), ("t1", {"x0": "M", "x1": "B"}),
              ("t2", {"x0": "M", "x1": "C"})],
    "triangle": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
                 ("t2", {"x0": "C", "x1": "A"})],
    "cycle4": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
               ("t2", {"x0": "C", "x1": "D"}), ("t3", {"x0": "D", "x1": "A"})],
    "clique4": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "A", "x1": "C"}),
                ("t2", {"x0": "A", "x1": "D"}), ("t3", {"x0": "B", "x1": "C"}),
                ("t4", {"x0": "B", "x1": "D"}), ("t5", {"x0": "C", "x1": "D"})],
    "bowtie": [("t0", {"x0": "A", "x1": "B"}), ("t1", {"x0": "B", "x1": "C"}),
               ("t2", {"x0": "C", "x1": "A"}), ("t3", {"x0": "C", "x1": "D"}),
               ("t4", {"x0": "D", "x1": "E"}), ("t5", {"x0": "E", "x1": "C"})],
}

CYCLIC_SHAPES = ["triangle", "cycle4", "clique4", "bowtie"]


def _random_instance(shape: str, seed: int, output=None):
    rng = np.random.default_rng(seed)
    spec = SHAPES[shape]
    domain = int(rng.integers(2, 6))
    cat = Catalog()
    for tname, vm in spec:
        if tname in cat:
            continue
        nrows = int(rng.integers(0, 20))
        cat.add(Table(tname, {
            c: rng.integers(0, domain, size=nrows).astype(np.int64)
            for c in vm}))
    return cat, JoinQuery.of(shape, spec, output=output)


def _row_multiset(gj, gfjs, all_vars):
    """Desummarized rows as a sorted array over a fixed global var order."""
    res = gj.desummarize(gfjs, decode=False)
    if gfjs.join_size == 0:
        return np.zeros((0, len(all_vars)), np.int64)
    m = np.stack([res[v] for v in all_vars], axis=1)
    return m[np.lexsort(m.T[::-1])]


def _admissible_orders(variables, out_vars):
    """All permutations with non-output vars first (what the search emits)."""
    non_out = [v for v in variables if v not in out_vars]
    outs = [v for v in variables if v in out_vars]
    for p1 in itertools.permutations(non_out):
        for p2 in itertools.permutations(outs):
            yield list(p1) + list(p2)


# ---------------------------------------------------------------------------
# plan equivalence (satellite: property test over admissible orders)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", ["chain3", "star3", "triangle", "cycle4"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_all_admissible_orders_equivalent(shape, seed):
    cat, query = _random_instance(shape, seed)
    base = GraphicalJoin(cat, query)
    ref_gfjs = base.run()
    all_vars = sorted(query.variables)
    ref_rows = _row_multiset(base, ref_gfjs, all_vars)

    for order in _admissible_orders(query.variables, query.output_variables):
        gj = GraphicalJoin(cat, query, elimination_order=order)
        gfjs = gj.run()
        assert gfjs.join_size == ref_gfjs.join_size
        assert np.array_equal(_row_multiset(gj, gfjs, all_vars), ref_rows)


@pytest.mark.parametrize("seed", [3, 4])
def test_projected_orders_equivalent(seed):
    """Early projection: every admissible order agrees on the projection."""
    cat, query = _random_instance("chain3", seed, output=["A", "D"])
    base = GraphicalJoin(cat, query)
    ref = base.run()
    ref_rows = _row_multiset(base, ref, ["A", "D"])
    for order in _admissible_orders(query.variables, query.output_variables):
        gj = GraphicalJoin(cat, query, elimination_order=order)
        gfjs = gj.run()
        assert gfjs.join_size == ref.join_size
        assert np.array_equal(_row_multiset(gj, gfjs, ["A", "D"]), ref_rows)


@pytest.mark.parametrize("shape", ["chain3", "triangle", "cycle4"])
def test_planner_candidates_equivalent(shape):
    """min-fill, greedy, and beam candidates all produce the same result."""
    cat, query = _random_instance(shape, 7)
    enc = encode_query(cat, query)
    logical, phys = plan_query(enc)
    assert phys.alternatives, "search must report its candidates"
    all_vars = sorted(query.variables)
    ref = None
    for cand in phys.alternatives:
        gj = GraphicalJoin(cat, query, elimination_order=list(cand.order))
        gfjs = gj.run()
        rows = _row_multiset(gj, gfjs, all_vars)
        if ref is None:
            ref = (gfjs.join_size, rows)
        assert gfjs.join_size == ref[0]
        assert np.array_equal(rows, ref[1])


# ---------------------------------------------------------------------------
# search admissibility + cost model sanity
# ---------------------------------------------------------------------------

def test_search_orders_are_admissible():
    cat, qs = lastfm_like(n_users=40, n_artists=30, artists_per_user=3,
                          friends_per_user=2)
    q = JoinQuery(qs["lastfm_A1"].name, qs["lastfm_A1"].tables,
                  output=("A1", "A2"))
    enc = encode_query(cat, q)
    logical, phys = plan_query(enc)
    out = set(q.output_variables)
    for cand in phys.alternatives:
        order = list(cand.order)
        assert sorted(order) == sorted(q.variables)
        assert order[-1] in out                     # output-var root
        non_out = [v for v in order if v not in out]
        assert order[:len(non_out)] == non_out      # O' strictly first

    assert phys.order[-1] in out
    assert phys.est_cost >= 0.0


def test_cost_model_sees_skew():
    """Dot-product bounds rank a skewed self-join above a uniform one."""
    n = 4000
    rng = np.random.default_rng(0)
    skew = np.minimum((rng.pareto(0.7, n) * 3).astype(np.int64), 99)
    unif = rng.integers(0, 100, n).astype(np.int64)
    cat = Catalog.of(
        Table("s", {"k": skew, "v": np.arange(n, dtype=np.int64)}),
        Table("u", {"k": unif, "v": np.arange(n, dtype=np.int64)}),
    )
    def self_join_cost(tab, var):
        q = JoinQuery.of("sj", [(tab, {"k": var, "v": "L"}),
                                (tab, {"k": var, "v": "R"})])
        enc = encode_query(cat, q)
        model = CostModel(QueryStats.of(enc))
        steps, total = model.simulate([var, "L", "R"])
        return steps[0].product_entries
    assert self_join_cost("s", "K") > 2 * self_join_cost("u", "K")


def test_forced_order_and_min_fill_modes():
    cat, query = figure1()
    forced = GraphicalJoin(cat, query, elimination_order=["D", "C", "B", "A"])
    assert list(forced.plan().order) == ["D", "C", "B", "A"]
    assert forced.plan().source == "forced"
    mf = GraphicalJoin(cat, query, planner="min_fill")
    assert mf.plan().source == "min_fill"
    assert forced.run().join_size == mf.run().join_size == 32


# ---------------------------------------------------------------------------
# explain + plan identity
# ---------------------------------------------------------------------------

def test_explain_renders_order_steps_backends():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gj.run()
    text = gj.explain()
    plan = gj.plan()
    assert " -> ".join(plan.order) in text
    assert "eliminate" in text and "est_product=" in text
    assert "backends" in text and "numpy" in text
    assert "summarize" in text        # measured timings section
    assert plan.signature() in text


def test_plan_signature_and_fingerprint():
    cat, query = figure1()
    p1 = GraphicalJoin(cat, query, elimination_order=["D", "C", "B", "A"]).plan()
    p2 = GraphicalJoin(cat, query, elimination_order=["C", "B", "A", "D"]).plan()
    same = GraphicalJoin(cat, query, elimination_order=["D", "C", "B", "A"]).plan()
    assert p1.signature() == same.signature()
    assert p1.signature() != p2.signature()
    # fingerprint: plan-less stays stable, plan-ful differs per plan
    assert query.fingerprint() == query.fingerprint(plan=None)
    assert query.fingerprint(plan=p1) != query.fingerprint()
    assert query.fingerprint(plan=p1) != query.fingerprint(plan=p2)
    assert query.fingerprint(plan=p1) == query.fingerprint(plan=same)


def test_service_keys_on_plan_identity():
    cat, query = figure1()
    svc = JoinService(cat)
    r1 = svc.frame(query)
    assert r1.source == "computed" and r1.plan is not None
    # same query, same (cached) plan -> hit
    assert svc.frame(query).cache_hit
    # a different forced plan is a different summary
    other = GraphicalJoin(cat, query,
                          elimination_order=["B", "C", "D", "A"]).plan()
    r2 = svc.frame(query, plan=other)
    assert r2.source == "computed" and r2.key != r1.key
    assert svc.frame(query, plan=other).cache_hit
    assert svc.stats()["compiled_plans"] == 1


# ---------------------------------------------------------------------------
# executor state machine (satellite: phase-state staleness fix)
# ---------------------------------------------------------------------------

def test_build_model_reentry_resets_downstream_state():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gj.run()
    stale_gen = gj.generator
    assert stale_gen is not None and "summarize" in gj.timings
    gj.build_model()                      # re-plan entry point
    assert gj.generator is None           # no stale generator survives
    assert "summarize" not in gj.timings  # downstream timings cleared
    assert "build_generator" not in gj.timings
    gfjs = gj.run()                       # pipeline rebuilds cleanly
    assert gfjs.join_size == 32
    assert gj.generator is not stale_gen


def test_post_construction_mutation_is_live():
    """The historical pattern: set elimination_order on an existing gj."""
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gj.run()                                   # planner picked some order
    gj.elimination_order = ["D", "C", "B", "A"]
    gj.build_model()
    gfjs = gj.run()
    assert list(gj.plan().order) == ["D", "C", "B", "A"]
    assert gj.plan().source == "forced"
    assert gfjs.join_size == 32


def test_executor_runs_precompiled_plan():
    cat, query = figure1()
    plan = GraphicalJoin(cat, query).plan()
    ex = Executor(cat, query, plan=plan)
    gfjs = ex.run()
    assert gfjs.join_size == 32
    assert ex.plan is plan                # pinned, not re-searched
    assert list(gfjs.column_order)[0] == plan.order[-1]
    # materialize honors the plan (inmem on these sizes)
    out = ex.materialize(gfjs, decode=False)
    assert isinstance(out, dict)


def test_executor_jax_desummarize_matches_numpy():
    cat, query = figure1()
    ex = Executor(cat, query)
    gfjs = ex.run()
    ref = ex.desummarize(gfjs, decode=False)
    ex.plan.backends["desummarize"] = "jax"
    got = ex.desummarize(gfjs, decode=False)
    for v in gfjs.column_order:
        assert np.array_equal(ref[v], got[v])


# ---------------------------------------------------------------------------
# hypertree-decomposed hybrid GJ/WCOJ execution (DESIGN §19)
# ---------------------------------------------------------------------------

def _assert_gfjs_identical(a, b):
    """Level-for-level bit-identity: the hybrid contract, not just multiset
    equality — same column order, same runs, same codes, same freqs."""
    assert a.column_order == b.column_order
    assert a.join_size == b.join_size
    assert len(a.levels) == len(b.levels)
    for la, lb in zip(a.levels, b.levels):
        assert la.vars == lb.vars
        assert np.array_equal(la.freq, lb.freq)
        for v in la.vars:
            assert np.array_equal(la.key_cols[v], lb.key_cols[v])


def _oracle_rows(cat, query, all_vars):
    enc = encode_query(cat, query)
    res = oracle_join(enc)
    if len(res[all_vars[0]]) == 0:
        return np.zeros((0, len(all_vars)), np.int64)
    return sort_rows(res, all_vars)


@pytest.mark.parametrize("shape", CYCLIC_SHAPES)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_hybrid_gfjs_bit_identical(shape, seed):
    """Every random cyclic instance: the hypertree plan's GFJS equals the
    pure-GJ GFJS level for level, and both equal the oracle multiset."""
    cat, query = _random_instance(shape, seed)
    hyb = GraphicalJoin(cat, query, hybrid=True)
    g_h = hyb.run()
    plan = hyb.plan()
    assert plan.bags and plan.source == "hybrid"
    for bag in plan.bags:
        assert len(bag.occurrences) >= 2
        assert sorted(bag.bind_order) == sorted(bag.vars)
    pure = GraphicalJoin(cat, query, hybrid=False,
                         elimination_order=list(plan.order))
    g_p = pure.run()
    assert not pure.plan().bags
    _assert_gfjs_identical(g_h, g_p)
    all_vars = sorted(query.variables)
    rows = _row_multiset(hyb, g_h, all_vars)
    assert np.array_equal(rows, _row_multiset(pure, g_p, all_vars))
    assert np.array_equal(rows, _oracle_rows(cat, query, all_vars))


@pytest.mark.parametrize("seed", [5, 6])
def test_hybrid_every_admissible_order_triangle(seed):
    cat, query = _random_instance("triangle", seed)
    ref = None
    for order in _admissible_orders(query.variables, query.output_variables):
        hyb = GraphicalJoin(cat, query, hybrid=True,
                            elimination_order=order)
        g_h = hyb.run()
        assert hyb.plan().bags
        pure = GraphicalJoin(cat, query, hybrid=False,
                             elimination_order=order)
        _assert_gfjs_identical(g_h, pure.run())
        rows = _row_multiset(hyb, g_h, sorted(query.variables))
        if ref is None:
            ref = rows
        assert np.array_equal(rows, ref)


def test_hybrid_cost_picked_on_skew():
    """On the hub-skewed triangle the cost model itself chooses the
    hybrid plan (no forcing) and the answer matches pure GJ."""
    from repro.relational.synth import cyclic_pattern_like
    cat, query = cyclic_pattern_like("triangle", m=400, domain=2000,
                                     dense=80, dense_domain=20, seed=0)
    gj = GraphicalJoin(cat, query)            # hybrid=None: model decides
    plan = gj.plan()
    assert plan.source == "hybrid" and plan.bags
    g_h = gj.run()
    pure = GraphicalJoin(cat, query, hybrid=False,
                         elimination_order=list(plan.order))
    _assert_gfjs_identical(g_h, pure.run())


def test_acyclic_never_bagged_and_signature_stable():
    """Acyclic queries are never bagged, and their plan signatures (hence
    cache keys) are byte-identical whatever the hybrid knob says."""
    cat, query = figure1()
    default = GraphicalJoin(cat, query).plan()
    off = GraphicalJoin(cat, query, hybrid=False).plan()
    assert default.bags == () and off.bags == ()
    assert default.signature() == off.signature()
    assert "bags" not in default.explain()
    cat2, q2 = _random_instance("chain3", 9)
    assert GraphicalJoin(cat2, q2).plan().bags == ()


def test_hybrid_knob_validation():
    cat, query = figure1()                    # acyclic
    with pytest.raises(ValueError, match="cyclic"):
        GraphicalJoin(cat, query, hybrid=True).plan()
    tcat, tq = _random_instance("triangle", 0)
    with pytest.raises(ValueError, match="record_trace"):
        GraphicalJoin(tcat, tq, hybrid=True, record_trace=True)
    with pytest.raises(ValueError, match="partitions"):
        plan_query(encode_query(tcat, tq), hybrid=True, partitions=2)
    # a pre-compiled bagged plan + record_trace is refused up front
    bagged = GraphicalJoin(tcat, tq, hybrid=True).plan()
    if bagged.bags:
        with pytest.raises(ValueError, match="record_trace"):
            Executor(tcat, tq, plan=bagged, record_trace=True)
    # record_trace wins over a cost-picked hybrid: plan silently pure
    traced = GraphicalJoin(tcat, tq, record_trace=True)
    assert traced.plan().bags == ()


def test_bagged_plan_signature_differs():
    cat, query = _random_instance("triangle", 1)
    hyb = GraphicalJoin(cat, query, hybrid=True).plan()
    pure = GraphicalJoin(cat, query, hybrid=False,
                         elimination_order=list(hyb.order)).plan()
    assert hyb.bags and not pure.bags
    assert hyb.signature() != pure.signature()
    assert query.fingerprint(plan=hyb) != query.fingerprint(plan=pure)


# ---------------------------------------------------------------------------
# cost-model calibration from measured drift (satellite: feedback loop)
# ---------------------------------------------------------------------------

def test_executor_calibration_factors():
    cat, query = _random_instance("triangle", 2)
    gj = GraphicalJoin(cat, query, hybrid=True)
    gj.run()
    ex = gj._executor
    calib = ex.calibration()
    assert set(calib) == {"eliminate", "bag"}
    assert all(v > 0.0 for v in calib.values())
    text = gj.explain(analyze=True)
    assert "calibration" in text and "calib=" in text
    # geometric mean of actual/est, computed straight from the drift records
    est = {s.var: float(s.product_entries) for s in gj.plan().steps}
    expect = CostModel.drift_factor(est, ex.step_actuals)
    assert calib["eliminate"] == pytest.approx(expect)


def test_cost_model_consumes_corrections():
    cat, query = _random_instance("triangle", 3)
    enc = encode_query(cat, query)
    stats = QueryStats.of(enc)
    raw = CostModel(stats)
    order = list(plan_query(enc)[1].order)
    steps_raw, total_raw = raw.simulate(order)
    # a calibrated model scales its eliminate estimates by the correction
    cal = CostModel(stats, corrections={"eliminate": 2.0})
    steps_cal, total_cal = cal.simulate(order)
    for a, b in zip(steps_raw, steps_cal):
        if a.product_entries > 0:
            assert b.product_entries == pytest.approx(2.0 * a.product_entries)
    # calibrate() folds measured drift into the model in place
    model = CostModel(stats)
    got = model.calibrate({"A": 100.0}, {"A": 50.0})
    assert got["eliminate"] == pytest.approx(0.5)
    assert model.corrections["eliminate"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# serve wire-in: features through a pre-compiled plan
# ---------------------------------------------------------------------------

def test_relational_feature_provider():
    from repro.serve.engine import RelationalFeatureProvider
    cat, qs = lastfm_like(n_users=50, n_artists=40, artists_per_user=4,
                          friends_per_user=3)
    q = qs["lastfm_A1"]
    svc = JoinService(cat)
    prov = RelationalFeatureProvider(
        svc, q, key_var="U1",
        aggs={"n_rows": "count", "n_artists": ("count", None)})
    keys = np.asarray([0, 1, 10**9])      # last key unknown -> zeros
    feats = prov.features(keys)
    assert feats.shape == (3, 2) and feats.dtype == np.float32
    assert np.all(feats[2] == 0.0)
    # ground truth from the service's own group_by
    tab = svc.group_by(q, "U1", n="count")
    for i, k in enumerate(keys[:2]):
        m = tab["U1"] == k
        expect = float(tab["n"][m][0]) if m.any() else 0.0
        assert feats[i, 0] == expect
    # repeat pull is a cache hit (no second join)
    before = svc.stats()["misses"]
    prov.refresh()
    prov.features(keys)
    assert svc.stats()["misses"] == before
