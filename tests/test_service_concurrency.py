"""JoinService / SummaryCache under threads, TTL, explicit invalidation
(ROADMAP "JoinService concurrency" item), and incremental-refresh races:
an append hammer must never let a reader observe a half-spliced summary —
every reply is either the old-consistent or the new-consistent state."""

import threading
import time

import numpy as np
import pytest

from repro.relational.query import JoinQuery
from repro.relational.synth import lastfm_like
from repro.relational.table import Catalog, Table
from repro.summary.cache import SummaryCache, cache_key
from repro.summary.service import JoinService


@pytest.fixture(scope="module")
def lastfm():
    return lastfm_like(n_users=50, n_artists=40, artists_per_user=4,
                       friends_per_user=3)


def test_concurrent_requests_agree(lastfm):
    cat, qs = lastfm
    svc = JoinService(cat)
    queries = [qs["lastfm_A1"], qs["lastfm_B"], qs["lastfm_tri"]]
    expected = [svc.count(q) for q in queries]

    results, errors = [], []

    def worker(i):
        try:
            for _ in range(5):
                for q, want in zip(queries, expected):
                    got = svc.count(q)
                    if got != want:
                        results.append((i, got, want))
        except Exception as e:  # pragma: no cover - the assertion target
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert not results
    st = svc.stats()
    # every request did exactly one cache lookup; none lost under the lock
    assert st["hits"] + st["disk_hits"] + st["misses"] == st["requests"]


def test_concurrent_cold_start_single_query(lastfm):
    """Many threads racing the same cold query: all agree, no crash."""
    cat, qs = lastfm
    svc = JoinService(cat)
    out, errors = [], []

    def worker():
        try:
            out.append(svc.count(qs["lastfm_A1"]))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(set(out)) == 1


def test_ttl_expires_resident_entries(lastfm):
    cat, qs = lastfm
    svc = JoinService(cat, ttl_seconds=0.05)
    q = qs["lastfm_A1"]
    assert svc.frame(q).source == "computed"
    assert svc.frame(q).cache_hit                 # within TTL
    time.sleep(0.06)
    reply = svc.frame(q)                          # expired -> recompute
    assert reply.source == "computed"
    assert svc.cache.stats.expirations >= 1


def test_ttl_expires_spilled_entries(tmp_path, lastfm):
    cat, qs = lastfm
    q = qs["lastfm_A1"]
    cache = SummaryCache(byte_budget=1, spill_dir=str(tmp_path),
                         ttl_seconds=0.05)
    svc = JoinService(cat, cache=cache)
    svc.frame(q)
    other = qs["lastfm_B"]
    svc.frame(other)                              # evicts + spills A1
    time.sleep(0.06)
    reply = svc.frame(q)
    assert reply.source == "computed"             # spill file expired
    assert cache.stats.expirations >= 1


def test_ttl_measures_creation_not_promotion(tmp_path, lastfm):
    """Evict/promote cycles must not restart the TTL clock."""
    cat, qs = lastfm
    q = qs["lastfm_A1"]
    cache = SummaryCache(byte_budget=1, spill_dir=str(tmp_path),
                         ttl_seconds=0.3)
    svc = JoinService(cat, cache=cache)
    svc.frame(q)                                  # born at t0
    svc.frame(qs["lastfm_B"])                     # evicts + spills q
    time.sleep(0.1)
    assert svc.frame(q).source == "disk"          # promoted, still born t0
    time.sleep(0.25)                              # 0.35 > ttl since *birth*
    assert svc.frame(q).source == "computed"
    assert cache.stats.expirations >= 1


def test_invalidate_table_drops_exactly_matching(lastfm, tmp_path):
    cat, qs = lastfm
    svc = JoinService(cat, spill_dir=str(tmp_path))
    a1, tri = qs["lastfm_A1"], qs["lastfm_tri"]
    svc.frame(a1)       # uses user_artists + user_friends
    svc.frame(tri)      # uses user_friends only
    assert svc.frame(a1).cache_hit and svc.frame(tri).cache_hit

    removed = svc.invalidate("user_artists")
    assert removed >= 1
    assert svc.frame(a1).source == "computed"     # dropped
    assert svc.frame(tri).cache_hit               # untouched
    assert svc.cache.stats.invalidations >= 1

    # invalidating a table nobody used is a no-op
    assert svc.invalidate("no_such_table") == 0


def test_invalidate_covers_spill_files(tmp_path, lastfm):
    cat, qs = lastfm
    cache = SummaryCache(byte_budget=1, spill_dir=str(tmp_path))
    svc = JoinService(cat, cache=cache)
    svc.frame(qs["lastfm_A1"])
    svc.frame(qs["lastfm_B"])                     # spills A1 to disk
    assert cache.stats.spills >= 1
    svc.invalidate("user_artists")                # both used user_artists
    # nothing comes back from disk: both recompute
    assert svc.frame(qs["lastfm_A1"]).source == "computed"
    assert svc.frame(qs["lastfm_B"]).source == "computed"


def test_invalidate_counts_logical_entries_once(tmp_path, lastfm):
    """An entry both resident and spilled is one entry, not two."""
    cat, qs = lastfm
    cache = SummaryCache(byte_budget=1, spill_dir=str(tmp_path))
    svc = JoinService(cat, cache=cache)
    svc.frame(qs["lastfm_A1"])
    svc.frame(qs["lastfm_B"])       # evicts + spills A1
    svc.frame(qs["lastfm_A1"])      # promotes A1: resident AND on disk
    assert cache.stats.spills >= 1
    removed = svc.invalidate("user_artists")
    assert removed == 2             # A1 and B, each counted once


def test_provenance_pruned_with_evictions(lastfm):
    """Without a spill dir, evicted/cleared keys leave no _tables residue."""
    cat, qs = lastfm
    cache = SummaryCache(byte_budget=1)     # no spill_dir
    svc = JoinService(cat, cache=cache)
    for q in (qs["lastfm_A1"], qs["lastfm_B"], qs["lastfm_tri"]):
        svc.frame(q)
    # budget of 1 byte keeps at most one resident entry; evicted keys must
    # not accumulate provenance (version churn would grow it forever)
    assert len(cache._tables) <= len(cache._entries)


def test_plan_cache_is_bounded(lastfm):
    cat, qs = lastfm
    svc = JoinService(cat, max_plans=2)
    for q in (qs["lastfm_A1"], qs["lastfm_B"], qs["lastfm_tri"],
              qs["lastfm_A2"]):
        svc.compile(q)
    assert svc.stats()["compiled_plans"] <= 2


def _row_count_service(n_base: int = 50):
    """A service over a single-table query: COUNT == exact table rows.

    Every append of r rows moves the true count by exactly r, so any
    value a reader observes must sit on the append lattice — a torn
    splice (half-refreshed weights) lands between lattice points.
    """
    rng = np.random.default_rng(0)
    t = Table("events", {"x0": rng.integers(0, 9, n_base).astype(np.int64),
                         "x1": rng.integers(0, 9, n_base).astype(np.int64)})
    q = JoinQuery.of("events_q", [("events", {"x0": "A", "x1": "B"})])
    return JoinService(Catalog.of(t)), q


def test_refresh_vs_get_race():
    """Append hammer vs readers: old-consistent or new-consistent, only."""
    base, block, n_appends = 50, 3, 12
    svc, q = _row_count_service(base)
    assert svc.count(q) == base
    legal = {base + i * block for i in range(n_appends + 1)}
    errors, observed = [], []
    stop = threading.Event()
    rng = np.random.default_rng(1)
    blocks = [{"x0": rng.integers(0, 12, block).astype(np.int64),
               "x1": rng.integers(0, 12, block).astype(np.int64)}
              for _ in range(n_appends)]

    def appender():
        try:
            for b in blocks:
                svc.append("events", b)
                svc.frame(q)            # trigger refresh under contention
        except Exception as e:  # pragma: no cover
            errors.append(e)
        finally:
            stop.set()

    def reader():
        try:
            last = 0
            while not stop.is_set():
                reply = svc.frame(q)
                n = reply.frame.count()
                # internal consistency: every level agrees on the total
                totals = {int(w.sum()) for w in reply.frame.weights}
                if totals != {n}:
                    errors.append(AssertionError(f"torn summary: {totals}"))
                observed.append(n)
                if n < last:
                    errors.append(AssertionError(f"count went back: {last}->{n}"))
                last = n
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(6)] \
        + [threading.Thread(target=appender)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert set(observed) <= legal, sorted(set(observed) - legal)
    assert svc.count(q) == base + n_appends * block
    assert svc.stats()["refreshed_requests"] >= 1


def test_refresh_vs_invalidate_race():
    """invalidate() racing the append/refresh loop: no torn state, and the
    final answer equals a cold recompute either way."""
    svc, q = _row_count_service(40)
    svc.frame(q)
    errors = []
    stop = threading.Event()
    rng = np.random.default_rng(2)

    def appender():
        try:
            for _ in range(10):
                svc.append("events",
                           {"x0": rng.integers(0, 12, 2).astype(np.int64),
                            "x1": rng.integers(0, 12, 2).astype(np.int64)})
                reply = svc.frame(q)
                totals = {int(w.sum()) for w in reply.frame.weights}
                if len(totals) != 1:
                    errors.append(AssertionError(f"torn summary: {totals}"))
        except Exception as e:  # pragma: no cover
            errors.append(e)
        finally:
            stop.set()

    def invalidator():
        try:
            while not stop.is_set():
                svc.invalidate("events")
                time.sleep(0.001)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                reply = svc.frame(q)
                totals = {int(w.sum()) for w in reply.frame.weights}
                if len(totals) != 1:
                    errors.append(AssertionError(f"torn summary: {totals}"))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=appender),
               threading.Thread(target=invalidator)] \
        + [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert svc.count(q) == svc.catalog["events"].num_rows
    cold = JoinService(svc.catalog, incremental=False)
    assert cold.count(q) == svc.count(q)


def test_append_while_cold_compute_in_flight():
    """An append landing mid-compute must not corrupt the cache: later
    frames converge to the grown catalog's answer."""
    svc, q = _row_count_service(30)
    errors, done = [], threading.Event()

    def computer():
        try:
            svc.frame(q)
        except Exception as e:  # pragma: no cover
            errors.append(e)
        finally:
            done.set()

    t = threading.Thread(target=computer)
    t.start()
    svc.append("events", {"x0": np.asarray([1, 2]), "x1": np.asarray([3, 4])})
    t.join()
    done.wait()
    assert not errors
    assert svc.count(q) == svc.catalog["events"].num_rows


def test_cache_lock_guards_raw_operations(lastfm):
    """Hammer get/put/invalidate from threads directly on the cache."""
    cat, qs = lastfm
    svc = JoinService(cat)
    gfjs_frame = svc.frame(qs["lastfm_tri"]).frame
    gfjs = gfjs_frame.gfjs
    cache = SummaryCache(byte_budget=4 << 20)
    errors = []

    def worker(i):
        try:
            for j in range(50):
                k = f"k{(i * 7 + j) % 5}"
                cache.put(k, gfjs, tables={"user_friends"})
                cache.get(k)
                if j % 10 == 0:
                    cache.invalidate("user_friends")
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors


def test_aux_nbytes_hammer_vs_lockless_growers(lastfm):
    """The PR 4 race: cache re-measurement iterates ``_bounds``/``_launch``
    while reader threads grow them lockless (``bounds()`` memoization,
    kernel-meta inserts).  ``aux_nbytes`` must snapshot keys defensively —
    no "dict changed size during iteration", ever, and every returned
    value a sane non-negative byte count."""
    cat, qs = lastfm
    svc = JoinService(cat)
    gfjs = svc.frame(qs["lastfm_tri"]).frame.gfjs
    nlevels = len(gfjs.levels)
    stop = threading.Event()
    errors = []

    def grower(i):
        try:
            arr = np.arange(64, dtype=np.int64)
            j = 0
            while not stop.is_set():
                lvl = (i + j) % nlevels
                gfjs.bounds(lvl)
                # simulate repro.kernels.ops.gfjs_expand_meta's lockless
                # replace-insert of launch metadata
                gfjs._launch[lvl] = (64 + j, (arr, arr))
                if j % 17 == 0:
                    gfjs._bounds.pop(lvl, None)
                    gfjs._launch.pop(lvl, None)
                j += 1
        except Exception as e:  # pragma: no cover - the assertion target
            errors.append(e)

    def measurer():
        try:
            while not stop.is_set():
                n = gfjs.resident_nbytes()
                assert n >= gfjs.nbytes()
        except Exception as e:  # pragma: no cover - the assertion target
            errors.append(e)

    threads = [threading.Thread(target=grower, args=(i,)) for i in range(4)]
    threads += [threading.Thread(target=measurer) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors


# -- ISSUE 8: serving-tier collapse correctness + lock-scope bugfixes -------

def _gate_frames(svc, entered, release):
    """Shadow ``svc.frame`` with an entered/release gate (call-counting)."""
    orig = svc.frame
    calls = []

    def gated(query, plan=None):
        calls.append(query.name)
        entered.set()
        assert release.wait(10.0), "gate never released"
        return orig(query, plan=plan)

    svc.frame = gated
    return calls


def test_collapse_stampede_exactly_one_build():
    """16 threads x one cold query: one "computed", 15 "collapsed", every
    reply the same key and the same frame."""
    from repro.serve.server import JoinServer

    svc, q = _row_count_service(50)
    plan = svc.compile(q)
    server = JoinServer(svc)
    entered, release = threading.Event(), threading.Event()
    calls = _gate_frames(svc, entered, release)

    N = 16
    replies, errors = [None] * N, []

    def worker(i):
        try:
            replies[i] = server.frame(q, plan=plan)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    ts[0].start()
    assert entered.wait(10.0)
    for t in ts[1:]:
        t.start()
    while sum(fl.waiters
              for fl in server._flights._flights.values()) < N - 1:
        time.sleep(0.001)
    release.set()
    for t in ts:
        t.join()

    assert not errors
    assert calls == [q.name]                    # exactly one service build
    sources = sorted(r.source for r in replies)
    assert sources.count("computed") == 1
    assert sources.count("collapsed") == N - 1
    assert len({r.key for r in replies}) == 1
    assert len({r.frame.count() for r in replies}) == 1


def test_append_mid_collapse_version_consistent():
    """An append landing while a stampede is parked on the latch: every
    reply (leader and waiters alike) reflects ONE catalog state."""
    from repro.serve.server import JoinServer

    base, grow = 40, 5
    svc, q = _row_count_service(base)
    plan = svc.compile(q)
    server = JoinServer(svc)
    entered, release = threading.Event(), threading.Event()
    _gate_frames(svc, entered, release)

    N = 8
    replies, errors = [None] * N, []

    def worker(i):
        try:
            replies[i] = server.frame(q, plan=plan)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    ts[0].start()
    assert entered.wait(10.0)
    for t in ts[1:]:
        t.start()
    while sum(fl.waiters
              for fl in server._flights._flights.values()) < N - 1:
        time.sleep(0.001)
    # the leader is parked pre-build: this append lands mid-collapse
    rng = np.random.default_rng(7)
    svc.append("events", {"x0": rng.integers(0, 9, grow).astype(np.int64),
                          "x1": rng.integers(0, 9, grow).astype(np.int64)})
    release.set()
    for t in ts:
        t.join()

    assert not errors
    counts = {r.frame.count() for r in replies}
    assert len(counts) == 1                 # never a mix of old/new state
    assert counts <= {base, base + grow}    # a lattice point, not a tear
    assert len({r.key for r in replies}) == 1
    # the service converges on the grown catalog afterwards
    assert svc.count(q) == base + grow


def test_slow_spill_does_not_stall_cache_hit(tmp_path, monkeypatch):
    """ISSUE 8 satellite: the refresh-commit eviction spill runs OUTSIDE
    the service lock — a 1s disk write must not block a cache-hit frame."""
    import repro.summary.cache as cache_mod
    from repro.summary.cache import SummaryCache

    rng = np.random.default_rng(0)
    events = Table("events",
                   {"x0": rng.integers(0, 9, 50).astype(np.int64),
                    "x1": rng.integers(0, 9, 50).astype(np.int64)})
    other = Table("other",
                  {"y0": rng.integers(0, 9, 30).astype(np.int64),
                   "y1": rng.integers(0, 9, 30).astype(np.int64)})
    q = JoinQuery.of("events_q", [("events", {"x0": "A", "x1": "B"})])
    q2 = JoinQuery.of("other_q", [("other", {"y0": "C", "y1": "D"})])
    cache = SummaryCache(byte_budget=1, spill_dir=str(tmp_path))
    svc = JoinService(Catalog.of(events, other), cache=cache)

    svc.frame(q)                    # retains incremental state for events_q
    svc.frame(q2)                   # evicts events entry; "other" resident
    svc.append("events", {"x0": np.asarray([1, 2], np.int64),
                          "x1": np.asarray([3, 4], np.int64)})

    entered = threading.Event()
    real_save = cache_mod.save_gfjs

    def slow_save(gfjs, path):
        entered.set()
        time.sleep(1.0)             # a slow disk
        return real_save(gfjs, path)

    monkeypatch.setattr(cache_mod, "save_gfjs", slow_save)

    errors, done = [], threading.Event()

    def refresher():
        try:
            # delta refresh -> cache.refresh admit -> budget evicts q2's
            # entry -> deferred spill hits the slow disk
            reply = svc.frame(q)
            assert reply.source == "refreshed"
        except Exception as e:  # pragma: no cover
            errors.append(e)
        finally:
            done.set()

    t = threading.Thread(target=refresher)
    t.start()
    assert entered.wait(10.0)       # the spill write is in progress
    t0 = time.perf_counter()
    reply = svc.frame(q)            # hit on the freshly-admitted entry
    dt = time.perf_counter() - t0
    t.join()
    done.wait()
    assert not errors
    assert reply.cache_hit
    # with the spill inside the lock this is ~1s; outside it is ~ms
    assert dt < 0.5, f"cache-hit frame stalled {dt:.3f}s behind a spill"
    assert cache.stats.spills >= 1


def test_append_hammer_stages_each_block_once():
    """ISSUE 8 satellite: per-table append locks — k appenders stage k
    copies total, never the O(k^2) lost-race restaging."""
    svc, q = _row_count_service(30)
    n_threads, per_thread, block = 8, 3, 2

    stagings = []
    real_append = Table.append

    def counting_append(self, rows):
        stagings.append(self.name)
        return real_append(self, rows)

    Table.append = counting_append
    try:
        rng = np.random.default_rng(5)
        blocks = [{"x0": rng.integers(0, 9, block).astype(np.int64),
                   "x1": rng.integers(0, 9, block).astype(np.int64)}
                  for _ in range(n_threads * per_thread)]
        errors = []

        def appender(i):
            try:
                for j in range(per_thread):
                    svc.append("events", blocks[i * per_thread + j])
            except Exception as e:  # pragma: no cover
                errors.append(e)

        ts = [threading.Thread(target=appender, args=(i,))
              for i in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errors
    finally:
        Table.append = real_append

    total = n_threads * per_thread
    # one staging copy per logical append: the lost-race retry never fired
    assert len(stagings) == total, f"{len(stagings)} stagings for {total}"
    assert svc.catalog["events"].num_rows == 30 + total * block
    assert svc.count(q) == 30 + total * block


def test_feature_provider_stampede_recomputes_once():
    """ISSUE 8 satellite: the provider's memo rebuild is single-flight —
    a post-append stampede computes the per-key table exactly once."""
    from repro.obs.metrics import REGISTRY
    from repro.serve.engine import RelationalFeatureProvider

    svc, q = _row_count_service(40)
    prov = RelationalFeatureProvider(svc, q, key_var="A",
                                     aggs={"n": "count"})
    keys = np.arange(9)
    counter = REGISTRY.counter("serve.feature_recomputes")
    base = counter.value
    warm = prov.features(keys)
    assert counter.value - base == 1
    svc.append("events", {"x0": np.zeros(4, np.int64),
                          "x1": np.ones(4, np.int64)})

    entered, release = threading.Event(), threading.Event()
    real_table = prov._feature_table

    def gated_table():
        entered.set()
        assert release.wait(10.0)
        return real_table()

    prov._feature_table = gated_table

    N = 8
    outs, errors = [None] * N, []

    def worker(i):
        try:
            outs[i] = prov.features(keys)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
    ts[0].start()
    assert entered.wait(10.0)
    for t in ts[1:]:
        t.start()
    while sum(fl.waiters
              for fl in prov._flight._flights.values()) < N - 1:
        time.sleep(0.001)
    release.set()
    for t in ts:
        t.join()

    assert not errors
    assert counter.value - base == 2        # warm + ONE stampede rebuild
    assert outs[0][0, 0] == warm[0, 0] + 4  # key 0 grew by the append
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


# ---------------------------------------------------------------------------
# elimination-message cache: single-flight under threads (DESIGN.md §20)
# ---------------------------------------------------------------------------

def _toy_message():
    from repro.core.potentials import Factor
    return Factor(("X",), np.array([[0], [1]]), np.array([1, 1]),
                  np.array([1, 1]), (2,))


def test_msgcache_single_flight_leader_publishes():
    """Deterministic latch handoff: the follower blocks on the leader's
    flight and adopts the published entry (counted as a wait)."""
    from repro.summary.msgcache import MessageCache
    mc = MessageCache()
    entry, flight = mc.lookup_or_begin("k")
    assert entry is None and flight is not None
    out = []
    t = threading.Thread(target=lambda: out.append(mc.lookup_or_begin("k")))
    t.start()
    time.sleep(0.05)                       # let the follower park on the latch
    mc.publish("k", flight, None, _toy_message(), tables=("t",))
    t.join(10.0)
    assert not t.is_alive()
    (e, f), = out
    assert f is None and e is not None
    assert mc.stats.waits == 1 and mc.stats.puts == 1


def test_msgcache_single_flight_abandon_promotes_follower():
    """A leader that abandons (compute failed) releases the latch; the
    follower retries and becomes the new leader instead of failing."""
    from repro.summary.msgcache import MessageCache
    mc = MessageCache()
    _, flight = mc.lookup_or_begin("k")
    out = []
    t = threading.Thread(target=lambda: out.append(mc.lookup_or_begin("k")))
    t.start()
    time.sleep(0.05)
    mc.abandon("k", flight)
    t.join(10.0)
    assert not t.is_alive()
    (e2, f2), = out
    assert e2 is None and f2 is not None   # promoted to leader
    mc.publish("k", f2, None, _toy_message())
    assert mc.get("k") is not None


def test_msgcache_single_flight_timeout_computes_locally():
    """A stuck leader can only delay a follower, never wedge it: past
    flight_timeout the follower computes locally and publishes nothing."""
    from repro.summary.msgcache import MessageCache
    mc = MessageCache(flight_timeout=0.05)
    _, flight = mc.lookup_or_begin("k")    # leader that never publishes
    e, f = mc.lookup_or_begin("k")
    assert e is None and f is None
    assert mc.stats.timeouts == 1
    mc.abandon("k", flight)


def test_msgcache_concurrent_builds_agree():
    """Threads racing overlapping queries through one shared MessageCache:
    every warm answer equals its cache-disabled cold build, and shared
    subtrees were computed fewer times than they were consumed."""
    from repro.core.api import GraphicalJoin
    from repro.relational.query import JoinQuery, QueryTable
    from repro.summary.msgcache import MessageCache

    rng = np.random.default_rng(7)
    cat = Catalog.of(
        Table("dim", {"id": np.arange(120),
                      "sub": rng.integers(0, 10, 120)}),
        Table("sub", {"id": np.arange(10), "val": rng.integers(0, 4, 10)}),
        *[Table(f"fact{f}", {"u": rng.integers(0, 8, 500),
                             "d": rng.integers(0, 120, 500)})
          for f in range(4)])

    def q(f):
        return JoinQuery(f"q{f}", (
            QueryTable.of(f"fact{f}", {"u": "U", "d": "D"}),
            QueryTable.of("dim", {"id": "D", "sub": "S"}),
            QueryTable.of("sub", {"id": "S", "val": "V"})), output=("U",))

    queries = [q(f) for f in range(4)]
    truth = [GraphicalJoin(cat, x).run().join_size for x in queries]
    mc = MessageCache()
    errors, bad = [], []

    def worker(i):
        try:
            for r in range(3):
                x = queries[(i + r) % len(queries)]
                got = GraphicalJoin(cat, x, message_cache=mc).run().join_size
                want = truth[(i + r) % len(queries)]
                if got != want:
                    bad.append((x.name, got, want))
        except Exception as e:  # pragma: no cover - the assertion target
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors and not bad
    st = mc.stats
    # the chain subtree (V, S) is shared by all four queries: it must have
    # been computed strictly fewer times than it was consumed
    assert st.hits + st.waits > 0
    assert st.puts < st.hits + st.waits + st.misses


def test_service_threads_share_message_cache():
    """JoinService threads on cold overlapping queries: answers agree and
    the service-level msgcache counters are visible in stats()."""
    rng = np.random.default_rng(11)
    cat = Catalog.of(
        Table("dim", {"id": np.arange(80), "sub": rng.integers(0, 8, 80)}),
        Table("sub", {"id": np.arange(8), "val": rng.integers(0, 3, 8)}),
        *[Table(f"fact{f}", {"u": rng.integers(0, 6, 300),
                             "d": rng.integers(0, 80, 300)})
          for f in range(3)])
    from repro.relational.query import QueryTable

    def q(f):
        return JoinQuery(f"q{f}", (
            QueryTable.of(f"fact{f}", {"u": "U", "d": "D"}),
            QueryTable.of("dim", {"id": "D", "sub": "S"}),
            QueryTable.of("sub", {"id": "S", "val": "V"})), output=("U",))

    queries = [q(f) for f in range(3)]
    # incremental off: service builds run untraced, so message reuse is on
    svc = JoinService(cat, incremental=False)
    expected = [JoinService(Catalog(dict(cat.tables)),
                            incremental=False,
                            message_reuse=False).count(x) for x in queries]
    errors, bad = [], []

    def worker(i):
        try:
            for r in range(4):
                j = (i + r) % len(queries)
                got = svc.count(queries[j])
                if got != expected[j]:
                    bad.append((j, got, expected[j]))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors and not bad
    st = svc.stats()
    assert st["msgcache_puts"] > 0
    assert st["msgcache_hits"] + st["msgcache_waits"] >= 0
