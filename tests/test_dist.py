"""Distributed tests on fake multi-device CPU meshes (subprocess-isolated:
XLA fixes the device count at first jax init, so these run via a child
python with XLA_FLAGS set — the main pytest process keeps 1 device).

The partition-layer tests force 4 (or 8) virtual devices and hold the
hash-partitioned pipeline (repro/dist/partition.py + ShardedGFJS) to the
monolithic numpy oracle; the training tests exercise the model-side DP/
GSPMD paths."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

# depth tier (DESIGN.md §13): deselect with -m "not slow"
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_potential_counts_match_single_device():
    res = run_child(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.dist.partition import sharded_potential_counts
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        codes = jnp.asarray(rng.integers(0, 50, 8000), jnp.int32)
        got = sharded_potential_counts(mesh, "data", codes, 50)
        want = np.bincount(np.asarray(codes), minlength=50)
        print(json.dumps({"ok": bool((np.asarray(got) == want).all())}))
    """))
    assert res["ok"]


def test_partition_histogram_matches_host_hash():
    """Device-parallel partition histogram == numpy hash_partition counts
    (the host/device hash twins must be bit-identical)."""
    res = run_child(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.dist.partition import hash_partition, partition_histogram
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        codes = rng.integers(0, 10_000, 8191).astype(np.int64)  # uneven pad
        ok = True
        for k in (2, 4, 7):
            got = np.asarray(partition_histogram(
                mesh, "data", jnp.asarray(codes, jnp.int32), k, salt=3))
            want = np.bincount(hash_partition(codes, k, salt=3), minlength=k)
            ok = ok and (got == want).all()
        print(json.dumps({"ok": bool(ok)}))
    """))
    assert res["ok"]


def test_partitioned_pipeline_matches_oracle_on_virtual_devices():
    """The tentpole acceptance gate: partitioned execution on 4 forced
    virtual CPU devices — jax generation backend, shards built
    device-parallel — produces a summary whose row count, desummarized
    rows, and aggregates exactly equal the monolithic numpy oracle."""
    res = run_child(textwrap.dedent("""
        import json
        import numpy as np
        import jax
        from repro.core.api import GraphicalJoin
        from repro.relational.synth import lastfm_like
        from repro.summary.algebra import SummaryFrame
        assert jax.device_count() >= 4
        cat, qs = lastfm_like(n_users=120, n_artists=90, artists_per_user=4,
                              friends_per_user=3)
        checks = []
        for name in ("lastfm_A1", "lastfm_cyc"):
            q = qs[name]
            mono = GraphicalJoin(cat, q, generation_backend="numpy")
            g0 = mono.run()
            part = GraphicalJoin(cat, q, partitions=4,
                                 generation_backend="jax")
            g1 = part.run()
            vs = sorted(q.variables)
            def rows(gj, g):
                r = gj.desummarize(g, decode=False)
                m = np.stack([r[v] for v in vs], axis=1)
                return m[np.lexsort(m.T[::-1])]
            f0, f1 = SummaryFrame.of(g0), SummaryFrame.of(g1)
            var = vs[0]
            t0 = f0.group_by(vs[-1], n="count", s=("sum", var))
            t1 = f1.group_by(vs[-1], n="count", s=("sum", var))
            checks.append(bool(
                g1.join_size == g0.join_size
                and np.array_equal(rows(mono, g0), rows(part, g1))
                and f1.count() == f0.count()
                and f1.sum(var) == f0.sum(var)
                and f1.min(var) == f0.min(var)
                and f1.max(var) == f0.max(var)
                and all(np.array_equal(np.asarray(t0[k]),
                                       np.asarray(t1[k])) for k in t0)))
        print(json.dumps({"ok": all(checks), "checks": checks}))
    """), devices=4)
    assert res["ok"], res


def test_parallel_desummarize_equals_full():
    import numpy as np
    from repro.core.api import GraphicalJoin
    from repro.dist.partition import parallel_desummarize
    from repro.relational.synth import lastfm_like
    cat, qs = lastfm_like(n_users=100, n_artists=80, artists_per_user=4,
                          friends_per_user=3)
    gj = GraphicalJoin(cat, qs["lastfm_A1"])
    gfjs = gj.run()
    full = gj.desummarize(gfjs, decode=False)
    par = parallel_desummarize(gfjs, 5)
    for v in gfjs.column_order:
        np.testing.assert_array_equal(full[v], par[v])


def test_data_parallel_training_equivalence():
    """8-way DP (shard_map, uncompressed) == single-device training."""
    res = run_child(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.models.model import LM
        from repro.launch.mesh import make_mesh
        from repro.train.optim import AdamWConfig, init_state
        from repro.train.train_step import (TrainState, make_train_step,
                                            make_dp_shard_map_step)
        cfg = get_smoke("qwen3_8b").scaled(num_layers=2,
                                           compute_dtype="float32",
                                           param_dtype="float32")
        lm = LM(cfg)
        p = lm.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
        ocfg = AdamWConfig(grad_clip=0.0)
        # reference: plain single-logical-device step
        ref, _ = jax.jit(make_train_step(lm, ocfg))(TrainState(p, init_state(p)), batch)
        # explicit shard_map DP without compression
        mesh = make_mesh((8,), ("data",))
        init, step = make_dp_shard_map_step(lm, ocfg, mesh, compress=False,
                                            axis="data")
        dp_state, m = step(init(p), batch)
        diffs = [float(jnp.abs(dp_state.params[k].astype(jnp.float32)
                               - ref.params[k].astype(jnp.float32)).max())
                 for k in ref.params]
        print(json.dumps({"max_diff": max(diffs)}))
    """))
    assert res["max_diff"] < 2e-5, res


def test_compressed_gradient_allreduce_close_to_exact():
    """int8 + error feedback: first step close, error bounded."""
    res = run_child(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.models.model import LM
        from repro.launch.mesh import make_mesh
        from repro.train.optim import AdamWConfig, init_state
        from repro.train.train_step import (TrainState, make_train_step,
                                            make_dp_shard_map_step)
        cfg = get_smoke("qwen3_8b").scaled(num_layers=2,
                                           compute_dtype="float32",
                                           param_dtype="float32")
        lm = LM(cfg)
        p = lm.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        def next_batch():
            return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
                    "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
        ocfg = AdamWConfig(grad_clip=0.0, lr=1e-3)
        mesh = make_mesh((8,), ("data",))
        init_c, step_c = make_dp_shard_map_step(lm, ocfg, mesh, compress=True)
        init_e, step_e = make_dp_shard_map_step(lm, ocfg, mesh, compress=False)
        sc, se = init_c(p), init_e(p)
        for _ in range(5):
            b = next_batch()
            sc, mc = step_c(sc, b)
            se, me = step_e(se, b)
        rel = []
        for k in se.params:
            a = np.asarray(sc.params[k], np.float32)
            b_ = np.asarray(se.params[k], np.float32)
            denom = np.abs(b_ - np.asarray(p[k], np.float32)).max() + 1e-12
            rel.append(float(np.abs(a - b_).max() / denom))
        print(json.dumps({"rel_drift": max(rel),
                          "loss_c": float(mc["loss"]), "loss_e": float(me["loss"])}))
    """))
    # the functional criterion: after 5 steps the compressed run's loss
    # tracks the exact run's loss tightly; per-leaf drift stays bounded
    # (relative drift is noisy on leaves whose total movement is ~0)
    assert abs(res["loss_c"] - res["loss_e"]) < 0.05, res
    assert res["rel_drift"] < 2.0, res


def test_gspmd_sharded_train_step_matches_single_device():
    """The production-style GSPMD path (param/batch shardings on a 4x2 mesh)
    computes the same update as the unsharded step."""
    res = run_child(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models.model import LM
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import state_shardings, arch_rules
        from repro.dist.sharding import param_specs
        from repro.train.optim import AdamWConfig, init_state
        from repro.train.train_step import TrainState, make_train_step
        cfg = get_smoke("qwen3_8b").scaled(num_layers=2,
                                           compute_dtype="float32",
                                           param_dtype="float32",
                                           d_model=64, num_heads=4,
                                           num_kv_heads=2)
        lm = LM(cfg)
        p = lm.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
        ocfg = AdamWConfig(grad_clip=0.0)
        step = make_train_step(lm, ocfg)
        ref, _ = jax.jit(step)(TrainState(p, init_state(p)), batch)

        mesh = make_mesh((4, 2), ("data", "model"))
        rules = arch_rules(cfg, mesh)
        st_sh = state_shardings(lm, mesh, rules)
        b_sh = jax.tree.map(lambda _: NamedSharding(mesh, P("data")), batch)
        with mesh:
            fn = jax.jit(step, in_shardings=(st_sh, b_sh))
            state = TrainState(
                {k: jax.device_put(v, st_sh.params[k]) for k, v in p.items()},
                init_state(p))
            out, _ = fn(state, batch)
        diffs = [float(jnp.abs(out.params[k].astype(jnp.float32)
                               - ref.params[k].astype(jnp.float32)).max())
                 for k in ref.params]
        print(json.dumps({"max_diff": max(diffs)}))
    """))
    assert res["max_diff"] < 2e-5, res
