"""Edge cases: desummarize_range / row_at boundaries, empty-psi lookup
regression, and the storage codec fallback."""

import numpy as np
import pytest

from repro.core.api import GraphicalJoin
from repro.core.elimination import Psi
from repro.core.gfjs import (_lookup_groups, desummarize, desummarize_range,
                             generate_gfjs, row_at)
from repro.core.potentials import INT
from repro.relational.synth import figure1, lastfm_like
from repro.relational.query import JoinQuery
from repro.relational.table import Catalog, Table


@pytest.fixture(scope="module")
def fig1_gfjs():
    cat, query = figure1()
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    return gfjs, desummarize(gfjs, decode=False)


# ---------------------------------------------------------------------------
# desummarize_range / row_at
# ---------------------------------------------------------------------------

def test_range_empty_when_lo_equals_hi(fig1_gfjs):
    gfjs, _ = fig1_gfjs
    for lo in (0, 1, gfjs.join_size // 2, gfjs.join_size):
        part = desummarize_range(gfjs, lo, lo, decode=False)
        assert all(len(part[v]) == 0 for v in gfjs.column_order)


def test_range_inverted_bounds_are_empty(fig1_gfjs):
    gfjs, _ = fig1_gfjs
    part = desummarize_range(gfjs, 10, 3, decode=False)
    assert all(len(v) == 0 for v in part.values())


def test_range_full_equals_desummarize(fig1_gfjs):
    gfjs, full = fig1_gfjs
    part = desummarize_range(gfjs, 0, gfjs.join_size, decode=False)
    for v in gfjs.column_order:
        assert np.array_equal(part[v], full[v])
    # out-of-bounds clamp
    part = desummarize_range(gfjs, -5, gfjs.join_size + 100, decode=False)
    for v in gfjs.column_order:
        assert np.array_equal(part[v], full[v])


def test_range_aligned_on_run_boundaries(fig1_gfjs):
    gfjs, full = fig1_gfjs
    # every prefix-sum boundary of every level, as both lo and hi
    cuts = sorted({0, gfjs.join_size}
                  | {int(b) for li in range(len(gfjs.levels))
                     for b in gfjs.bounds(li)})
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        part = desummarize_range(gfjs, lo, hi, decode=False)
        for v in gfjs.column_order:
            assert np.array_equal(part[v], full[v][lo:hi]), (lo, hi, v)


def test_range_single_rows_match_row_at(fig1_gfjs):
    gfjs, full = fig1_gfjs
    for t in range(gfjs.join_size):
        part = desummarize_range(gfjs, t, t + 1, decode=False)
        row = row_at(gfjs, t, decode=False)
        for v in gfjs.column_order:
            assert part[v][0] == full[v][t] == row[v]
    with pytest.raises(IndexError):
        row_at(gfjs, gfjs.join_size)
    with pytest.raises(IndexError):
        row_at(gfjs, -1)


# ---------------------------------------------------------------------------
# empty-psi regression (_lookup_groups on zero-group conditional factors)
# ---------------------------------------------------------------------------

def _empty_psi() -> Psi:
    return Psi(child="B", parents=("A",),
               parent_keys=np.zeros((0, 1), INT),
               start=np.zeros(0, INT), count=np.zeros(0, INT),
               child_codes=np.zeros(0, INT), bucket=np.zeros(0, INT),
               fac=np.zeros(0, INT), parent_sizes=(4,), child_size=4)


def test_lookup_groups_empty_psi_returns_misses():
    frontier = np.asarray([[0], [1], [3]], dtype=INT)
    got = _lookup_groups(frontier, _empty_psi())
    assert got.tolist() == [-1, -1, -1]


def test_lookup_groups_empty_frontier_and_psi():
    got = _lookup_groups(np.zeros((0, 1), INT), _empty_psi())
    assert got.shape == (0,)


def test_generate_gfjs_with_empty_join_branch():
    """A table with no rows empties the join; generation must not crash."""
    cat = Catalog.of(
        Table("t0", {"x0": np.asarray([0, 1, 2]), "x1": np.asarray([0, 1, 2])}),
        Table("t1", {"x0": np.zeros(0, np.int64), "x1": np.zeros(0, np.int64)}))
    query = JoinQuery.of("empty", [("t0", {"x0": "A", "x1": "B"}),
                                   ("t1", {"x0": "B", "x1": "C"})])
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    assert gfjs.join_size == 0
    flat = desummarize(gfjs, decode=False)
    assert all(len(flat[v]) == 0 for v in gfjs.column_order)


# ---------------------------------------------------------------------------
# storage codec
# ---------------------------------------------------------------------------

def test_zlib_codec_roundtrip(tmp_path):
    from repro.core.storage import load_gfjs, save_gfjs
    cat, query = figure1()
    gfjs = GraphicalJoin(cat, query).run()
    p = str(tmp_path / "fig1.zlib.gfjs")
    n = save_gfjs(gfjs, p, codec="zlib")
    assert n > 0
    back = load_gfjs(p)
    assert back.join_size == gfjs.join_size
    for a, b in zip(gfjs.levels, back.levels):
        assert np.array_equal(a.freq, b.freq)
        for v in a.vars:
            assert np.array_equal(a.key_cols[v], b.key_cols[v])


def test_default_codec_always_loadable(tmp_path):
    """Whatever the environment, save with defaults must load back."""
    from repro.core.storage import default_codec, load_gfjs, save_gfjs
    cat, qs = lastfm_like(n_users=40, n_artists=30, artists_per_user=3,
                          friends_per_user=2)
    gfjs = GraphicalJoin(cat, qs["lastfm_A1"]).run()
    p = str(tmp_path / "a1.gfjs")
    save_gfjs(gfjs, p)
    assert default_codec() in ("zstd", "zlib")
    back = load_gfjs(p)
    assert back.column_order == gfjs.column_order
    assert back.join_size == gfjs.join_size


def test_compress_roundtrip_helpers():
    from repro.core.storage import compress_bytes, decompress_bytes
    raw = b"graphical join summary" * 100
    codec, payload = compress_bytes(raw)
    assert len(payload) < len(raw)
    assert decompress_bytes(payload, codec) == raw
    codec2, payload2 = compress_bytes(raw, codec="zlib")
    assert codec2 == "zlib"
    assert decompress_bytes(payload2, "zlib") == raw
