"""Junction-tree machinery tests (paper §2.2.1): min-fill, chordality, RIP."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; absent in minimal envs
from hypothesis import given, settings, strategies as st

from repro.core.graph import (QueryGraph, is_chordal, junction_tree,
                              min_fill_order)
from repro.relational.query import JoinQuery
from repro.relational.synth import figure1, lastfm_like


def _graph_from_edges(edges):
    variables = sorted({v for e in edges for v in e})
    adj = {v: set() for v in variables}
    for a, b in edges:
        adj[a].add(b)
        adj[b].add(a)
    hyper = [frozenset(e) for e in edges]
    return QueryGraph(variables, adj, hyper)


def test_tree_query_has_perfect_elimination_order():
    cat, query = figure1()
    g = QueryGraph.from_query(query)
    tri = min_fill_order(g)
    assert tri.fill_edges == []           # trees need no fill-ins
    assert len(tri.maxcliques) == 3       # the three table edges


def test_four_cycle_needs_one_fill_edge():
    g = _graph_from_edges([("A", "B"), ("B", "C"), ("C", "D"), ("D", "A")])
    tri = min_fill_order(g)
    assert len(tri.fill_edges) == 1       # one chord triangulates a 4-cycle
    assert max(len(c) for c in tri.maxcliques) == 3


def test_lastfm_cyc_junction_tree_shape():
    """The paper's Figure 6: three maxcliques of size 3, RIP holds."""
    _, queries = lastfm_like(n_users=10, n_artists=10)
    q = queries["lastfm_cyc"]
    g = QueryGraph.from_query(q)
    tri = min_fill_order(g)
    jt = junction_tree(tri.maxcliques)
    assert max(len(c) for c in tri.maxcliques) == 3
    assert len(tri.maxcliques) == 3
    assert jt.satisfies_rip()


def test_triangulated_graph_is_chordal():
    g = _graph_from_edges([("A", "B"), ("B", "C"), ("C", "D"), ("D", "E"),
                           ("E", "A"), ("B", "D")])
    tri = min_fill_order(g)
    adj = {v: set(ns) for v, ns in g.adjacency.items()}
    for a, b in tri.fill_edges:
        adj[a].add(b)
        adj[b].add(a)
    assert is_chordal(adj)


@settings(max_examples=60, deadline=None)
@given(st.integers(3, 8), st.data())
def test_random_graph_triangulation_properties(n, data):
    """Min-fill output is chordal; its JT satisfies RIP; maxcliques cover
    every original hyperedge."""
    vars_ = [f"v{i}" for i in range(n)]
    edges = []
    # random connected graph: spanning path + random extras
    for i in range(n - 1):
        edges.append((vars_[i], vars_[i + 1]))
    extra = data.draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)), max_size=8))
    for a, b in extra:
        if a != b:
            edges.append((vars_[a], vars_[b]))
    g = _graph_from_edges(edges)
    tri = min_fill_order(g)

    adj = {v: set(ns) for v, ns in g.adjacency.items()}
    for a, b in tri.fill_edges:
        adj[a].add(b)
        adj[b].add(a)
    assert is_chordal(adj)

    for e in g.hyperedges:
        assert any(e <= c for c in tri.maxcliques), "hyperedge not covered"

    jt = junction_tree(tri.maxcliques)
    assert jt.satisfies_rip()

    # elimination order covers every variable exactly once
    assert sorted(tri.order) == sorted(vars_)


def test_early_projection_order_puts_non_output_first():
    cat, query = figure1()
    q = JoinQuery.of("p", [(qt.table, dict(qt.var_map)) for qt in query.tables],
                     output=["A", "D"])
    g = QueryGraph.from_query(q)
    tri = min_fill_order(g, first=["B", "C"])
    assert set(tri.order[:2]) == {"B", "C"}
