"""Fused multi-payload expansion + device-resident GFJS generation.

Interpret-mode parity for `expand_gather_many` against the np.repeat oracle
(empty runs, single-run levels, padding-tail contract, K=1 degeneration,
x64 dtype pinning), level-for-level `generate_gfjs_jax` == `generate_gfjs`
on the random acyclic/cyclic query generator from test_plan, the
generation-backend plumbing, the memoized launch metadata, the on-device
group_by sort, and the O(1) kernel-pick guard of `segment_weighted_sum`.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine_jax  # noqa: F401  (flips jax_enable_x64 on)
from repro.core.api import GraphicalJoin
from repro.core.engine_jax import (_f32_exact_conclusive, desummarize_jax,
                                   generate_gfjs_jax, group_runs_device,
                                   segment_weighted_sum)
from repro.core.gfjs import desummarize, generate_gfjs
from repro.kernels import ops
from repro.kernels.expand import expand_gather
from repro.kernels.expand_fused import expand_gather_many
from repro.plan import Executor

from test_plan import SHAPES, _random_instance


# ---------------------------------------------------------------------------
# expand_gather_many vs the np.repeat oracle (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_runs", [1, 7, 500, 513, 1200])
@pytest.mark.parametrize("k", [1, 2, 5])
def test_expand_many_parity(n_runs, k):
    rng = np.random.default_rng(n_runs * 31 + k)
    freqs = rng.integers(1, 9, n_runs)
    bounds = np.cumsum(freqs).astype(np.int32)
    total = int(bounds[-1])
    payloads = rng.integers(0, 1 << 20, (k, n_runs)).astype(np.int32)
    got = ops.rle_expand_many(payloads, bounds, total, interpret=True)
    want = np.stack([np.repeat(payloads[q], freqs) for q in range(k)])
    np.testing.assert_array_equal(np.asarray(got), want)


def test_expand_many_empty_runs():
    """Zero-length runs (absent parent groups) contribute no output rows."""
    rng = np.random.default_rng(0)
    freqs = rng.integers(0, 4, 600)          # many zero-length runs
    freqs[::7] = 0
    bounds = np.cumsum(freqs).astype(np.int32)
    total = int(bounds[-1])
    payloads = rng.integers(0, 1 << 20, (3, 600)).astype(np.int32)
    got = ops.rle_expand_many(payloads, bounds, total, interpret=True)
    want = np.stack([np.repeat(payloads[q], freqs) for q in range(3)])
    np.testing.assert_array_equal(np.asarray(got), want)


def test_expand_many_single_run_level():
    got = ops.rle_expand_many(np.asarray([[9], [4]], np.int32),
                              np.asarray([6], np.int32), 6, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), [[9] * 6, [4] * 6])


def test_expand_many_padding_tail_contract():
    """Rows [total..t_pad) replicate whatever the saturated run index picks —
    exactly what the per-column kernel produces for the same bounds."""
    rng = np.random.default_rng(3)
    freqs = rng.integers(1, 5, 300)
    bounds = np.cumsum(freqs).astype(np.int32)
    total = int(bounds[-1])
    t_pad = ops.next_bucket(total)
    assert t_pad > total                      # the contract has a tail here
    payloads = rng.integers(0, 1 << 20, (2, 300)).astype(np.int32)
    got = expand_gather_many(jnp.asarray(payloads), jnp.asarray(bounds),
                             t_pad=t_pad, interpret=True)
    assert got.shape == (2, t_pad)
    for q in range(2):
        col = expand_gather(jnp.asarray(payloads[q]), jnp.asarray(bounds),
                            t_pad=t_pad, interpret=True)
        np.testing.assert_array_equal(np.asarray(got[q]), np.asarray(col))


def test_expand_many_k1_degenerates_to_expand_gather():
    rng = np.random.default_rng(4)
    freqs = rng.integers(1, 7, 777)
    bounds = np.cumsum(freqs).astype(np.int32)
    t_pad = ops.next_bucket(int(bounds[-1]))
    payload = rng.integers(0, 1 << 30, 777).astype(np.int32)
    one = expand_gather(jnp.asarray(payload), jnp.asarray(bounds),
                        t_pad=t_pad, interpret=True)
    many = expand_gather_many(jnp.asarray(payload[None]), jnp.asarray(bounds),
                              t_pad=t_pad, interpret=True)
    np.testing.assert_array_equal(np.asarray(many[0]), np.asarray(one))


def test_expand_many_x64_dtype_pinning():
    """Under jax_enable_x64 (flipped by the engine_jax import) the int32
    pins must hold: int64 inputs ride in, int32 comes out, no promotion."""
    freqs = np.asarray([2, 3, 1], np.int64)
    bounds = np.cumsum(freqs)                 # int64 on purpose
    payloads = np.asarray([[5, 6, 7], [1, 2, 3]], np.int64)
    got = ops.rle_expand_many(payloads, bounds, 6, interpret=True)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(got), np.stack([np.repeat(payloads[q], freqs)
                                   for q in range(2)]))


def test_gfjs_expand_meta_is_memoized():
    cat, query = _random_instance("chain3", 0)
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    if gfjs.join_size == 0:
        pytest.skip("degenerate empty instance")
    t_pad = ops.next_bucket(gfjs.join_size)
    m1 = ops.gfjs_expand_meta(gfjs, 0, t_pad)
    m2 = ops.gfjs_expand_meta(gfjs, 0, t_pad)
    assert m1 is m2                          # same tuple, no recompute
    assert 0 in gfjs._launch
    # bounded: a different t_pad replaces rather than accumulates, and the
    # byte-budget accounting sees the cached arrays
    ops.gfjs_expand_meta(gfjs, 0, t_pad * 2)
    assert len(gfjs._launch) == 1 and gfjs._launch[0][0] == t_pad * 2
    assert gfjs.resident_nbytes() == gfjs.nbytes() + gfjs.aux_nbytes()
    assert gfjs.aux_nbytes() > 0


# ---------------------------------------------------------------------------
# generate_gfjs_jax vs the numpy oracle, level for level
# ---------------------------------------------------------------------------

def _assert_gfjs_equal(a, b):
    assert a.join_size == b.join_size
    assert a.column_order == b.column_order
    assert len(a.levels) == len(b.levels)
    for la, lb in zip(a.levels, b.levels):
        assert la.vars == lb.vars
        np.testing.assert_array_equal(la.freq, lb.freq)
        for v in la.vars:
            np.testing.assert_array_equal(la.key_cols[v], lb.key_cols[v])


@pytest.mark.parametrize("shape", sorted(SHAPES))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_generate_gfjs_jax_parity(shape, seed):
    cat, query = _random_instance(shape, seed)
    gj = GraphicalJoin(cat, query)
    gfjs_np = gj.run()
    gfjs_jax = generate_gfjs_jax(gj.generator, gj.enc.domains,
                                 interpret=True)
    _assert_gfjs_equal(gfjs_np, gfjs_jax)


@pytest.mark.parametrize("seed", [3, 5])
def test_generate_gfjs_jax_parity_projected(seed):
    cat, query = _random_instance("chain3", seed, output=["A", "D"])
    gj = GraphicalJoin(cat, query)
    gfjs_np = gj.run()
    gfjs_jax = generate_gfjs_jax(gj.generator, gj.enc.domains,
                                 interpret=True)
    _assert_gfjs_equal(gfjs_np, gfjs_jax)


def test_generate_gfjs_jax_empty_join():
    """A join that dies mid-generation must emit empty levels, like numpy."""
    from repro.relational.table import Catalog, Table
    from repro.relational.query import JoinQuery
    cat = Catalog.of(
        Table("t0", {"x0": np.asarray([0, 1]), "x1": np.asarray([0, 1])}),
        Table("t1", {"x0": np.asarray([5, 6]), "x1": np.asarray([2, 3])}),
    )
    q = JoinQuery.of("dead", [("t0", {"x0": "A", "x1": "B"}),
                              ("t1", {"x0": "B", "x1": "C"})])
    gj = GraphicalJoin(cat, q)
    gfjs_np = gj.run()
    assert gfjs_np.join_size == 0
    gfjs_jax = generate_gfjs_jax(gj.generator, gj.enc.domains,
                                 interpret=True)
    _assert_gfjs_equal(gfjs_np, gfjs_jax)


def test_generate_gfjs_jax_fallback_is_oracle(monkeypatch):
    """Outside the int32/packing envelope the numpy oracle runs unchanged."""
    monkeypatch.setattr(engine_jax, "_jax_generable", lambda gen: False)
    cat, query = _random_instance("triangle", 1)
    gj = GraphicalJoin(cat, query)
    gfjs_np = gj.run()
    gfjs_jax = generate_gfjs_jax(gj.generator, gj.enc.domains)
    _assert_gfjs_equal(gfjs_np, gfjs_jax)


def test_executor_generation_backend_knob():
    cat, query = _random_instance("cycle4", 2)
    ex_np = Executor(cat, query, generation_backend="numpy")
    gfjs_np = ex_np.run()
    ex_jax = Executor(cat, query, generation_backend="jax")
    gfjs_jax = ex_jax.run()
    _assert_gfjs_equal(gfjs_np, gfjs_jax)
    assert ex_jax.plan.backends["summarize"] == "jax"
    assert "summarize=jax" in ex_jax.explain()
    # the knob is execution-relevant, so it must flow into plan identity
    assert ex_np.plan.signature() != ex_jax.plan.signature()


def test_desummarize_jax_fused_matches_numpy():
    cat, query = _random_instance("star3", 1)
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    want = desummarize(gfjs, decode=False)
    got = desummarize_jax(gfjs, decode=False, interpret=True)
    for v in gfjs.column_order:
        np.testing.assert_array_equal(want[v], np.asarray(got[v]))
    assert gfjs._launch                       # meta memoized on the summary


# ---------------------------------------------------------------------------
# on-device group_by sort + O(1) exactness guard
# ---------------------------------------------------------------------------

def test_group_runs_device_matches_host():
    rng = np.random.default_rng(11)
    ranks = rng.integers(0, 400, 6000).astype(np.int64)
    order, seg, starts, ngroups = group_runs_device(ranks)
    horder = np.argsort(ranks, kind="stable")
    sranks = ranks[horder]
    new = np.ones(len(sranks), bool)
    new[1:] = sranks[1:] != sranks[:-1]
    np.testing.assert_array_equal(order, horder)
    np.testing.assert_array_equal(seg, np.cumsum(new) - 1)
    np.testing.assert_array_equal(starts, np.flatnonzero(new))
    assert ngroups == int(new.sum())


def test_group_by_device_path_parity(monkeypatch):
    from repro.summary.algebra import SummaryFrame
    cat, query = _random_instance("chain3", 6)
    gj = GraphicalJoin(cat, query)
    gfjs = gj.run()
    if gfjs.join_size == 0:
        pytest.skip("degenerate empty instance")
    frame = SummaryFrame.of(gfjs)
    host = frame.group_by(["A", "B"], n="count", lo=("min", "D"),
                          s=("sum", "C"))
    monkeypatch.setattr(engine_jax, "GROUP_DEVICE_MIN_RUNS", 0)
    monkeypatch.setattr(engine_jax, "group_device_enabled", lambda: True)
    dev = frame.group_by(["A", "B"], n="count", lo=("min", "D"),
                         s=("sum", "C"))
    assert host.keys() == dev.keys()
    for k in host:
        np.testing.assert_array_equal(host[k], dev[k])


def test_f32_exact_guard_dtype_ranges_are_o1():
    """Narrow dtypes decide without touching the data."""
    v = np.ones(1000, np.int8)
    w = np.ones(1000, np.int8)
    assert _f32_exact_conclusive(v, w, len(v), None)       # 1000*127*127 fits
    big = np.full(10, 2 ** 40, np.int64)
    # wide dtype + no hint -> falls back to the scan, which is conclusive
    assert not _f32_exact_conclusive(big, big, len(big), None)


def test_f32_exact_guard_bound_hint():
    big_dtype_small_values = np.ones(10, np.int64)
    w = np.ones(10, np.int64)
    assert _f32_exact_conclusive(big_dtype_small_values, w, 10, bound=10.0)
    assert not _f32_exact_conclusive(big_dtype_small_values, w, 10,
                                     bound=float(1 << 30))


def test_segment_weighted_sum_bound_does_not_change_results():
    rng = np.random.default_rng(5)
    seg = np.sort(rng.integers(0, 50, 2000)).astype(np.int32)
    _, seg = np.unique(seg, return_inverse=True)
    v = rng.integers(-100, 100, len(seg)).astype(np.int64)
    w = rng.integers(0, 100, len(seg)).astype(np.int64)
    ns = int(seg.max()) + 1
    base = segment_weighted_sum(seg, v, w, ns)
    hinted = segment_weighted_sum(seg, v, w, ns,
                                  bound=float(np.abs(v * w).sum()))
    loose = segment_weighted_sum(seg, v, w, ns, bound=float(1 << 40))
    np.testing.assert_array_equal(base, hinted)
    np.testing.assert_array_equal(base, loose)
